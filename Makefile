PYTEST ?= python -m pytest

.PHONY: test test-fast test-dist dryrun

# full tier-1 suite (includes slow 8-host-device subprocess parity tests)
test:
	$(PYTEST) -q

# fast tier: skips @slow (multi-device subprocess / long-running) tests
test-fast:
	$(PYTEST) -q -m "not slow"

# just the distribution layer (seed parity tests + unit tests)
test-dist:
	$(PYTEST) -q tests/test_distribution.py tests/test_dist_layer.py

# 512-host-device compile census over every (arch x shape) cell
dryrun:
	PYTHONPATH=src python -m repro.launch.dryrun
