PYTEST ?= python -m pytest

.PHONY: test test-fast test-dist dryrun bench-serve bench-traffic \
	bench-reuse bench-disagg bench-compress bench-overlap validate-bench

# full tier-1 suite (includes slow 8-host-device subprocess parity tests)
test:
	$(PYTEST) -q

# fast tier: skips @slow (multi-device subprocess / long-running) tests
test-fast:
	$(PYTEST) -q -m "not slow"

# just the distribution layer (seed parity tests + unit tests)
test-dist:
	$(PYTEST) -q tests/test_distribution.py tests/test_dist_layer.py

# 512-host-device compile census over every (arch x shape) cell
dryrun:
	PYTHONPATH=src python -m repro.launch.dryrun

# short serving benchmark (tokens/s + tier hit rates + migration bytes/s);
# writes BENCH_serve.json so the perf trajectory is recorded per commit
bench-serve:
	PYTHONPATH=src:. python benchmarks/run.py --quick --only serve_bench

# multi-tenant traffic benchmark: the continuous-batching scheduler over the
# zipf-hot / diurnal-shift / scan-antagonist traces (throughput, p50/p99
# per-token latency, steady-state hit rates, migration bytes/s) — appends
# the "traffic" section to BENCH_serve.json
bench-traffic:
	PYTHONPATH=src:. python benchmarks/run.py --quick --only traffic_bench

# cross-request KV reuse A/B (DESIGN.md §12): the agentic multi-turn trace
# served with the content-addressed page store off / prefix / substring —
# writes the "kv_reuse" section of BENCH_serve.json (bit-exactness,
# prefill-tokens-saved, and substring-vs-prefix hit-rate gates)
bench-reuse:
	PYTHONPATH=src:. python benchmarks/traffic_bench.py --quick --reuse

# prefill/decode disaggregation A/B (DESIGN.md §13): the prefill-heavy trace
# served by the unified scheduler vs the split prefill-worker/decode-worker
# pools over the slow-tier hand-off fabric, same total lane budget — writes
# the "disagg" section of BENCH_serve.json (bit-exactness, hand-off bytes,
# and decode-lane TPOT-flatness-under-concurrent-prefill gates)
bench-disagg:
	PYTHONPATH=src:. python benchmarks/traffic_bench.py --disagg

# slow-tier codec A/B (DESIGN.md §14): the zipf-hot trace served under the
# none / fp32 / int8 slow-store codecs at the same page quota, plus the
# logit-drift probe and the zero1 compressed-collective parity — writes the
# "compress" section of BENCH_serve.json (byte-ratio, hit-parity, drift,
# and fp32-arm bit-exactness gates)
bench-compress:
	PYTHONPATH=src:. python benchmarks/serve_bench.py --quick --compress

# async-migration A/B (DESIGN.md §15): the MoE smoke arch (paged KV +
# experts + embeddings) served with the synchronous data plane vs the
# double-buffered async one — writes the "overlap" section of
# BENCH_serve.json (bit-exactness, equal-migration-bytes, stall-cut, and
# achieved-overlap gates)
bench-overlap:
	PYTHONPATH=src:. python benchmarks/serve_bench.py --quick --overlap

# check BENCH_serve.json against the schema documented in benchmarks/README.md
validate-bench:
	PYTHONPATH=src:. python benchmarks/validate_bench.py
