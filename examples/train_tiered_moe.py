"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps with
NeoMem expert-stream profiling + checkpointing + (optional) crash resume.

    PYTHONPATH=src python examples/train_tiered_moe.py --steps 200
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import tiering as tm
from repro.ckpt.manager import CheckpointManager
from repro.configs.base import ArchConfig, MoECfg
from repro.data.pipeline import DataConfig, make_dataset
from repro.models import transformer as tr
from repro.optim.optimizers import OptConfig, make_optimizer

# ~100M params: 8L, d=512, 16 experts of ff=1024 top-2, vocab 32K
CFG = ArchConfig(
    name="moe-100m", family="moe", n_layers=9, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64, pattern=("moe",),
    moe=MoECfg(n_experts=16, top_k=2, expert_ff=1024, shared_ff=1024,
               n_dense_prologue=1, dense_ff=2048),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/neomem_moe_ckpt")
    args = ap.parse_args()

    n = CFG.total_params()
    print(f"model: {n/1e6:.0f}M params ({CFG.active_params()/1e6:.0f}M active)")
    data = make_dataset(DataConfig(seq_len=args.seq, global_batch=args.batch,
                                   vocab=CFG.vocab))
    opt_init, opt_update = make_optimizer(OptConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps, weight_decay=0.01))
    params = tr.init_params(CFG, jax.random.PRNGKey(0))
    opt_state = opt_init(params)
    mgr = CheckpointManager(args.ckpt, keep=2)
    # NeoMem: register the router stream as an "experts" TieredResource on a
    # multiplexed daemon (a trainer would register more resources here).
    daemon = tm.NeoMemDaemon()
    experts = daemon.register(tm.make_resource("experts", tm.ResourceSpec(
        "experts", n_pages=CFG.n_groups * 16,
        hot_slots=CFG.n_groups * 4, quota_pages=32), n_experts=16))

    start = mgr.latest_step() or 0
    if start:
        print(f"resuming from checkpoint step {start}")
        params = mgr.restore(start, params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, (metrics, aux)), grads = jax.value_and_grad(
            lambda p: tr.train_loss(CFG, p, batch), has_aux=True)(params)
        params, opt_state, om = opt_update(params, grads, opt_state)
        return params, opt_state, loss, aux.get("router_streams")

    t0 = time.time()
    for s in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch(s, 0, 1))
        params, opt_state, loss, streams = step(params, opt_state, batch)
        if streams is not None:
            experts.observe(streams)      # NeoMem: profile the router stream
            daemon.tick()
        if s % 20 == 0 or s == args.steps - 1:
            tput = (s - start + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {s:4d} loss={float(loss):.3f} "
                  f"tok/s={tput:,.0f} expert_hit={experts.hit_rate():.2f}")
        if s and s % 100 == 0:
            mgr.save(s, params, blocking=False)
    mgr.wait()
    mgr.save(args.steps, params)
    print("final expert residency (hot experts per group):")
    res = np.asarray(experts.state.tier.page_slot).reshape(CFG.n_groups, 16)
    print((res >= 0).sum(axis=1))


if __name__ == "__main__":
    main()
