"""Quickstart: NeoMem's sketch-profiled tiering on a synthetic access stream.

Shows the full paper loop in ~40 lines: NeoProf observes the stream on
device, Algorithm 1 adapts the hotness threshold, the TieredStore promotes
hot pages under quota, and the hit rate converges.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (DaemonParams, NeoMemDaemon, NeoProfParams,
                        SketchParams, TierParams, neoprof_init,
                        neoprof_observe, tier_init, touch)

N_PAGES, N_SLOTS = 8192, 1024
pp = NeoProfParams(sketch=SketchParams(width=1 << 14))
tp = TierParams(N_PAGES, N_SLOTS, quota_pages=128)
daemon = NeoMemDaemon(pp, tp, DaemonParams(
    migration_interval=1, threshold_update_period=4, clear_interval=16))
prof, tier = neoprof_init(pp), tier_init(tp)
prof = daemon.cmd.set_threshold(prof, 4)

rng = np.random.default_rng(0)
for step in range(128):
    # 85% of traffic to a 600-page hot region, 15% uniform
    hot = rng.integers(7000, 7600, 1740)
    uni = rng.integers(0, N_PAGES, 308)
    pages = np.concatenate([hot, uni]).astype(np.int32)
    # profile ONLY slow-tier traffic (NeoProf sits in the slow tier)
    slot = np.asarray(tier.page_slot)
    slow = pages[slot[pages] < 0]
    blk = np.full(len(pages), -1, np.int32)
    blk[: len(slow)] = slow
    prof = neoprof_observe(prof, jnp.asarray(blk), pp)
    tier = touch(tier, jnp.asarray(pages))
    prof, tier = daemon.tick(prof, tier)
    if step % 16 == 15:
        st = daemon.state
        total = st.total_fast + st.total_slow + 1
        print(f"step {step:4d}  theta={daemon.policy.theta:4d}  "
              f"hit={st.total_fast/total:.3f}  promoted={st.total_promoted}")
print("hot pages resident:",
      int((np.asarray(tier.page_slot)[7000:7600] >= 0).sum()), "/ 600")
