"""Quickstart: NeoMem's sketch-profiled tiering on a synthetic access stream.

Shows the full paper loop in ~40 lines on the unified ``repro.tiering``
surface: one :class:`ResourceSpec` declares the geometry, NeoProf observes
the stream on device, Algorithm 1 adapts the hotness threshold, the 2Q
tier promotes hot pages under quota, and the hit rate converges.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.tiering import (DaemonParams, NeoMemDaemon, ResourceSpec,
                           StreamResource)

N_PAGES, N_SLOTS = 8192, 1024
spec = ResourceSpec(name="demo", n_pages=N_PAGES, hot_slots=N_SLOTS,
                    quota_pages=128, sketch_width=1 << 14)
daemon = NeoMemDaemon(DaemonParams(
    migration_interval=1, threshold_update_period=4, clear_interval=16))
h = daemon.register(StreamResource(spec))
h.state = h.state._replace(prof=h.mem.cmd.set_threshold(h.state.prof, 4))

rng = np.random.default_rng(0)
for step in range(128):
    # 85% of traffic to a 600-page hot region, 15% uniform
    hot = rng.integers(7000, 7600, 1740)
    uni = rng.integers(0, N_PAGES, 308)
    pages = np.concatenate([hot, uni]).astype(np.int32)
    # profile ONLY slow-tier traffic (NeoProf sits in the slow tier);
    # the tier's touch accounting still sees every access
    slot = np.asarray(h.state.tier.page_slot)
    slow = pages[slot[pages] < 0]
    blk = np.full(len(pages), -1, np.int32)
    blk[: len(slow)] = slow
    h.observe_pages(jnp.asarray(blk), touch_pages=jnp.asarray(pages))
    daemon.tick()
    if step % 16 == 15:
        pol = h.mem.policy_state(h.state, h.stats)
        print(f"step {step:4d}  theta={pol.theta:4d}  "
              f"hit={h.hit_rate():.3f}  promoted={h.stats.promoted}")
print("hot pages resident:",
      int((np.asarray(h.state.tier.page_slot)[7000:7600] >= 0).sum()),
      "/ 600")
