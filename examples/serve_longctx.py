"""Serve a small LM with NeoMem tiering on the unified TieredResource API:
paged-KV decode over fast-tier hot pages plus embedding-row tiering, both
multiplexed on ONE daemon with a shared migration budget.

    PYTHONPATH=src python examples/serve_longctx.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(
        max_seq=512, paged=True, page_t=16, hot_slots=8,
        migration_interval=8, resources=("embeddings",), embed_hot_slots=4))

    batch = 4
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, 48)).astype(np.int32)
    print(f"prefill {batch} requests x {prompts.shape[1]} tokens (paged KV,"
          f" {eng.scfg.hot_slots} hot slots x {eng.scfg.page_t} tokens; "
          f"tiered resources: {sorted(eng.daemon.resources)})")
    t0 = time.time()
    out = eng.generate(prompts, n_tokens=32)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({batch*32/dt:.1f} tok/s interpret-mode)")
    for name, row in sorted(eng.tier_stats().items()):
        print(f"{name:12s} fast-tier hit rate: {row['hit_rate']:.2f}")
    print("sample:", out[0][:16])


if __name__ == "__main__":
    main()
