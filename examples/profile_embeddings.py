"""Embedding-row tiering demo on the unified TieredResource API: gemma2-scale
256K-row vocab, zipf token stream; NeoMem keeps the hot rows HBM-resident.

    PYTHONPATH=src python examples/profile_embeddings.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro import tiering as tm

VOCAB = 256_000
ROWS = tm.EMBED_ROWS_PER_PAGE
daemon = tm.NeoMemDaemon()
rows = daemon.register(tm.make_resource("embeddings", tm.ResourceSpec(
    "embeddings", n_pages=(VOCAB + ROWS - 1) // ROWS, hot_slots=256,
    quota_pages=64)))
rng = np.random.default_rng(0)
for step in range(96):
    toks = (rng.zipf(1.3, 4096) - 1) % VOCAB
    rows.observe(jnp.asarray(toks.astype(np.int32)))
    daemon.tick()
    if step % 16 == 15:
        theta = rows.stats.theta_trace[-1] if rows.stats.theta_trace else 1
        print(f"step {step:3d} hot-row page hit rate: {rows.hit_rate():.3f} "
              f"theta={theta}")
