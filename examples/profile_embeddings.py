"""Embedding-row tiering demo: gemma2-scale 256K-row vocab, zipf token
stream; NeoMem keeps the hot rows HBM-resident.

    PYTHONPATH=src python examples/profile_embeddings.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.adapters.embed_cache import EmbedCache, EmbedTierConfig

VOCAB = 256_000
cache = EmbedCache(EmbedTierConfig(vocab=VOCAB, hot_slots=256,
                                   quota_pages=64))
rng = np.random.default_rng(0)
for step in range(96):
    toks = (rng.zipf(1.3, 4096) - 1) % VOCAB
    cache.observe_tokens(jnp.asarray(toks.astype(np.int32)))
    cache.tick()
    if step % 16 == 15:
        print(f"step {step:3d} hot-row page hit rate: {cache.hit_rate():.3f} "
              f"theta={cache.daemon.policy.theta}")
