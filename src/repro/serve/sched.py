"""Continuous-batching request scheduler: many tenants, one tiered engine.

The request lifecycle (DESIGN.md §9) over the ServeEngine lane substrate:

    arrive ──> admit ──> prefill ──> decode ──> finish
                 ^                     │
                 └──── preempt <───────┘   (resume is bit-exact)

* **arrive/admit** — requests queue per tenant; free decode lanes are
  filled by a weighted-fair policy that reuses the daemon's
  demand-proportional quota split (`tiering.daemon.split_quota`) with
  per-tenant isolation weights: a tenant's target lane share is
  proportional to ``weight x (running + queued)``, clamped at its own
  demand.  Admission needs a free lane AND a free KV slow-store segment —
  when either is exhausted (the paper's "slow tier full" condition at the
  request level) arrivals stay queued.
* **prefill** — iteration-level continuous batching: every lane consumes
  exactly one token per engine step, a prompt token while prefilling, its
  last sampled token while decoding, so new requests join the running
  batch without draining it (the Orca-style schedule).
* **decode** — one `advance_lanes` call per step serves all lanes; the
  NeoMem daemon observes every tenant's streams and migrates on its own
  cadence between steps.  The paged ring is the per-lane fast tier; filled
  pages are flushed down to the lane's slow-store segment, so the ring
  wrapping over old pages is a real fast-tier eviction, not data loss.
* **preempt/finish** — the starvation guard: a tenant whose queue head has
  waited longer than ``preempt_patience`` steps while the tenant holds no
  lane in that pool preempts the most over-served tenant's youngest
  request.  Preemption force-flushes the lane's resident pages to the slow
  tier and snapshots the residual (`ServeEngine.preempt_lane`); resuming
  restores bit-exactly.

**Disaggregated prefill/decode** (DESIGN.md §13, ``SchedConfig.
prefill_lanes > 0``): the scheduler splits into two worker pools over the
SAME tiered slow store — the CXL-pooled hand-off fabric.  A dedicated
prefill engine (attached to the decode engine's daemon, its own lanes/
ring) runs only `ServeEngine.prefill_lane` chunks; each finished chunk's
pages flush down into the request's slow-store segment via the migration
data plane (``migrate.write_pages``).  When the last chunk lands the
request detaches as a hand-off residual (`ServeEngine.handoff_lane`) and
queues for the decode pool, which admits it only once its segment is
fully write-witnessed in the slow tier (`ServeEngine.segment_resident`)
and pulls the ring window back up THROUGH the placement-table read path
(`ServeEngine.install_handoff`) — the daemon promotes the new request's
hot pages exactly like any slow-resident data.  The first output token is
emitted (TTFT stamped) at hand-off completion, from the final chunk's
last-position logits.  Outputs are bit-exact against the unified
scheduler: sampling keys derive from (seed, rid, token index) and the
chunked scan equals streaming, so the split changes WHERE work runs,
never what is computed.

Each pool accrues wall time on its own **virtual worker clock**
(``Scheduler.clock``): a worker's clock only advances while its own
engine/host work runs, so on a single host the decode clock measures
decode-lane latency the way a dedicated decode box would experience it —
hand-off install and gather costs included, the other worker's prefill
scans excluded.  The unified scheduler runs everything on the decode
clock, which is how a colocated deployment experiences a long prompt.

Per-tenant telemetry rides the same `TierStats` schema the daemon uses:
each step the scheduler looks the lanes' resident pages up in the KV
placement map and meters fast/slow reads per tenant, so tenant isolation is
observable in the same units as resource tiering (`benchmarks/
traffic_bench.py` emits both).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as dec
from repro.serve.engine import ServeEngine
from repro.tiering.daemon import split_quota
from repro.tiering.stats import TierStats


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One traffic source multiplexed onto the engine."""

    name: str
    weight: float = 1.0        # isolation weight in the lane/quota split


@dataclasses.dataclass
class SchedConfig:
    preempt_patience: int = 16   # steps a lane-less tenant waits before
    #                              its queue head may preempt someone
    max_queue: int = 4096        # hard bound on queued requests
    # Chunked prefill (DESIGN.md §11): a prefilling lane consumes up to
    # `prefill_chunk` prompt tokens per scheduler step through ONE jitted
    # scan (`ServeEngine.prefill_lane`) instead of one engine step per
    # token; decode lanes keep stepping between chunks.  0 = legacy
    # token-at-a-time streaming; prompts no longer than the chunk also
    # fall back to the streaming loop (bit-exact either way).
    prefill_chunk: int = 0
    # Disaggregation (DESIGN.md §13): > 0 reserves a DISJOINT pool of that
    # many prefill-worker lanes on an attached engine; the decode pool
    # keeps the owning engine's lanes.  Requests prefill chunk-by-chunk on
    # the prefill pool, hand off through the shared slow store, and decode
    # on the decode pool — requires prefill_chunk > 0 (the chunked scan is
    # the prefill worker's unit of work).  Size the KV slow store for both
    # pools: ServeConfig.kv_segments >= lanes + prefill_lanes, plus slack
    # for hand-offs in flight.  0 = unified scheduling (unchanged).
    prefill_lanes: int = 0
    # Sampling (models/decode.py::sample_tokens): temperature <= 0 is exact
    # argmax (the default — zero overhead); with temperature > 0 each
    # emitted token is drawn with a per-request PRNG key folded from
    # (seed, request id, tokens emitted), so a trace replays bit-identically
    # regardless of lane assignment, admission order, or preemptions.
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0                # sampling seed (seeded per trace)
    # Content-addressed admission matching (repro.cache, DESIGN.md §12),
    # active when the engine has a reuse pool (ServeConfig.reuse_pages):
    # "substring" verifies every full prompt page independently and skips
    # holes; "prefix" stops at the first miss (the vLLM-style baseline —
    # strictly a subset of substring, kept for the kv_reuse A/B).
    reuse_match: str = "substring"


@dataclasses.dataclass
class Request:
    """One request's lifecycle record (see module docstring).

    ``state`` walks: queued -> running -> finished in the unified
    scheduler (preempted in between on a starvation guard); the
    disaggregated scheduler inserts the hand-off leg — queued -> prefill
    (on a prefill-pool lane) -> handoff (detached, waiting for slow-tier
    residency + a decode lane) -> running (decode pool) -> finished."""

    rid: int
    tenant: str
    prompt: np.ndarray           # (P,) int32 prompt tokens
    max_new: int                 # output tokens to generate
    arrival_step: int = 0
    state: str = "queued"  # queued | prefill | handoff | running | preempted
    #                        | finished
    lane: int = -1               # pool-local lane index (state names the pool)
    segment: int = -1            # KV slow-store segment (kept while preempted)
    pos: int = 0                 # tokens consumed so far (prompt + generated)
    out: list = dataclasses.field(default_factory=list)
    residual: dict | None = None  # preemption/hand-off snapshot (engine)
    queued_since: int = 0
    admitted_step: int = -1
    finished_step: int = -1
    preemptions: int = 0
    arrival_time: float = 0.0
    token_times: list = dataclasses.field(default_factory=list)
    # per-token worker-clock stamps + the step each token was emitted on:
    # the disagg A/B classifies decode gaps by what the prefill worker was
    # doing between the two stamps (benchmarks/traffic_bench.py)
    token_clock: list = dataclasses.field(default_factory=list)
    token_steps: list = dataclasses.field(default_factory=list)
    key: np.ndarray | None = None  # per-request PRNG key (sampling mode)
    # admission-matched shared pages not yet installed: local page -> pool
    # gid (install consumes runs as prefill reaches them)
    matched: dict = dataclasses.field(default_factory=dict)
    # every pool gid this request holds a reference on (released at finish;
    # survives preemption — the claim belongs to the request, not the lane)
    shared_gids: list = dataclasses.field(default_factory=list)

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefilling(self) -> bool:
        return self.pos < self.n_prompt


class Scheduler:
    """Multiplexes tenants' requests onto one ServeEngine/NeoMemDaemon."""

    def __init__(self, engine: ServeEngine, tenants: list[Tenant],
                 scfg: SchedConfig | None = None):
        if not engine.lane_mode:
            raise ValueError("Scheduler requires an engine with "
                             "ServeConfig.lanes > 0")
        if not tenants:
            raise ValueError("at least one tenant required")
        self.eng = engine
        self.tenants = {t.name: t for t in tenants}
        self.scfg = scfg or SchedConfig()
        self.n_lanes = engine.scfg.lanes
        n_seg = engine.scfg.kv_segments or self.n_lanes
        self.free_segments = list(range(n_seg))
        self.lanes: list[Request | None] = [None] * self.n_lanes
        self.queue: list[Request] = []      # arrival order (incl. preempted)
        self.finished: list[Request] = []
        self.step_count = 0
        self.preemptions = 0
        self.queued_peak = 0
        self._next_rid = 0
        self.tenant_stats = {t: TierStats(name=t) for t in self.tenants}
        self._sample_master = jax.random.PRNGKey(self.scfg.seed)
        # per-worker virtual clocks (module docstring): unified mode runs
        # everything on "decode"; disagg charges each pool's engine/host
        # work to its own worker
        self.clock = {"prefill": 0.0, "handoff": 0.0, "decode": 0.0}
        self._seg_role: str | None = None
        self._seg_t0 = 0.0
        # prefill_busy[s]: was a prefill in flight during step s?  (the
        # disagg A/B's gap classifier — maintained in both modes)
        self.prefill_busy: list[bool] = []
        # -- disaggregated pools (DESIGN.md §13) --
        self.disagg = self.scfg.prefill_lanes > 0
        self.handoff: list[Request] = []    # detached, awaiting decode admit
        self.handoffs = 0
        self.handoff_bytes_out = 0          # producer flush (prefill -> slow)
        self.handoff_bytes_in = 0           # consumer gather (slow -> decode)
        self.handoff_peak = 0
        if self.disagg:
            if self.scfg.prefill_chunk <= 0:
                raise ValueError(
                    "disaggregated scheduling (prefill_lanes > 0) requires "
                    "prefill_chunk > 0 — the chunked scan is the prefill "
                    "worker's unit of work (DESIGN.md §13)")
            pcfg = dataclasses.replace(engine.scfg,
                                       lanes=self.scfg.prefill_lanes)
            self.peng = ServeEngine(engine.cfg, engine.params, pcfg,
                                    ep_axes=engine.ep, attach_to=engine)
            self.pre_lanes: list[Request | None] = \
                [None] * self.scfg.prefill_lanes
        else:
            self.peng = None
            self.pre_lanes = []
        if engine.cache is None:
            engine.start_lanes()
        if self.peng is not None and self.peng.cache is None:
            self.peng.start_lanes()

    # -- request intake -------------------------------------------------------
    def submit(self, tenant: str, prompt: np.ndarray,
               max_new: int) -> Request:
        """Queue a request (the *arrive* stage).  Raises when the queue is
        at its bound — backpressure belongs to the caller, not silent drop."""
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        if len(self.queue) >= self.scfg.max_queue:
            raise RuntimeError(f"queue full ({self.scfg.max_queue})")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0 or max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if prompt.size + max_new > self.eng.scfg.max_seq:
            raise ValueError(
                f"request length {prompt.size}+{max_new} exceeds the "
                f"max_seq={self.eng.scfg.max_seq} KV segment")
        req = Request(rid=self._next_rid, tenant=tenant, prompt=prompt,
                      max_new=max_new, arrival_step=self.step_count,
                      queued_since=self.step_count,
                      arrival_time=time.perf_counter())
        if self.scfg.temperature > 0.0:
            # identity-derived key: (seed, rid) — lane/preemption-invariant
            req.key = np.asarray(
                jax.random.fold_in(self._sample_master, req.rid))
        self._next_rid += 1
        self.queue.append(req)
        self.queued_peak = max(self.queued_peak, len(self.queue))
        return req

    # -- worker clocks --------------------------------------------------------
    def _enter(self, role: str) -> None:
        """Start charging wall time to ``role``'s virtual clock."""
        self._close_seg()
        self._seg_role, self._seg_t0 = role, time.perf_counter()

    def _close_seg(self) -> None:
        if self._seg_role is not None:
            self.clock[self._seg_role] += time.perf_counter() - self._seg_t0
            self._seg_role = None

    def _now(self, role: str) -> float:
        """``role``'s virtual clock reading, mid-segment included."""
        t = self.clock[role]
        if self._seg_role == role:
            t += time.perf_counter() - self._seg_t0
        return t

    # -- admission / preemption ----------------------------------------------
    def _pool(self, role: str) -> tuple[ServeEngine, list]:
        if role == "prefill":
            return self.peng, self.pre_lanes
        return self.eng, self.lanes

    def _running_by_tenant(self, lanes: list) -> dict[str, int]:
        counts = {t: 0 for t in self.tenants}
        for r in lanes:
            if r is not None:
                counts[r.tenant] += 1
        return counts

    def _candidates(self, role: str) -> list[Request]:
        """Admissible requests for a pool, in service order.

        Unified mode: the whole queue competes for the decode pool.  Disagg
        prefill pool: fresh arrivals and mid-prefill preemptions, queue
        (arrival) order.  Disagg decode pool: hand-offs whose segment has
        become fully slow-tier resident (the fabric admission gate) plus
        decode-phase preemptions, oldest wait first."""
        if not self.disagg:
            return list(self.queue)
        if role == "prefill":
            return [r for r in self.queue
                    if r.state == "queued"
                    or (r.state == "preempted" and r.prefilling)]
        ready = [r for r in self.handoff
                 if self.eng.segment_resident(r.residual)]
        ready += [r for r in self.queue
                  if r.state == "preempted" and not r.prefilling]
        return sorted(ready, key=lambda r: (r.queued_since, r.rid))

    def _lane_shares(self, role: str, cands: list[Request]) -> dict[str, int]:
        """Target lane allocation per tenant for one pool: the daemon's
        quota split applied to lanes — demand = running + waiting,
        weighted, clamped."""
        _, lanes = self._pool(role)
        n_pool = len(lanes)
        demands = self._running_by_tenant(lanes)
        for r in cands:
            demands[r.tenant] += 1
        caps = {t: n_pool for t in self.tenants}
        weights = {t: self.tenants[t].weight for t in self.tenants}
        return split_quota(n_pool, demands, caps, weights)

    def _admit_pool(self, role: str) -> None:
        _, lanes = self._pool(role)
        if self._candidates(role):
            self._maybe_preempt(role)
        free = [ln for ln, r in enumerate(lanes) if r is None]
        while free:
            cands = self._candidates(role)
            if not cands:
                break
            shares = self._lane_shares(role, cands)
            running = self._running_by_tenant(lanes)
            heads: dict[str, Request] = {}
            for r in cands:                  # service order: first is head
                heads.setdefault(r.tenant, r)
            # the waiting tenant with the largest share deficit wins the
            # lane; deficit <= 0 everywhere falls back to FIFO
            pick = max(heads.values(),
                       key=lambda r: (shares.get(r.tenant, 0)
                                      - running[r.tenant],
                                      -r.queued_since, -r.rid))
            if shares.get(pick.tenant, 0) - running[pick.tenant] <= 0:
                pick = cands[0]
            if not self._install(pick, free[0], role):
                # no free KV segment for a fresh request — a preempted one
                # (which kept its segment) can still take the lane
                pre = next((r for r in cands
                            if r.state == "preempted"), None)
                if pre is None or not self._install(pre, free[0], role):
                    break
            free.pop(0)

    def _install(self, req: Request, lane: int, role: str = "decode") -> bool:
        eng, lanes = self._pool(role)
        if req.state == "handoff":
            # decode-side hand-off completion (DESIGN.md §13): pull the
            # ring window up through the placement table, then emit the
            # first output token from the final chunk's logits — TTFT is
            # stamped HERE, when the hand-off completes
            residual = req.residual
            logits_row = residual.pop("logits")
            # the gather itself is the fabric transfer (CXL port / DMA
            # engine), charged to its own clock: the decode worker's clock
            # keeps only what decode actually executes — the placement-
            # table slow-tier pulls during advance — so hand-off traffic
            # shows up in clock.handoff_s and bytes_in, not as fake TPOT
            self._enter("handoff")
            self.handoff_bytes_in += eng.install_handoff(lane, residual)
            self._enter("decode")
            req.residual = None
            self.handoff.remove(req)
            req.state, req.lane = "running", lane
            lanes[lane] = req
            self._emit(req, logits_row)
            return True
        if req.state == "preempted":
            eng.resume_lane(lane, req.residual)
            req.residual = None
        else:
            if not self.free_segments:
                return False
            req.segment = self.free_segments.pop(0)
            req.admitted_step = self.step_count
            eng.reset_lane(lane)
            if eng.reuse is not None:
                # content-addressed admission matching (DESIGN.md §12):
                # matched pages install as prefill reaches them, so the
                # lane only scans the unmatched gaps; the match acquires
                # one reference per page, released when the request ends
                res = eng.reuse.match(req.prompt,
                                      mode=self.scfg.reuse_match)
                req.matched = dict(res.pages)
                req.shared_gids = list(res.pages.values())
        req.state = "prefill" if role == "prefill" else "running"
        req.lane = lane
        lanes[lane] = req
        self.queue.remove(req)
        return True

    def _maybe_preempt(self, role: str = "decode") -> None:
        """Per-pool starvation guard: one preemption per step, only for a
        tenant that holds NO lane in this pool and whose waiting head has
        out-waited the patience.  On the prefill pool the victim is mid-
        prefill — its chunk boundary is the preemption point."""
        _, lanes = self._pool(role)
        if any(r is None for r in lanes):
            return                            # a free lane serves them first
        running = self._running_by_tenant(lanes)
        starving = None
        for r in self._candidates(role):      # service order
            waited = self.step_count - r.queued_since
            if running[r.tenant] == 0 and waited >= self.scfg.preempt_patience:
                starving = r
                break
        if starving is None:
            return
        if starving.state == "queued" and not self.free_segments:
            return                            # nowhere to hold its KV yet
        # victim tenant: most over-served per unit weight; victim request:
        # its youngest admission (least sunk work discarded)
        cands = [t for t, n in running.items()
                 if n > 0 and t != starving.tenant]
        if not cands:
            return
        # a zero-weight tenant holding lanes is infinitely over-served
        victim_t = max(cands,
                       key=lambda t: running[t] / max(self.tenants[t].weight,
                                                      1e-9))
        victim = max((r for r in lanes
                      if r is not None and r.tenant == victim_t),
                     key=lambda r: r.admitted_step)
        lane = victim.lane
        self._preempt(victim)
        # the freed lane goes to the starving head DIRECTLY — handing it to
        # the weighted-fair pick would return it to the hog and thrash
        self._install(starving, lane, role)

    def _preempt(self, req: Request) -> None:
        eng, lanes = (self.peng, self.pre_lanes) if req.state == "prefill" \
            else (self.eng, self.lanes)
        lane = req.lane
        req.residual = eng.preempt_lane(lane)
        lanes[lane] = None
        req.state, req.lane = "preempted", -1
        req.queued_since = self.step_count
        req.preemptions += 1
        self.preemptions += 1
        self.queue.append(req)
        self.queued_peak = max(self.queued_peak, len(self.queue))

    def _to_handoff(self, lane: int, req: Request,
                    logits_row: np.ndarray) -> None:
        """Producer-side hand-off: detach a finished prefill from its lane
        (force-flushing its pages down the fabric) and queue it for decode
        admission, final-chunk logits riding along for the first token."""
        residual = self.peng.handoff_lane(lane)
        self.handoff_bytes_out += residual.pop("handoff_bytes")
        residual["logits"] = logits_row
        self.handoffs += 1
        self.pre_lanes[lane] = None
        req.residual = residual
        req.state, req.lane = "handoff", -1
        req.queued_since = self.step_count   # now waiting on the decode pool
        self.handoff.append(req)
        self.handoff_peak = max(self.handoff_peak, len(self.handoff))

    def _finish(self, req: Request) -> None:
        if self.eng.reuse is not None:
            # publish BEFORE the segment is recycled (the pool copy sources
            # from it), then drop this request's claims on shared pages
            stream = (np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)])
                if len(req.out) > 1 else req.prompt)
            self.eng.publish_lane(req.lane, stream)
            if req.shared_gids:
                self.eng.reuse.release(req.shared_gids)
                req.shared_gids = []
        self.lanes[req.lane] = None
        self.free_segments.append(req.segment)
        req.state, req.lane = "finished", -1
        req.finished_step = self.step_count
        self.finished.append(req)

    # -- token emission -------------------------------------------------------
    def _emit(self, req: Request, logits_row: np.ndarray) -> None:
        """Emit one output token for ``req`` outside the batched decode
        sweep (the hand-off first token): same identity-derived key fold,
        so the draw is bit-identical to the unified scheduler's."""
        req.out.append(self._sample_one(req, logits_row))
        req.token_times.append(time.perf_counter())
        req.token_clock.append(self._now("decode"))
        req.token_steps.append(self.step_count)
        if len(req.out) >= req.max_new:
            self._finish(req)

    def _sample_one(self, req: Request, logits_row: np.ndarray) -> int:
        row = np.asarray(logits_row, np.float32)
        if self.scfg.temperature <= 0.0:
            return int(np.argmax(row))
        folded = dec.fold_lane_keys(
            jnp.asarray(req.key[None, :]),
            jnp.asarray([len(req.out)], jnp.uint32))
        return int(np.asarray(dec.sample_tokens(
            jnp.asarray(row[None]), folded,
            temperature=self.scfg.temperature, top_p=self.scfg.top_p))[0])

    # -- the serving loop -----------------------------------------------------
    def step(self) -> None:
        """One scheduler iteration.

        Unified mode: admit, advance every lane (one decode token, or one
        prefill CHUNK for long-prompt admissions), sample/finish, meter
        per-tenant tier stats.  With ``SchedConfig.prefill_chunk > 0`` a
        prefilling request whose prompt is longer than one chunk goes
        through the chunked path: its lane consumes up to ``prefill_chunk``
        prompt tokens via ``ServeEngine.prefill_lane`` while the other
        lanes take their normal decode step — no stop-the-world.  The first
        output token is emitted (and its TTFT stamped) the step the LAST
        chunk lands, from the same last-prompt-position logits the
        streaming path would produce.

        Disaggregated mode (``prefill_lanes > 0``): decode-side hand-off
        admission, then the prefill worker's turn (one chunk or matched
        install per busy prefill lane) on the prefill clock, then the
        decode worker's turn (one batched decode step over the decode
        lanes) on the decode clock."""
        self._enter("decode")
        try:
            if self.disagg:
                self._step_disagg()
            else:
                self._step_unified()
        finally:
            self._close_seg()

    def _step_disagg(self) -> None:
        self._admit_pool("decode")           # hand-offs may emit first tokens
        self._enter("prefill")
        self._admit_pool("prefill")
        self.prefill_busy.append(any(r is not None for r in self.pre_lanes))
        self._prefill_turn()
        self._enter("decode")
        self._decode_turn()
        self.step_count += 1

    def _prefill_turn(self) -> None:
        """The prefill worker's step: each busy prefill lane consumes one
        matched-page install OR one chunk scan; a lane whose last chunk
        lands detaches its request into the hand-off queue."""
        chunk = self.scfg.prefill_chunk
        page_t = self.eng.scfg.page_t
        for lane, req in enumerate(list(self.pre_lanes)):
            if req is None:
                continue
            if req.matched:
                # content-addressed fast-forward (DESIGN.md §12) — cannot
                # complete the prompt (the final page is never matchable),
                # so the hand-off always ends on a real chunk scan
                j = req.pos // page_t
                if req.pos % page_t == 0 and j in req.matched:
                    run: dict[int, int] = {}
                    while j in req.matched:
                        run[j] = req.matched.pop(j)
                        j += 1
                    fast_n, slow_n = self.peng.install_lane_pages(lane, run)
                    st = self.tenant_stats[req.tenant]
                    st.fast_reads += fast_n
                    st.slow_reads += slow_n
                    req.pos += len(run) * page_t
                    continue
            end = req.pos + chunk
            gap = min((jj * page_t for jj in req.matched
                       if jj * page_t >= req.pos), default=end)
            piece = req.prompt[req.pos:min(end, gap)]
            logits = self.peng.prefill_lane(lane, piece, req.segment,
                                            chunk=chunk)
            req.pos += int(piece.size)
            if not req.prefilling:
                self._to_handoff(lane, req, np.asarray(logits))
        if any(r is not None for r in self.pre_lanes):
            self._meter_pool(self.peng, self.pre_lanes)

    def _decode_turn(self) -> None:
        """The decode worker's step: one batched engine step over the
        decode lanes (every occupant is past its prompt — hand-off
        admission emitted the first token already)."""
        tokens = np.zeros(self.n_lanes, np.int32)
        active = np.zeros(self.n_lanes, bool)
        segments = np.full(self.n_lanes, -1, np.int32)
        for lane, req in enumerate(self.lanes):
            if req is None:
                continue
            segments[lane] = req.segment
            active[lane] = True
            tokens[lane] = req.out[-1]
        if not active.any():
            return
        logits = np.asarray(
            self.eng.advance_lanes(tokens, active, segments)
        ).astype(np.float32)
        self._meter_pool(self.eng, self.lanes)
        now = time.perf_counter()
        clock_now = self._now("decode")
        sampled = self._sample(logits, active.astype(np.int32))
        for lane, req in enumerate(list(self.lanes)):
            if req is None:
                continue
            req.pos += 1
            tok = (int(sampled[lane]) if sampled is not None
                   else int(np.argmax(logits[lane])))
            req.out.append(tok)
            req.token_times.append(now)
            req.token_clock.append(clock_now)
            req.token_steps.append(self.step_count)
            if len(req.out) >= req.max_new:
                self._finish(req)

    def _step_unified(self) -> None:
        self._admit_pool("decode")
        chunk = self.scfg.prefill_chunk
        # a step is prefill-busy when a lane is mid-CHUNKED-prefill: its
        # chunk scan (or matched install) is the serialized host wall that
        # delays the batched decode.  Streaming prefill rides the decode
        # batch itself and stalls nobody, so it does not count.
        self.prefill_busy.append(any(
            r is not None and r.prefilling and chunk > 0
            and r.n_prompt > chunk for r in self.lanes))
        tokens = np.zeros(self.n_lanes, np.int32)
        active = np.zeros(self.n_lanes, bool)
        segments = np.full(self.n_lanes, -1, np.int32)
        consumed = np.zeros(self.n_lanes, np.int32)
        chunk_logits: dict[int, np.ndarray] = {}
        page_t = self.eng.scfg.page_t
        for lane, req in enumerate(self.lanes):
            if req is None:
                continue
            segments[lane] = req.segment
            if req.prefilling and req.matched:
                # content-addressed fast-forward (DESIGN.md §12): when the
                # page at the lane position is matched, install the whole
                # consecutive run from the shared pool — no forward pass —
                # and charge the pool reads to the admitting tenant
                j = req.pos // page_t
                if req.pos % page_t == 0 and j in req.matched:
                    run: dict[int, int] = {}
                    while j in req.matched:
                        run[j] = req.matched.pop(j)
                        j += 1
                    fast_n, slow_n = self.eng.install_lane_pages(lane, run)
                    st = self.tenant_stats[req.tenant]
                    st.fast_reads += fast_n
                    st.slow_reads += slow_n
                    consumed[lane] = len(run) * page_t
                    continue
            if chunk > 0 and req.prefilling and req.n_prompt > chunk:
                # a chunk scan must stop at the next matched page — scanning
                # past it would recompute what the pool already holds
                end = req.pos + chunk
                gap = min((jj * page_t for jj in req.matched
                           if jj * page_t >= req.pos), default=end)
                piece = req.prompt[req.pos:min(end, gap)]
                chunk_logits[lane] = self.eng.prefill_lane(
                    lane, piece, req.segment, chunk=chunk)
                consumed[lane] = piece.size
                continue
            active[lane] = True
            consumed[lane] = 1
            tokens[lane] = (req.prompt[req.pos] if req.prefilling
                            else req.out[-1])
        if not (active.any() or chunk_logits):
            # install-only step (or nothing to do): no engine step ran and
            # no lane can emit — just advance the fast-forwarded positions
            for lane, req in enumerate(self.lanes):
                if req is not None and consumed[lane]:
                    req.pos += int(consumed[lane])
            self.step_count += 1
            return
        logits = (self.eng.advance_lanes(tokens, active, segments)
                  if active.any() else None)
        if logits is None:
            logits = np.zeros(
                (self.n_lanes, next(iter(chunk_logits.values())).shape[-1]),
                np.float32)
        else:
            logits = np.asarray(logits).astype(np.float32)
        for lane, row in chunk_logits.items():
            logits[lane] = row
        # meter BEFORE the finish sweep (each request's final step of
        # resident-page reads must still be charged to its tenant)
        self._meter_tenants()
        now = time.perf_counter()
        clock_now = self._now("decode")
        sampled = self._sample(logits, consumed)
        for lane, req in enumerate(list(self.lanes)):
            if req is None or consumed[lane] == 0:
                continue
            req.pos += int(consumed[lane])
            if not req.prefilling:           # last prompt token or decoding
                tok = (int(sampled[lane]) if sampled is not None
                       else int(np.argmax(logits[lane])))
                req.out.append(tok)
                req.token_times.append(now)
                req.token_clock.append(clock_now)
                req.token_steps.append(self.step_count)
                if len(req.out) >= req.max_new:
                    self._finish(req)
        self.step_count += 1

    def _sample(self, logits: np.ndarray,
                consumed: np.ndarray) -> np.ndarray | None:
        """Batched lane sampling (None in greedy mode -> argmax fallback).

        One jitted :func:`models.decode.sample_tokens` call covers every
        lane that emits this step; each lane's key is its request's
        identity key folded with the emitted-token index, so the draw
        stream is a pure function of (seed, rid, token index) — chunked
        and streamed prefill sample identically."""
        if self.scfg.temperature <= 0.0:
            return None
        keys = np.zeros((self.n_lanes, 2), np.uint32)
        idx = np.zeros(self.n_lanes, np.uint32)
        emitting = False
        for lane, req in enumerate(self.lanes):
            if req is None or consumed[lane] == 0 \
                    or req.pos + consumed[lane] < req.n_prompt:
                continue                      # still prefilling this step
            keys[lane] = req.key
            idx[lane] = len(req.out)
            emitting = True
        if not emitting:
            return None
        folded = dec.fold_lane_keys(jnp.asarray(keys), jnp.asarray(idx))
        return np.asarray(dec.sample_tokens(
            jnp.asarray(logits), folded,
            temperature=self.scfg.temperature, top_p=self.scfg.top_p))

    @property
    def active(self) -> bool:
        """Any request still in flight (queued, pooled, or in hand-off)?"""
        return bool(self.queue or self.handoff
                    or any(r is not None for r in self.lanes)
                    or any(r is not None for r in self.pre_lanes))

    def run(self, max_steps: int = 10_000) -> None:
        """Drain: run until every submitted request finished (or the bound)."""
        while self.active:
            if self.step_count >= max_steps:
                raise RuntimeError(f"undrained after {max_steps} steps")
            self.step()

    # -- telemetry ------------------------------------------------------------
    def _meter_tenants(self) -> None:
        self._meter_pool(self.eng, self.lanes)

    def _meter_pool(self, eng: ServeEngine, lanes: list) -> None:
        """Account each lane's resident KV pages against its tenant: a page
        the placement map holds fast is a per-tenant fast read.  Runs BEFORE
        the finish sweep over the explicit occupancy mask, so a finishing
        request's final step — and a chunk-prefilling lane the engine's own
        active mask no longer carries — is still charged."""
        if eng is None or "kv" not in eng.daemon:
            return
        occupied = np.array([r is not None for r in lanes], bool)
        sv = eng._kv_lane_stream(active=occupied)
        if sv is None:
            return
        _, gids = sv
        h = eng.daemon["kv"]
        _, hit = h.lookup(jnp.asarray(gids.reshape(-1), jnp.int32))
        hit = np.asarray(hit).reshape(gids.shape)
        valid = gids >= 0
        for lane, req in enumerate(lanes):
            if req is None:
                continue
            st = self.tenant_stats[req.tenant]
            f = int(np.sum(hit[lane] & valid[lane]))
            st.fast_reads += f
            st.slow_reads += int(np.sum(valid[lane])) - f

    @staticmethod
    def _pct_row(gaps) -> dict:
        if not len(gaps):
            return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
        g = np.asarray(gaps) * 1e3
        return {"p50": float(np.percentile(g, 50)),
                "p99": float(np.percentile(g, 99)),
                "mean": float(np.mean(g)), "n": int(g.size)}

    @classmethod
    def _latency_rows(cls, reqs: list[Request]) -> dict:
        """Split latency schema: ``ttft_ms`` (arrival -> first emitted token)
        and ``tpot_ms`` (gaps between a request's consecutive output tokens)
        are DIFFERENT distributions — folding them together makes the
        "per-token p99" just TTFT in disguise.  (The combined ``latency_ms``
        row served its one-release deprecation and is gone.)"""
        ttft, tpot = [], []
        for r in reqs:
            if r.token_times:
                ttft.append(r.token_times[0] - r.arrival_time)
                tpot.extend(np.diff(r.token_times))
        return {"ttft_ms": cls._pct_row(ttft),
                "tpot_ms": cls._pct_row(tpot)}

    def report(self) -> dict:
        """The traffic-bench schema row for this run (BENCH_serve.json)."""
        done = self.finished
        tenants = {}
        for name, ten in self.tenants.items():
            reqs = [r for r in done if r.tenant == name]
            st = self.tenant_stats[name]
            total = st.fast_reads + st.slow_reads
            tenants[name] = {
                "weight": ten.weight,
                "completed": len(reqs),
                "tokens": sum(len(r.out) for r in reqs),
                "kv_hit_rate": st.fast_reads / max(total, 1),
                **self._latency_rows(reqs),
            }
        return {
            "steps": self.step_count,
            "submitted": self._next_rid,
            "completed": len(done),
            "tokens": sum(len(r.out) for r in done),
            "preemptions": self.preemptions,
            "queued_peak": self.queued_peak,
            "mode": "disagg" if self.disagg else "unified",
            "prefill_lanes": self.scfg.prefill_lanes,
            "clock": {"prefill_s": self.clock["prefill"],
                      "handoff_s": self.clock["handoff"],
                      "decode_s": self.clock["decode"]},
            "handoff": {"count": self.handoffs,
                        "bytes_out": self.handoff_bytes_out,
                        "bytes_in": self.handoff_bytes_in,
                        "depth_peak": self.handoff_peak},
            **self._latency_rows(done),
            "tenants": tenants,
            "resources": self.eng.tier_stats(),
        }
