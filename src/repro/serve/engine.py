"""Serving engine: batched prefill + decode with NeoMem-tiered KV/experts.

ServeEngine drives a small continuous-batching loop on top of the
models.decode steps:

  * prefill(tokens)           — full-sequence forward, returns first token +
                                dense cache (short contexts), or seeds the
                                paged fast tier (long contexts);
  * step()                    — one decode step for the active batch;
  * NeoMem integration        — per migration_interval the KVTier / Expert-
                                Cache daemons promote sketch-hot pages into
                                the fast tier between steps (never inside
                                the jitted hot path).

This is the substrate behind examples/serve_longctx.py and the serving
benchmarks; the dry-run lowers the same step functions at production shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adapters.kv_tier import KVTier, KVTierConfig
from repro.models import decode as dec
from repro.models import transformer as tr


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 4096
    page_t: int = 64
    hot_slots: int = 16
    paged: bool = False
    migration_interval: int = 8     # decode steps between daemon ticks


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig,
                 ep_axes=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.ep = ep_axes
        self.kv_tier: KVTier | None = None
        if scfg.paged:
            self.kv_tier = KVTier(KVTierConfig(
                n_pages_total=scfg.max_seq // scfg.page_t,
                hot_slots=scfg.hot_slots))
        self._decode = jax.jit(self._decode_fn)
        self._decode_paged = jax.jit(self._decode_paged_fn)
        self.cache = None
        self.step_count = 0

    # -- jitted step bodies -------------------------------------------------
    def _decode_fn(self, params, cache, token, aux):
        return dec.decode_step(self.cfg, params, cache, token,
                               aux_embeds=aux, ep_axes=self.ep)

    def _decode_paged_fn(self, params, cache, token):
        return dec.decode_step_paged(self.cfg, params, cache, token,
                                     page_t=self.scfg.page_t, ep_axes=self.ep)

    # -- public API -----------------------------------------------------------
    def prefill(self, tokens: np.ndarray, aux_embeds=None):
        b, s = tokens.shape
        self.aux = aux_embeds
        if self.cfg.encoder_layers and aux_embeds is not None:
            self.aux = tr.encode(self.cfg, self.params, aux_embeds)
        if self.scfg.paged:
            self.cache = dec.init_paged_cache(
                self.cfg, b, self.scfg.hot_slots, self.scfg.page_t)
            # seed by streaming the prompt through paged decode (keeps one
            # code path; production would bulk-write pages from prefill)
            last = None
            for t in range(s):
                last, self.cache = self._decode_paged(
                    self.params, self.cache, jnp.asarray(tokens[:, t:t + 1]))
                self._maybe_tick()
            return np.asarray(jnp.argmax(last[:, -1], -1))
        self.cache = dec.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, _ = dec.prefill(self.cfg, self.params, jnp.asarray(tokens),
                                aux_embeds=aux_embeds, ep_axes=self.ep)
        # replay tokens into the cache (single-sourced decode path)
        for t in range(s):
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(tokens[:, t:t + 1]),
                                         self.aux)
        return np.asarray(jnp.argmax(logits[:, -1], -1))

    def step(self, token: np.ndarray) -> np.ndarray:
        tok = jnp.asarray(token)[:, None]
        if self.scfg.paged:
            logits, self.cache = self._decode_paged(self.params, self.cache, tok)
        else:
            logits, self.cache = self._decode(self.params, self.cache, tok,
                                              self.aux)
        self._maybe_tick()
        return np.asarray(jnp.argmax(logits[:, -1], -1))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 aux_embeds=None) -> np.ndarray:
        nxt = self.prefill(prompt, aux_embeds)
        out = [nxt]
        for _ in range(n_tokens - 1):
            nxt = self.step(nxt)
            out.append(nxt)
        return np.stack(out, axis=1)

    # -- NeoMem daemon cadence --------------------------------------------------
    def _maybe_tick(self):
        self.step_count += 1
        if self.kv_tier is not None \
                and self.step_count % self.scfg.migration_interval == 0:
            self.kv_tier.tick()
