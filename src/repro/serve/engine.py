"""Serving engine: batched prefill + decode with NeoMem-tiered resources.

ServeEngine drives a small continuous-batching loop on top of the
models.decode steps:

  * prefill(tokens)           — full-sequence forward, returns first token +
                                dense cache (short contexts), or seeds the
                                paged fast tier (long contexts);
  * step()                    — one decode step for the active batch;
  * NeoMem integration        — ANY set of registered tiered resources
                                ("kv", "experts", "embeddings", or custom
                                registry kinds) multiplexed on ONE daemon:
                                per migration_interval the daemon promotes
                                sketch-hot pages for every resource under a
                                shared quota budget, between steps (never
                                inside the jitted hot path);
  * migration data plane      — each built-in resource binds REAL payload
                                (embedding-table pages, expert weight
                                blocks, flushed KV pages) to fast/slow
                                TierBuffers, so daemon epochs physically
                                move rows and meter bytes; ``read_rows``
                                serves lookups from the fast buffer with
                                slow-tier fallback (DESIGN.md §8).

Access streams fed per decode step (DESIGN.md §3): the token column
(embedding rows), the router's token->expert ids surfaced by
``decode_step(..., return_streams=True)`` (experts), and the resident
paged-KV window weighted by per-page fill (KV pages).

This is the substrate behind examples/serve_longctx.py and the serving
benchmarks; the dry-run lowers the same step functions at production shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import tiering as tm
from repro.configs.base import ArchConfig
from repro.models import decode as dec
from repro.models import transformer as tr


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 4096
    page_t: int = 64
    hot_slots: int = 16
    paged: bool = False
    migration_interval: int = 8     # decode steps between daemon ticks
    # Tiered resources to register ("kv" is implied by paged=True).
    resources: tuple[str, ...] = ()
    kv_quota: int = 64
    kv_mass_threshold: float = 0.02
    expert_hot_slots: int = 4       # HBM-resident experts per layer group
    expert_quota: int = 32
    embed_hot_slots: int = 64       # hot vocab row-blocks kept HBM-resident
    embed_quota: int = 64


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig,
                 ep_axes=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.ep = ep_axes
        self.daemon = tm.NeoMemDaemon()
        self._register_resources()
        self._want_streams = "experts" in self.daemon
        self._decode = jax.jit(self._decode_fn)
        self._decode_paged = jax.jit(self._decode_paged_fn)
        self.cache = None
        self.step_count = 0
        self._kv_flushed: dict[int, tuple[int, int]] = {}  # slot -> (id, fill)

    def _register_resources(self) -> None:
        cfg, scfg = self.cfg, self.scfg
        kinds = set(scfg.resources)
        if scfg.paged:
            kinds.add("kv")
        for kind in sorted(kinds):
            if kind == "kv":
                if not scfg.paged:
                    raise ValueError("the 'kv' resource requires paged=True")
                row_shape = self._kv_row_shape()
                spec = tm.ResourceSpec(
                    "kv", n_pages=scfg.max_seq // scfg.page_t,
                    hot_slots=scfg.hot_slots, quota_pages=scfg.kv_quota,
                    row_shape=row_shape, row_dtype="bfloat16")
                res = tm.make_resource(
                    "kv", spec, mass_threshold=scfg.kv_mass_threshold)
                # the slow tier starts empty: pages are flushed down from the
                # paged cache as decode fills them (_flush_kv_slow)
                payload = jnp.zeros((spec.n_pages,) + row_shape, jnp.bfloat16)
            elif kind == "experts":
                if cfg.moe is None or "moe" not in cfg.pattern:
                    raise ValueError(
                        f"arch {cfg.name!r} has no MoE layers to tier")
                payload = self._expert_payload()
                spec = tm.ResourceSpec(
                    "experts", n_pages=cfg.n_groups * cfg.moe.n_experts,
                    hot_slots=cfg.n_groups * scfg.expert_hot_slots,
                    quota_pages=scfg.expert_quota,
                    row_shape=tuple(payload.shape[1:]),
                    row_dtype=str(payload.dtype))
                res = tm.make_resource("experts", spec,
                                       n_experts=cfg.moe.n_experts)
            elif kind == "embeddings":
                rows = tm.EMBED_ROWS_PER_PAGE
                payload = self._embed_payload(rows)
                spec = tm.ResourceSpec(
                    "embeddings", n_pages=(cfg.vocab + rows - 1) // rows,
                    hot_slots=scfg.embed_hot_slots,
                    quota_pages=scfg.embed_quota,
                    row_shape=tuple(payload.shape[1:]),
                    row_dtype=str(payload.dtype))
                res = tm.make_resource("embeddings", spec)
            else:
                raise KeyError(f"unknown serve resource kind {kind!r}; "
                               f"known: {tm.resource_kinds()}")
            handle = self.daemon.register(res)
            handle.bind_data(payload)

    # -- payload construction (the migration data plane, DESIGN.md §8) -------
    def _kv_row_shape(self) -> tuple[int, ...]:
        """One logical KV page across all layer groups: K and V payloads of
        the representative paged-attention entry, concatenated on the last
        axis (MLA: latent + rope widths; GQA: 2 x head_dim)."""
        cfg = self.cfg
        if cfg.mla is not None:
            hkv, dk, dv = 1, cfg.mla.kv_lora + cfg.mla.d_rope, cfg.mla.kv_lora
        else:
            hkv, dk, dv = cfg.n_kv_heads, cfg.head_dim, cfg.head_dim
        return (cfg.n_groups, self.scfg.page_t, hkv, dk + dv)

    def _expert_payload(self) -> jax.Array:
        """(G*E, flat) expert weight blocks, page_id = group*n_experts+expert.

        Uses the first MoE position in the layer pattern as the weight block
        (one representative block per expert; per-position payloads would
        multiply the slow tier by the MoE depth without changing placement).
        """
        i = self.cfg.pattern.index("moe")
        ffn = self.params["blocks"][i]["ffn"]
        g, e = ffn["w_in"].shape[:2]
        parts = [ffn[k].reshape(g * e, -1) for k in ("w_gate", "w_in", "w_out")]
        return jnp.concatenate(parts, axis=-1)

    def _embed_payload(self, rows_per_page: int) -> jax.Array:
        """(n_pages, rows_per_page, d) vocab row-blocks of the live table."""
        table = self.params["embed"]["table"]
        v, d = table.shape
        n_pages = (v + rows_per_page - 1) // rows_per_page
        pad = n_pages * rows_per_page - v
        if pad:
            table = jnp.concatenate(
                [table, jnp.zeros((pad, d), table.dtype)], axis=0)
        return table.reshape(n_pages, rows_per_page, d)

    # -- jitted step bodies -------------------------------------------------
    def _decode_fn(self, params, cache, token, aux):
        return dec.decode_step(self.cfg, params, cache, token,
                               aux_embeds=aux, ep_axes=self.ep,
                               return_streams=self._want_streams)

    def _decode_paged_fn(self, params, cache, token):
        return dec.decode_step_paged(self.cfg, params, cache, token,
                                     page_t=self.scfg.page_t, ep_axes=self.ep,
                                     return_streams=self._want_streams)

    # -- public API -----------------------------------------------------------
    def prefill(self, tokens: np.ndarray, aux_embeds=None):
        b, s = tokens.shape
        self.aux = aux_embeds
        if self.cfg.encoder_layers and aux_embeds is not None:
            self.aux = tr.encode(self.cfg, self.params, aux_embeds)
        if self.scfg.paged:
            self.cache = dec.init_paged_cache(
                self.cfg, b, self.scfg.hot_slots, self.scfg.page_t)
            self._kv_flushed.clear()         # fresh ring: re-flush everything
            # seed by streaming the prompt through paged decode (keeps one
            # code path; production would bulk-write pages from prefill)
            logits = None
            for t in range(s):
                logits = self._advance(jnp.asarray(tokens[:, t:t + 1]))
            return np.asarray(jnp.argmax(logits[:, -1], -1))
        self.cache = dec.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, _ = dec.prefill(self.cfg, self.params, jnp.asarray(tokens),
                                aux_embeds=aux_embeds, ep_axes=self.ep)
        # replay tokens into the cache (single-sourced decode path)
        for t in range(s):
            self._advance(jnp.asarray(tokens[:, t:t + 1]))
        return np.asarray(jnp.argmax(logits[:, -1], -1))

    def step(self, token: np.ndarray) -> np.ndarray:
        logits = self._advance(jnp.asarray(token)[:, None])
        return np.asarray(jnp.argmax(logits[:, -1], -1))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 aux_embeds=None) -> np.ndarray:
        nxt = self.prefill(prompt, aux_embeds)
        out = [nxt]
        for _ in range(n_tokens - 1):
            nxt = self.step(nxt)
            out.append(nxt)
        return np.stack(out, axis=1)

    # -- decode + NeoMem observation/cadence ----------------------------------
    def _advance(self, tok: jax.Array):
        """One decode step: run the jitted body, feed the tiering streams,
        tick the multiplexed daemon on its cadence."""
        if self.scfg.paged:
            out = self._decode_paged(self.params, self.cache, tok)
        else:
            out = self._decode(self.params, self.cache, tok, self.aux)
        if self._want_streams:
            logits, self.cache, streams = out
        else:
            (logits, self.cache), streams = out, {}
        self._observe(tok, streams)
        self._maybe_tick()
        return logits

    def _observe(self, tok: jax.Array, streams: dict) -> None:
        if "embeddings" in self.daemon:
            self.daemon.observe("embeddings", tok)
        if "experts" in self.daemon and streams.get("router") is not None:
            self.daemon.observe("experts", streams["router"])
        if "kv" in self.daemon:
            mass, ids = self._kv_page_stream()
            if ids.size:
                self.daemon.observe("kv", mass, ids)

    def _kv_page_stream(self) -> tuple[jax.Array, jax.Array]:
        """Resident paged-KV window as (per-page mass, logical page ids).

        The paged cache is a ring of hot slots; per-page fill (page_len)
        stands in for attention mass — full pages carry proportionally more
        softmax mass on average.  Group 0 / batch row 0 is representative:
        all rows advance in lockstep (one appended token per step)."""
        entry = next((c for c in self.cache["blocks"]
                      if isinstance(c, dict) and "page_len" in c), None)
        if entry is None:
            return jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32)
        plen = np.asarray(entry["page_len"])[0, 0]           # (n_slots,)
        cur = int(np.asarray(entry["cur_slot"])[0, 0])
        n_slots = plen.shape[0]
        # cur_slot advances eagerly when a page fills, so the page being
        # filled at cur is always floor(pos / page_t) — also on boundaries.
        cur_page = int(self.cache["pos"]) // self.scfg.page_t
        slots = np.arange(n_slots)
        ids = cur_page - (cur - slots) % n_slots
        ids = np.where((plen > 0) & (ids >= 0), ids, -1)
        return jnp.asarray(plen, jnp.float32), jnp.asarray(ids, jnp.int32)

    def _flush_kv_slow(self) -> None:
        """Flush the resident paged-cache window down to the KV data plane.

        The ring of hot page slots is the authoritative copy of recent pages
        (DESIGN.md §3.2); before each daemon epoch the engine writes their
        payloads through ``write_rows`` — slow store always, plus the fast
        copies of promoted pages so neither reads nor demotion write-backs
        ever serve a stale snapshot.  Ring pages unchanged since the last
        flush (same page id, same fill) are skipped, and the flushed bytes
        are metered as ``flush_bytes``.  Batch row 0 is the representative
        payload, matching the mass proxy in _kv_page_stream.
        """
        h = self.daemon["kv"]
        if h.mem.buffers is None:
            return
        entry = next((c for c in self.cache["blocks"]
                      if isinstance(c, dict) and "page_len" in c), None)
        if entry is None:
            return
        mass, ids = self._kv_page_stream()
        if not ids.size:
            return
        ids = np.asarray(ids)
        fill = np.asarray(mass, np.int64)            # per-slot page_len
        changed = np.array([
            self._kv_flushed.get(slot) != (int(ids[slot]), int(fill[slot]))
            for slot in range(ids.shape[0])])
        ids = np.where(changed, ids, -1)             # -1 lanes are dropped
        if not (ids >= 0).any():
            return
        # (G, n_slots, T, hkv, dk+dv) -> slot-major rows for write_rows
        pages = jnp.concatenate(
            [entry["k_pages"][:, 0], entry["v_pages"][:, 0]], axis=-1)
        h.write_rows(ids, jnp.moveaxis(pages, 1, 0))
        for slot in np.flatnonzero(ids >= 0):
            self._kv_flushed[slot] = (int(ids[slot]), int(fill[slot]))

    def read_rows(self, name: str, page_ids) -> jax.Array:
        """Serve payload rows for a resource: fast-tier copy when the page
        is resident, slow-tier fallback otherwise (bit-exact either way)."""
        return self.daemon[name].read_rows(page_ids)

    def _maybe_tick(self) -> None:
        self.step_count += 1
        if self.daemon.resources \
                and self.step_count % self.scfg.migration_interval == 0:
            if "kv" in self.daemon:
                self._flush_kv_slow()
            self.daemon.tick()

    # -- telemetry ------------------------------------------------------------
    def tier_stats(self) -> dict[str, dict]:
        """Per-resource telemetry rows (the BENCH_serve.json schema)."""
        return self.daemon.snapshot()

    @property
    def kv_tier(self) -> tm.ResourceHandle | None:
        """Deprecated: the KV resource handle (None when not paged)."""
        return self.daemon["kv"] if "kv" in self.daemon else None
