"""Serving engine: batched prefill + decode with NeoMem-tiered resources.

ServeEngine drives a small continuous-batching loop on top of the
models.decode steps:

  * prefill(tokens)           — full-sequence forward, returns first token +
                                dense cache (short contexts), or seeds the
                                paged fast tier (long contexts);
  * step()                    — one decode step for the active batch;
  * NeoMem integration        — ANY set of registered tiered resources
                                ("kv", "experts", "embeddings", or custom
                                registry kinds) multiplexed on ONE daemon:
                                per migration_interval the daemon promotes
                                sketch-hot pages for every resource under a
                                shared quota budget, between steps (never
                                inside the jitted hot path);
  * migration data plane      — each built-in resource binds REAL payload
                                (embedding-table pages, expert weight
                                blocks, flushed KV pages) to fast/slow
                                TierBuffers, so daemon epochs physically
                                move rows and meter bytes; ``read_rows``
                                serves lookups from the fast buffer with
                                slow-tier fallback (DESIGN.md §8).

Access streams fed per decode step (DESIGN.md §3): the token column
(embedding rows), the router's token->expert ids surfaced by
``decode_step(..., return_streams=True)`` (experts), and the resident
paged-KV window weighted by the KERNEL-exported per-page softmax mass
(``streams["kv_mass"]``, DESIGN.md §10; ``ServeConfig.kv_mass_source=
"fill"`` keeps the old page-fill proxy as the A/B baseline).

In-jit tiered reads (DESIGN.md §10): the jitted decode step itself reads
embedding rows and the first MoE position's expert weight blocks THROUGH
the device-resident placement tables (``tiering.migrate.lookup_rows``) —
fast-buffer gather on residency, slow-store fallback in the same fused
gather, no host verb on the hot path.  The tier views are passed as jit
ARGUMENTS each step, so daemon epochs swap buffers without retracing.

Two serving modes share the machinery:

  * single-request (``prefill``/``step``/``generate``) — one batched
    prompt decoded lockstep, scalar position;
  * continuous-batching lanes (``ServeConfig.lanes > 0``; DESIGN.md §9) —
    the batch becomes independent decode *lanes* with per-lane positions,
    driven one token per lane per ``advance_lanes`` call by the request
    scheduler (serve/sched.py); the KV slow store is carved into
    per-request segments, lanes reset/preempt/resume mid-flight
    (``reset_lane``/``preempt_lane``/``resume_lane``, bit-exact), and
    ``save_tiering``/``load_tiering`` checkpoint the placement maps.

This is the substrate behind examples/serve_longctx.py and the serving
benchmarks; the dry-run lowers the same step functions at production shapes.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import tiering as tm
from repro.cache import KVReuseStore
from repro.configs.base import ArchConfig
from repro.models import decode as dec
from repro.models import transformer as tr
from repro.serve.clock import TickClock


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 4096
    page_t: int = 64
    hot_slots: int = 16
    paged: bool = False
    migration_interval: int = 8     # decode steps between daemon ticks
    # Tiered resources to register ("kv" is implied by paged=True).
    resources: tuple[str, ...] = ()
    kv_quota: int = 64
    kv_mass_threshold: float = 0.02
    expert_hot_slots: int = 4       # HBM-resident experts per layer group
    expert_quota: int = 32
    embed_hot_slots: int = 64       # hot vocab row-blocks kept HBM-resident
    embed_quota: int = 64
    embed_rows_per_page: int = 0    # vocab rows per page (0 -> package default)
    # Continuous-batching lane mode (serve/sched.py, DESIGN.md §9): the
    # engine batch becomes `lanes` independent decode lanes with per-lane
    # positions; the KV slow store is carved into `kv_segments` per-request
    # address spaces of max_seq//page_t pages each.
    lanes: int = 0                  # decode lanes (0 = single-request mode)
    kv_segments: int = 0            # slow-store KV segments (0 -> lanes)
    kv_tier_slots: int = 0          # kv fast-tier slots (0 -> hot_slots)
    # "kv" hotness stream source (DESIGN.md §10): "kernel" feeds the
    # flash-decode kernel's per-page softmax mass; "fill" keeps the old
    # host-computed page_len proxy (the A/B baseline for the fidelity gate).
    kv_mass_source: str = "kernel"
    # Bind embedding/expert reads of the jitted decode step to the tiered
    # store (in-jit lookup_rows; off = dense params, reads stay host-only).
    jit_tier_reads: bool = True
    # Slow-store wire format for every tiered resource (tiering/codec.py,
    # DESIGN.md §14): "none" = native rows (byte-exact data path), "fp32" =
    # full-precision store (the compression A/B's fp arm — numerically the
    # identity for bf16 rows), "int8" = per-row symmetric quantization
    # (~4x fewer wire bytes; reads dequantize in the fused tier gather).
    slow_codec: str = "none"
    # Content-addressed KV reuse (repro.cache, DESIGN.md §12): extra shared
    # pool pages appended to the KV slow store behind a refcounted index so
    # admission can install matched prompt pages pre-resident.  Lane mode
    # only; 0 = off.
    reuse_pages: int = 0
    # Asynchronous migration data plane (DESIGN.md §15): daemon epochs are
    # issued as non-blocking double-buffered copies and committed by pointer
    # swap at the NEXT tick — decode reads the previous committed epoch's
    # views (bit-exact, both tiers coherent) instead of stalling on the
    # fused copy.  Off = the synchronous stop-the-world plane.
    async_migration: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig,
                 ep_axes=None, attach_to: "ServeEngine | None" = None):
        """``attach_to`` builds a WORKER engine over another engine's tiered
        store (DESIGN.md §13): the daemon, every resource handle (placement
        maps + payload buffers) and the content-addressed reuse store are
        SHARED with ``attach_to`` — the two engines are two workers on one
        hand-off fabric.  The attached engine may differ in lane count but
        must match the owner's cache/store geometry exactly (its preemption
        residuals transplant onto the owner's lanes); it never ticks the
        shared daemon — migration cadence belongs to the owning engine."""
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.ep = ep_axes
        if scfg.lanes and not scfg.paged:
            raise ValueError("lane mode (ServeConfig.lanes) requires paged=True")
        if scfg.kv_mass_source not in ("kernel", "fill"):
            raise ValueError(
                f"kv_mass_source must be 'kernel' or 'fill', "
                f"got {scfg.kv_mass_source!r}")
        if scfg.reuse_pages:
            if not scfg.lanes:
                raise ValueError(
                    "reuse_pages requires lane mode (ServeConfig.lanes > 0)")
            if not dec.reuse_eligible(cfg):
                raise ValueError(
                    f"arch {cfg.name!r} is not reuse-eligible: the KV slow "
                    f"store must carry the whole per-position state (single "
                    f"attention pattern position, no recurrent blocks, no "
                    f"dense prologue)")
        self._daemon_owner = attach_to is None
        self._embed_rpp = scfg.embed_rows_per_page or tm.EMBED_ROWS_PER_PAGE
        if attach_to is not None:
            self._check_attach_geometry(attach_to)
            self.daemon = attach_to.daemon
            self.reuse = attach_to.reuse
            self.reuse_mass = attach_to.reuse_mass
        else:
            self.daemon = tm.NeoMemDaemon(tm.DaemonParams(
                async_plane=scfg.async_migration))
            self._register_resources()
            # content-addressed shared pool (repro.cache, DESIGN.md §12):
            # pool page ids sit ABOVE every private segment in the KV
            # address space
            self.reuse = None
            self.reuse_mass = {"shared": 0.0, "total": 0.0}
            if scfg.reuse_pages:
                n_segments = scfg.kv_segments or scfg.lanes
                self.reuse = KVReuseStore(
                    scfg.reuse_pages,
                    base_gid=n_segments * self.pages_per_seq,
                    page_t=scfg.page_t)
        self._kernel_mass = scfg.paged and scfg.kv_mass_source == "kernel"
        self._want_streams = "experts" in self.daemon or \
            ("kv" in self.daemon and self._kernel_mass)
        self._decode = jax.jit(self._decode_fn)
        self._decode_paged = jax.jit(self._decode_paged_fn)
        self._prefill_dense_jit = jax.jit(self._prefill_dense_fn)
        self._prefill_paged_jit = jax.jit(self._prefill_paged_fn)
        self.cache = None
        self._clock = TickClock(scfg.migration_interval)
        self._decode_s = 0.0            # decode wall time (overlap metering)
        self._last_kv_mass = None       # (B, n_slots) kernel mass, post-step
        # (lane, slot) -> (page id, fill) change tracking for the KV flush
        # (single-request mode uses lane 0)
        self._kv_flushed: dict[tuple[int, int], tuple[int, int]] = {}
        self._lane_active = np.zeros(max(scfg.lanes, 1), bool)
        self._lane_segments = np.full(max(scfg.lanes, 1), -1, np.int32)
        # per-lane page table (copy-on-write indirection): local page idx ->
        # global store page; -1 = the private affine default
        # segment*pages_per_seq + local.  Matched shared pages point into
        # the reuse pool instead, so every referencing lane observes the
        # SAME pool gid and the daemon aggregates their mass (DESIGN.md §12).
        pps = self.pages_per_seq if scfg.paged else 1
        self._lane_pages = np.full((max(scfg.lanes, 1), pps), -1, np.int64)
        # locals whose slow-store row holds a complete page (publish witness)
        self._lane_full = np.zeros((max(scfg.lanes, 1), pps), bool)

    def _check_attach_geometry(self, owner: "ServeEngine") -> None:
        """An attached worker engine must agree with the owner on every
        field that shapes the shared store or the per-lane cache geometry —
        a residual snapshotted on one engine's lane is installed verbatim
        onto the other's (ring arrays sized by hot_slots/page_t, segment
        address space sized by max_seq/kv_segments).  Only the lane count
        may differ: that is the worker-pool split."""
        if not (self.lane_mode and owner.lane_mode):
            raise ValueError("attach_to requires lane mode on both engines")
        mine = dataclasses.asdict(self.scfg)
        theirs = dataclasses.asdict(owner.scfg)
        mine.pop("lanes"), theirs.pop("lanes")
        diff = [k for k in mine if mine[k] != theirs[k]]
        if diff:
            raise ValueError(
                f"attached engine geometry differs from owner on {diff} — "
                "only ServeConfig.lanes may differ between workers")

    def _register_resources(self) -> None:
        cfg, scfg = self.cfg, self.scfg
        kinds = set(scfg.resources)
        if scfg.paged:
            kinds.add("kv")
        for kind in sorted(kinds):
            if kind == "kv":
                if not scfg.paged:
                    raise ValueError("the 'kv' resource requires paged=True")
                row_shape = self._kv_row_shape()
                # lane mode: the slow store is carved into per-request
                # segments, each a max_seq-worth of logical pages; the
                # content-addressed reuse pool's pages sit above them
                n_segments = scfg.kv_segments or scfg.lanes or 1
                spec = tm.ResourceSpec(
                    "kv", n_pages=n_segments * self.pages_per_seq
                    + scfg.reuse_pages,
                    hot_slots=scfg.kv_tier_slots or scfg.hot_slots,
                    quota_pages=scfg.kv_quota,
                    row_shape=row_shape, row_dtype="bfloat16",
                    slow_codec=scfg.slow_codec)
                res = tm.make_resource(
                    "kv", spec, mass_threshold=scfg.kv_mass_threshold)
                # the slow tier starts empty: pages are flushed down from the
                # paged cache as decode fills them (_flush_kv_slow)
                payload = jnp.zeros((spec.n_pages,) + row_shape, jnp.bfloat16)
            elif kind == "experts":
                if cfg.moe is None or "moe" not in cfg.pattern:
                    raise ValueError(
                        f"arch {cfg.name!r} has no MoE layers to tier")
                payload = self._expert_payload()
                spec = tm.ResourceSpec(
                    "experts", n_pages=cfg.n_groups * cfg.moe.n_experts,
                    hot_slots=cfg.n_groups * scfg.expert_hot_slots,
                    quota_pages=scfg.expert_quota,
                    row_shape=tuple(payload.shape[1:]),
                    row_dtype=str(payload.dtype),
                    slow_codec=scfg.slow_codec)
                res = tm.make_resource("experts", spec,
                                       n_experts=cfg.moe.n_experts)
            elif kind == "embeddings":
                rows = self._embed_rpp
                payload = self._embed_payload(rows)
                spec = tm.ResourceSpec(
                    "embeddings", n_pages=(cfg.vocab + rows - 1) // rows,
                    hot_slots=scfg.embed_hot_slots,
                    quota_pages=scfg.embed_quota,
                    row_shape=tuple(payload.shape[1:]),
                    row_dtype=str(payload.dtype),
                    slow_codec=scfg.slow_codec)
                res = tm.make_resource("embeddings", spec,
                                       rows_per_page=rows)
            else:
                raise KeyError(f"unknown serve resource kind {kind!r}; "
                               f"known: {tm.resource_kinds()}")
            handle = self.daemon.register(res)
            # the KV slow store starts as zero scratch — pages only become
            # resident (write-witnessed) once a flush lands on them; every
            # other resource binds a payload that is valid from step 0
            handle.bind_data(payload, initially_valid=(kind != "kv"))

    # -- payload construction (the migration data plane, DESIGN.md §8) -------
    def _kv_row_shape(self) -> tuple[int, ...]:
        """One logical KV page across all layer groups: K and V payloads of
        the representative paged-attention entry, concatenated on the last
        axis (MLA: latent + rope widths; GQA: 2 x head_dim)."""
        cfg = self.cfg
        if cfg.mla is not None:
            hkv, dk, dv = 1, cfg.mla.kv_lora + cfg.mla.d_rope, cfg.mla.kv_lora
        else:
            hkv, dk, dv = cfg.n_kv_heads, cfg.head_dim, cfg.head_dim
        return (cfg.n_groups, self.scfg.page_t, hkv, dk + dv)

    def _expert_payload(self) -> jax.Array:
        """(G*E, flat) expert weight blocks, page_id = group*n_experts+expert.

        Uses the first MoE position in the layer pattern as the weight block
        (one representative block per expert; per-position payloads would
        multiply the slow tier by the MoE depth without changing placement).
        """
        i = self.cfg.pattern.index("moe")
        ffn = self.params["blocks"][i]["ffn"]
        g, e = ffn["w_in"].shape[:2]
        parts = [ffn[k].reshape(g * e, -1) for k in ("w_gate", "w_in", "w_out")]
        return jnp.concatenate(parts, axis=-1)

    def _embed_payload(self, rows_per_page: int) -> jax.Array:
        """(n_pages, rows_per_page, d) vocab row-blocks of the live table."""
        table = self.params["embed"]["table"]
        v, d = table.shape
        n_pages = (v + rows_per_page - 1) // rows_per_page
        pad = n_pages * rows_per_page - v
        if pad:
            table = jnp.concatenate(
                [table, jnp.zeros((pad, d), table.dtype)], axis=0)
        return table.reshape(n_pages, rows_per_page, d)

    # -- jitted step bodies -------------------------------------------------
    def _decode_fn(self, params, cache, token, aux, tiered):
        return dec.decode_step(self.cfg, params, cache, token,
                               aux_embeds=aux, ep_axes=self.ep,
                               return_streams=self._want_streams,
                               tiered=tiered)

    def _decode_paged_fn(self, params, cache, token, tiered, active):
        out = dec.decode_step_paged(self.cfg, params, cache, token,
                                    page_t=self.scfg.page_t, ep_axes=self.ep,
                                    return_streams=self._want_streams,
                                    tiered=tiered,
                                    collect_mass=self._kernel_mass)
        if active is None:
            return out
        # lane mode: inactive lanes' cache leaves stay frozen — their
        # positions/rings must not drift while another lane chunk-prefills
        if self._want_streams:
            logits, new_cache, streams = out
            return logits, dec.merge_cache(cache, new_cache, active), streams
        logits, new_cache = out
        return logits, dec.merge_cache(cache, new_cache, active)

    def _prefill_dense_fn(self, params, cache, tokens, aux, tiered):
        return dec.prefill_dense(self.cfg, params, cache, tokens,
                                 aux_embeds=aux, ep_axes=self.ep,
                                 tiered=tiered)

    def _prefill_paged_fn(self, params, cache, tokens, valid, active, tiered):
        return dec.prefill_paged(self.cfg, params, cache, tokens,
                                 page_t=self.scfg.page_t, valid=valid,
                                 active=active, ep_axes=self.ep, tiered=tiered,
                                 collect_mass=self._kernel_mass)

    def _tier_reads(self) -> dict:
        """Tier views for the in-jit read path (DESIGN.md §10): device-array
        ``{"fast", "slow", "page_slot"}`` triples per resource, rebuilt each
        step so migration epochs are picked up as fresh jit arguments (same
        pytree structure — no retrace).  Empty when ``jit_tier_reads`` is
        off; the KV ring needs no view (it IS the fast tier, in-cache)."""
        out: dict = {}
        if not self.scfg.jit_tier_reads:
            return out
        if "embeddings" in self.daemon:
            h = self.daemon["embeddings"]
            if h.mem.buffers is not None:
                view = h.tier_view()
                view["rows_per_page"] = self._embed_rpp
                out["embeddings"] = view
        # EP-sharded serving keeps the shard_map dispatch (moe_apply_ep's
        # "residency" path shards hot experts over the EP axis); the
        # replicated per-token row gather is the single-device tiered path
        if "experts" in self.daemon and self.ep is None:
            h = self.daemon["experts"]
            if h.mem.buffers is not None:
                out["experts"] = h.tier_view()
        return out

    # -- public API -----------------------------------------------------------
    @property
    def _chunk_cap(self) -> int:
        """Ring-wrap safety bound on one prefill chunk: a chunk scan must
        never overwrite a page that has not been flushed to the slow store,
        so it spans at most the ring minus the slot it may be mid-filling."""
        return max((self.scfg.hot_slots - 1) * self.scfg.page_t, 1)

    def prefill(self, tokens: np.ndarray, aux_embeds=None):
        if self.lane_mode:
            raise ValueError("lane mode serves through prefill_lane/"
                             "advance_lanes (the request scheduler), not "
                             "prefill/generate")
        b, s = tokens.shape
        self.aux = aux_embeds
        if self.cfg.encoder_layers and aux_embeds is not None:
            self.aux = tr.encode(self.cfg, self.params, aux_embeds)
        if self.scfg.paged:
            self.cache = dec.init_paged_cache(
                self.cfg, b, self.scfg.hot_slots, self.scfg.page_t)
            self._kv_flushed.clear()         # fresh ring: re-flush everything
            # chunked prefill: scan the paged decode body over the prompt in
            # ring-capacity chunks (bit-exact with token-at-a-time streaming;
            # dec.prefill_paged), flushing each chunk's pages down before the
            # ring can wrap over them
            cap = self._chunk_cap
            logits = None
            for off in range(0, s, cap):
                logits = self._prefill_chunk(jnp.asarray(tokens[:, off:off + cap]))
            return np.asarray(jnp.argmax(logits, -1))
        # dense path: ONE scan fills the cache and yields the last-token
        # logits together — the prompt runs exactly once, and the tiering
        # streams are replayed as one masked observation batch
        self.cache = dec.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, self.cache, streams = self._prefill_dense_jit(
            self.params, self.cache, jnp.asarray(tokens), self.aux,
            self._tier_reads())
        self._observe_prefill(tokens, streams)
        self._maybe_tick(s)
        return np.asarray(jnp.argmax(logits, -1))

    def _prefill_chunk(self, tok: jax.Array):
        """One single-request paged prefill chunk: scan-advance the cache,
        observe the chunk's streams once, flush its pages, tick the daemon
        for the chunk's worth of steps.  Returns (B, V) last logits."""
        n = tok.shape[1]
        logits, self.cache, streams = self._prefill_paged_jit(
            self.params, self.cache, tok, None, None, self._tier_reads())
        self._observe_prefill(np.asarray(tok), streams)
        if "kv" in self.daemon:
            mass, ids = self._kv_page_stream()
            km = streams.get("kv_mass")
            if self._kernel_mass and km is not None:
                # chunk-summed kernel mass over the post-chunk window: the
                # (C, G, n_attn, B, S) stream head-averaged over groups,
                # positions and lockstep batch rows, summed over the chunk —
                # the aggregate of the per-step NeoProf streams (DESIGN.md §10)
                mass = jnp.sum(jnp.mean(km, axis=(1, 2, 3)), axis=0)
            if ids.size:
                self.daemon.observe("kv", mass, ids)
        self._flush_kv_slow()
        self._maybe_tick(n)
        return logits

    def _observe_prefill(self, tokens: np.ndarray, streams: dict) -> None:
        """Replay a prefilled chunk's embedding/expert streams as ONE
        observation batch each (not one per prompt token)."""
        if "embeddings" in self.daemon:
            self.daemon.observe("embeddings", jnp.asarray(tokens, jnp.int32))
        if "experts" in self.daemon and streams.get("router") is not None:
            self.daemon.observe("experts", streams["router"])

    def step(self, token: np.ndarray) -> np.ndarray:
        logits = self._advance(jnp.asarray(token)[:, None])
        return np.asarray(jnp.argmax(logits[:, -1], -1))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 aux_embeds=None) -> np.ndarray:
        nxt = self.prefill(prompt, aux_embeds)
        out = [nxt]
        for _ in range(n_tokens - 1):
            nxt = self.step(nxt)
            out.append(nxt)
        return np.stack(out, axis=1)

    # -- continuous-batching lane mode (serve/sched.py, DESIGN.md §9) ---------
    @property
    def pages_per_seq(self) -> int:
        """Logical KV pages per request segment (= per max_seq sequence)."""
        return self.scfg.max_seq // self.scfg.page_t

    @property
    def lane_mode(self) -> bool:
        return self.scfg.lanes > 0

    def start_lanes(self) -> None:
        """Initialize the lane substrate: ``lanes`` independent decode lanes
        over one paged ring with per-lane positions.  No prompt — the
        scheduler streams prompt tokens through :meth:`advance_lanes`."""
        scfg = self.scfg
        if not self.lane_mode:
            raise ValueError("start_lanes requires ServeConfig.lanes > 0")
        self.cache = dec.init_paged_cache(self.cfg, scfg.lanes, scfg.hot_slots,
                                          scfg.page_t, per_lane_pos=True)
        # pristine one-lane template: reset_lane restores INITIAL values,
        # which are not all zero (the m/sLSTM stabilizer state inits to -inf)
        self._lane_init = dec.init_paged_cache(self.cfg, 1, scfg.hot_slots,
                                               scfg.page_t, per_lane_pos=True)
        self.aux = None
        self._kv_flushed.clear()
        self._lane_active = np.zeros(scfg.lanes, bool)
        self._lane_segments = np.full(scfg.lanes, -1, np.int32)
        self._lane_pages = np.full((scfg.lanes, self.pages_per_seq), -1,
                                   np.int64)
        self._lane_full = np.zeros((scfg.lanes, self.pages_per_seq), bool)

    def advance_lanes(self, tokens, active, segments) -> np.ndarray:
        """One continuous-batching decode step for ALL lanes at once.

        ``tokens`` (L,) — the next token of each lane's stream: a prompt
        token while the lane prefills, the last sampled token while it
        decodes, don't-care for inactive lanes (their compute is masked out
        of every observation stream and never flushed).  ``active`` (L,)
        bool, ``segments`` (L,) int — the lane's slow-store KV segment
        (-1 = none).  Returns the last-position logits (L, vocab)."""
        if not self.lane_mode:
            raise ValueError("advance_lanes requires ServeConfig.lanes > 0")
        if self.cache is None:
            self.start_lanes()
        t0 = time.perf_counter()
        self._lane_active = np.asarray(active, bool).copy()
        self._lane_segments = np.asarray(segments, np.int32).copy()
        tokens = np.asarray(tokens, np.int32)
        tok = jnp.asarray(tokens)[:, None]
        out = self._decode_paged(self.params, self.cache, tok,
                                 self._tier_reads(),
                                 jnp.asarray(self._lane_active))
        if self._want_streams:
            logits, self.cache, streams = out
        else:
            (logits, self.cache), streams = out, {}
        self._set_kv_mass(streams)
        self._observe_lanes(tokens, streams)
        self._maybe_tick()
        out_logits = np.asarray(logits[:, -1])   # host sync = the step's end
        self._decode_s += time.perf_counter() - t0
        return out_logits

    def prefill_lane(self, lane: int, tokens, segment: int,
                     chunk: int | None = None) -> np.ndarray:
        """Chunked prefill of ONE lane's prompt through the paged ring
        (DESIGN.md §11): the prompt is consumed ``chunk`` tokens at a time
        by a single jitted scan of the paged decode body (bit-exact with
        token-at-a-time streaming), every other lane's decode state frozen
        by the active-lane mask — so the scheduler can interleave chunk
        writes with other lanes' decode steps, no stop-the-world.

        Per chunk the engine bulk-flushes the lane's freshly-filled ring
        pages down to its slow-store ``segment`` (one donated scatter,
        ``tiering.migrate.write_pages``), feeds the KV observation stream
        with the chunk's resident page ids so the daemon profiles prefilled
        pages immediately, and advances the daemon cadence by the chunk
        length.  Lane-addressed on purpose: this is the hand-off verb a
        disaggregated prefill tier would call against the shared slow
        store.  Returns the last prompt position's logits (vocab,) f32.
        """
        if not self.lane_mode:
            raise ValueError("prefill_lane requires ServeConfig.lanes > 0")
        if self.cache is None:
            self.start_lanes()
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("prefill_lane needs at least one token")
        chunk = min(chunk or tokens.size, self._chunk_cap)
        self._lane_segments[lane] = segment
        active = np.zeros(self.scfg.lanes, bool)
        active[lane] = True
        logits = None
        for off in range(0, tokens.size, chunk):
            logits = self._prefill_lane_chunk(lane, tokens[off:off + chunk],
                                              chunk, active)
        return logits

    def _prefill_lane_chunk(self, lane: int, piece: np.ndarray, chunk: int,
                            active: np.ndarray) -> np.ndarray:
        """One lane-chunk scan: ragged pieces are padded to the fixed chunk
        width with valid=False no-op steps (one traced shape per chunk
        size), so a prompt tail never retraces the scan."""
        n = piece.size
        tok = np.zeros((self.scfg.lanes, chunk), np.int32)
        tok[lane, :n] = piece
        valid = np.zeros((self.scfg.lanes, chunk), bool)
        valid[lane, :n] = True
        logits, self.cache, streams = self._prefill_paged_jit(
            self.params, self.cache, jnp.asarray(tok), jnp.asarray(valid),
            jnp.asarray(active), self._tier_reads())
        self._lane_active = active.copy()
        self._observe_lane_chunk(lane, tok, valid, streams, active)
        self._flush_kv_lanes(lanes=[lane])
        self._maybe_tick(n)
        return np.asarray(logits[lane])

    def _observe_lane_chunk(self, lane: int, tok: np.ndarray,
                            valid: np.ndarray, streams: dict,
                            active: np.ndarray) -> None:
        """Feed one chunk's tiering streams in ONE observation batch per
        resource, other lanes (and tail padding) masked to -1."""
        if "embeddings" in self.daemon:
            self.daemon.observe(
                "embeddings", jnp.asarray(np.where(valid, tok, -1), jnp.int32))
        if "experts" in self.daemon and streams.get("router") is not None:
            router = streams["router"]      # (C, G, n_moe, L, 1, k)
            mask = jnp.asarray(valid.T)[:, None, None, :, None, None]
            self.daemon.observe("experts", jnp.where(mask, router, -1))
        if "kv" in self.daemon:
            sv = self._kv_lane_stream(active=active)
            if sv is None:
                return
            mass, gids = sv                 # (L, S) post-chunk window
            km = streams.get("kv_mass")
            if self._kernel_mass and km is not None:
                # per-step (C, G, n_attn, L, S) kernel mass: head-averaged,
                # summed over the chunk's valid steps — the bulk analogue of
                # the one-step stream advance_lanes feeds
                per_step = jnp.mean(km, axis=(1, 2))          # (C, L, S)
                agg = jnp.sum(per_step * jnp.asarray(valid.T)[:, :, None],
                              axis=0)                         # (L, S)
                mass = np.where(gids >= 0, np.asarray(agg, np.float32), 0.0)
            self._count_shared_mass(mass, gids)
            self.daemon.observe("kv", jnp.asarray(mass.reshape(-1)),
                                jnp.asarray(gids.reshape(-1), jnp.int32))

    def _observe_lanes(self, tokens: np.ndarray, streams: dict) -> None:
        """Feed the tiering streams with inactive lanes masked to -1 pads."""
        act = self._lane_active
        if "embeddings" in self.daemon:
            toks = np.where(act, tokens, -1)
            self.daemon.observe("embeddings", jnp.asarray(toks, jnp.int32))
        if "experts" in self.daemon and streams.get("router") is not None:
            router = streams["router"]        # (G, n_moe, L, 1, k)
            mask = jnp.asarray(act)[None, None, :, None, None]
            self.daemon.observe("experts", jnp.where(mask, router, -1))
        if "kv" in self.daemon:
            sv = self._kv_lane_stream()
            if sv is not None:
                mass, gids = sv
                if self._kernel_mass and self._last_kv_mass is not None:
                    # per-lane kernel mass, masked to the live lanes'
                    # segment-mapped pages (same mask the gids carry)
                    km = np.asarray(self._last_kv_mass, np.float32)
                    mass = np.where(gids >= 0, km, 0.0)
                self._count_shared_mass(mass, gids)
                self.daemon.observe("kv", jnp.asarray(mass.reshape(-1)),
                                    jnp.asarray(gids.reshape(-1), jnp.int32))

    def reset_lane(self, lane: int) -> None:
        """Return a lane to its initial state for a fresh request admission:
        ring bookkeeping, O(1) recurrent states, and the lane position go
        back to their INIT values from the pristine template (page payloads
        may stay — ``page_len`` masks them)."""
        def clear(entry: dict, tmpl: dict, idx, tmpl_idx) -> None:
            for k, v in entry.items():
                if k in ("k_pages", "v_pages"):
                    continue
                entry[k] = v.at[idx].set(tmpl[k][tmpl_idx])
        for entry, tmpl in zip(self.cache["blocks"],
                               self._lane_init["blocks"]):
            if isinstance(entry, dict):
                clear(entry, tmpl, (slice(None), lane), (slice(None), 0))
        for entry, tmpl in zip(self.cache.get("prologue", []),
                               self._lane_init.get("prologue", [])):
            clear(entry, tmpl, lane, 0)
        self.cache["pos"] = self.cache["pos"].at[lane].set(0)
        self._invalidate_lane_flush(lane)
        self._lane_pages[lane] = -1
        self._lane_full[lane] = False

    def preempt_lane(self, lane: int) -> dict:
        """Evict a lane's request so the lane can serve someone else.

        The lane's resident ring pages are force-flushed down to its KV
        slow-store segment (the migration data plane — an exact snapshot of
        the ring survives outside it), while the per-lane bookkeeping and
        everything the tiered KV payload does not carry (O(1) recurrent
        states, sibling attention positions beyond the representative entry,
        the dense prologue ring) is snapshotted host-side into the returned
        residual.  :meth:`resume_lane` restores bit-exactly."""
        self._flush_kv_lanes(lanes=[lane], force=True)
        residual = {"pos": int(np.asarray(self.cache["pos"])[lane]),
                    "segment": int(self._lane_segments[lane]),
                    # page-table row + publish witnesses travel with the
                    # request: its claim on shared pool pages survives the
                    # lane (refcounts are the scheduler's, unchanged here)
                    "pages": self._lane_pages[lane].copy(),
                    "full": self._lane_full[lane].copy(),
                    "blocks": [], "prologue": []}
        rep = self._paged_entry()
        for entry in self.cache["blocks"]:
            if not isinstance(entry, dict):
                residual["blocks"].append({})
                continue
            skip = ("k_pages", "v_pages") if entry is rep else ()
            residual["blocks"].append(
                {k: np.asarray(v[:, lane]) for k, v in entry.items()
                 if k not in skip})
        for entry in self.cache.get("prologue", []):
            residual["prologue"].append(
                {k: np.asarray(v[lane]) for k, v in entry.items()})
        return residual

    def resume_lane(self, lane: int, residual: dict) -> int:
        """Re-install a preempted request into a lane: residual bookkeeping
        is restored and the representative entry's resident ring pages are
        gathered back through the tiered KV store (fast-tier copy when
        promoted, slow-tier fallback — bit-exact either way).  Returns the
        number of ring pages gathered back up (the consumer-side hand-off
        volume, DESIGN.md §13)."""
        for entry, snap in zip(self.cache["blocks"], residual["blocks"]):
            for k, v in snap.items():
                entry[k] = entry[k].at[:, lane].set(
                    jnp.asarray(v, entry[k].dtype))
        for entry, snap in zip(self.cache.get("prologue", []),
                               residual["prologue"]):
            for k, v in snap.items():
                entry[k] = entry[k].at[lane].set(jnp.asarray(v, entry[k].dtype))
        self.cache["pos"] = self.cache["pos"].at[lane].set(residual["pos"])
        self._invalidate_lane_flush(lane)
        self._lane_pages[lane] = residual.get("pages", -1)
        self._lane_full[lane] = residual.get("full", False)
        segment = residual["segment"]
        # restore the lane->segment binding NOW, not at the next
        # advance_lanes: a hand-off install may flush or publish this lane
        # (e.g. a max_new=1 request finishing at install) before any step
        self._lane_segments[lane] = segment
        entry = self._paged_entry()
        if entry is None or segment < 0:
            return 0
        plen = np.asarray(entry["page_len"])[0, lane][None]      # (1, S)
        cur = np.asarray(entry["cur_slot"])[0, lane][None]       # (1,)
        pos = np.asarray([residual["pos"]])
        local = self._ring_page_ids(plen, cur, pos, self.scfg.page_t)[0]
        slots = np.flatnonzero(local >= 0)
        if slots.size == 0:
            return 0
        # shared pool pages re-gather from the pool, private ones from the
        # segment — the page-table row restored above decides per page
        tabled = self._lane_pages[lane, local[slots]]
        gids = np.where(tabled >= 0, tabled,
                        segment * self.pages_per_seq + local[slots])
        rows = self.daemon["kv"].read_rows(jnp.asarray(gids, jnp.int32))
        rows = jnp.moveaxis(rows, 0, 1)          # (G, n, T, hkv, dk+dv)
        dk = self._kv_split_width()
        entry["k_pages"] = entry["k_pages"].at[:, lane, slots].set(
            rows[..., :dk].astype(entry["k_pages"].dtype))
        entry["v_pages"] = entry["v_pages"].at[:, lane, slots].set(
            rows[..., dk:].astype(entry["v_pages"].dtype))
        for i, s in enumerate(slots):
            self._kv_flushed[(lane, int(s))] = (int(gids[i]),
                                                int(plen[0, s]))
        return int(slots.size)

    def _kv_split_width(self) -> int:
        """Last-axis K width inside a concatenated [K | V] payload row."""
        cfg = self.cfg
        if cfg.mla is not None:
            return cfg.mla.kv_lora + cfg.mla.d_rope
        return cfg.head_dim

    # -- disaggregated prefill/decode hand-off (DESIGN.md §13) ----------------
    def handoff_lane(self, lane: int) -> dict:
        """Producer-side hand-off: detach a finished prefill from its lane.

        Mechanically a preemption — the force-flush pushes every resident
        ring page down into the request's slow-store segment
        (``migrate.write_pages``) and the residual snapshots everything the
        KV payload does not carry — plus the fabric metering:
        ``handoff_bytes`` counts the whole consumed prefix once, the bulk
        KV bytes that crossed the slow tier producer-side (each page was
        flushed exactly once as prefill filled it, or here if partial).
        The residual is the hand-off token a decode worker passes to
        :meth:`install_handoff`."""
        residual = self.preempt_lane(lane)
        n_pages = -(-residual["pos"] // self.scfg.page_t)
        row = self.daemon["kv"].mem.row_bytes if "kv" in self.daemon else 0
        residual["handoff_bytes"] = n_pages * row
        return residual

    def segment_resident(self, residual: dict) -> bool:
        """Consumer-side admission gate (DESIGN.md §13): is the hand-off's
        consumed prefix fully write-witnessed in the slow store?  Checks
        every page up to ``residual["pos"]`` — the final, possibly partial,
        page included (the hand-off force-flush writes it) — through the
        request's copy-on-write page table, so admission-matched shared
        pool pages count via their pool row (DESIGN.md §12)."""
        if "kv" not in self.daemon or residual["segment"] < 0:
            return True
        gids = tm.segment_page_ids(
            residual["segment"], residual["pos"], self.scfg.page_t,
            self.pages_per_seq, table=residual.get("pages"))
        return bool(self.daemon["kv"].pages_written(gids).all())

    def install_handoff(self, lane: int, residual: dict) -> int:
        """Consumer-side hand-off: install a prefilled request into a decode
        lane, pulling its ring window back up THROUGH the placement-table
        read path (``resume_lane``'s ``read_rows`` — fast-tier copy when the
        daemon already promoted the page, slow-tier gather otherwise, so the
        tiering daemon treats the new request's pages exactly like any
        slow-resident data).  Refuses a segment the producer has not fully
        flushed — callers gate admission on :meth:`segment_resident` first.
        Returns the consumer-side hand-off bytes (gathered pages x row)."""
        if not self.segment_resident(residual):
            raise RuntimeError(
                f"segment {residual['segment']} not fully resident — "
                "hand-off installed before the prefill flush completed")
        gathered = self.resume_lane(lane, residual)
        row = self.daemon["kv"].mem.row_bytes if "kv" in self.daemon else 0
        return gathered * row

    def _invalidate_lane_flush(self, lane: int) -> None:
        for key in [k for k in self._kv_flushed if k[0] == lane]:
            del self._kv_flushed[key]

    # -- content-addressed KV reuse (repro.cache, DESIGN.md §12) --------------
    def install_lane_pages(self, lane: int, run: dict[int, int]
                           ) -> tuple[int, int]:
        """Fast-forward a lane over one CONSECUTIVE run of admission-matched
        pages: install the run's ring-window tail from the shared pool and
        jump the lane position past the run, no forward pass (DESIGN.md
        §12).  ``run`` maps local page idx -> pool gid; pages before the
        window tail fall outside the attention ring and carry no payload
        (streaming would have wrapped over them identically) but still
        count as prefill tokens saved.  Installed slots are marked clean in
        the flush tracker — copy-on-write: the ring never writes a shared
        page back.  Returns the pool reads' (fast, slow) placement split so
        the scheduler can charge them to the admitting tenant (the reads
        themselves are metered on the "kv" resource by read_rows)."""
        if self.reuse is None:
            raise ValueError("install_lane_pages requires reuse_pages > 0")
        locals_ = np.asarray(sorted(run), np.int64)
        if locals_.size == 0:
            return 0, 0
        if not np.all(np.diff(locals_) == 1):
            raise ValueError("install run must be consecutive local pages")
        gids = np.asarray([run[int(j)] for j in locals_], np.int64)
        S, T = self.scfg.hot_slots, self.scfg.page_t
        sel, gsel = locals_[-S:], gids[-S:]
        h = self.daemon["kv"]
        _, hit = h.lookup(jnp.asarray(gsel, jnp.int32))
        fast_n = int(np.asarray(hit).sum())
        rows = h.read_rows(jnp.asarray(gsel, jnp.int32))
        rows = jnp.moveaxis(rows, 0, 1)          # (G, n, T, hkv, dk+dv)
        new_pos = int(locals_[-1] + 1) * T
        dec.install_pages(self.cache, lane, sel % S, rows,
                          dk=self._kv_split_width(), page_t=T,
                          new_pos=new_pos)
        self._lane_pages[lane, locals_] = gids
        cur = (new_pos // T) % S
        for j, g in zip(sel % S, gsel):
            if int(j) != cur:                    # cur slot was re-zeroed
                self._kv_flushed[(lane, int(j))] = (int(g), T)
        self.reuse.note_consumed(locals_.size)   # tokens_saved: consumed runs
        return fast_n, int(gsel.size - fast_n)

    def publish_lane(self, lane: int, tokens) -> int:
        """Publish a finishing request's completed KV pages into the shared
        pool: force-flush the lane (its segment becomes an exact ring
        snapshot), index every full page of its appended token stream whose
        slow row is witnessed complete, and copy NEW pages' payloads
        segment -> pool in ONE fused ``copy_rows``.  Pages already indexed
        (e.g. installed at admission) deduplicate to an LRU touch.
        Returns the number of newly published pages."""
        if self.reuse is None:
            return 0
        toks = np.asarray(tokens).ravel()
        pos = int(np.asarray(self.cache["pos"])[lane])
        n_pages = min(toks.size, pos) // self.scfg.page_t
        if n_pages <= 0 or self._lane_segments[lane] < 0:
            return 0
        self._flush_kv_lanes(lanes=[lane], force=True)
        witness = self._lane_full[lane] | (self._lane_pages[lane] >= 0)
        new = self.reuse.publish(toks, n_pages, mask=witness)
        if not new:
            return 0
        seg = int(self._lane_segments[lane])
        src = [int(self._lane_pages[lane, j]) if self._lane_pages[lane, j] >= 0
               else seg * self.pages_per_seq + j for j, _ in new]
        dst = [gid for _, gid in new]
        self.daemon["kv"].copy_rows(np.asarray(src, np.int32),
                                    np.asarray(dst, np.int32))
        return len(new)

    def _count_shared_mass(self, mass: np.ndarray, gids: np.ndarray) -> None:
        """Accumulate the observation mass landing on shared pool pages vs
        all resident pages — the shared-page mass share (BENCH kv_reuse)."""
        if self.reuse is None:
            return
        m = np.asarray(mass, np.float64)
        self.reuse_mass["total"] += float(m[gids >= 0].sum())
        self.reuse_mass["shared"] += float(m[gids >= self.reuse.base_gid].sum())

    def reuse_stats(self) -> dict | None:
        """Content-addressed store telemetry + the shared-page mass share."""
        if self.reuse is None:
            return None
        row = self.reuse.stats()
        total = self.reuse_mass["total"]
        row["shared_mass_share"] = (self.reuse_mass["shared"] / total
                                    if total > 0 else 0.0)
        return row

    # -- tiering-state checkpoint (DESIGN.md §6) ------------------------------
    def save_tiering(self, mgr, step: int) -> None:
        """Checkpoint every resource's placement/profiling state through
        ``ckpt/manager.py`` (one pure pytree; the pending FIFOs are
        best-effort and re-derived from the next sketch epoch)."""
        mgr.save(step, self.daemon.state_dict())

    def load_tiering(self, mgr, step: int) -> None:
        """Warm-restore the placement maps from a checkpoint; resident fast
        rows are refilled from the bound slow stores (daemon.load_state), so
        a restarted server serves with a warm placement map immediately."""
        self.daemon.load_state(mgr.restore(step, self.daemon.state_dict()))

    # -- decode + NeoMem observation/cadence ----------------------------------
    def _advance(self, tok: jax.Array):
        """One decode step: run the jitted body, feed the tiering streams,
        tick the multiplexed daemon on its cadence."""
        t0 = time.perf_counter()
        if self.scfg.paged:
            out = self._decode_paged(self.params, self.cache, tok,
                                     self._tier_reads(), None)
        else:
            out = self._decode(self.params, self.cache, tok, self.aux,
                               self._tier_reads())
        if self._want_streams:
            logits, self.cache, streams = out
        else:
            (logits, self.cache), streams = out, {}
        self._set_kv_mass(streams)
        self._observe(tok, streams)
        self._maybe_tick()
        self._decode_s += time.perf_counter() - t0
        return logits

    def _set_kv_mass(self, streams: dict) -> None:
        """Hold the step's kernel-exported (B, n_slots) page mass: the
        per-position (G, n_attn, B, S) stream head-averaged over layer
        groups and attention positions — the aggregate line-rate view one
        NeoProf device would see across the chip (DESIGN.md §10)."""
        km = streams.get("kv_mass")
        self._last_kv_mass = (jnp.mean(km, axis=(0, 1))
                              if km is not None else None)

    def _observe(self, tok: jax.Array, streams: dict) -> None:
        if "embeddings" in self.daemon:
            self.daemon.observe("embeddings", tok)
        if "experts" in self.daemon and streams.get("router") is not None:
            self.daemon.observe("experts", streams["router"])
        if "kv" in self.daemon:
            mass, ids = self._kv_page_stream()
            if self._kernel_mass and self._last_kv_mass is not None:
                # kernel-true hotness: batch rows advance in lockstep over
                # the same page ids, so the row-mean is the device's
                # aggregate view of the step's attention mass
                mass = jnp.mean(self._last_kv_mass, axis=0)
            if ids.size:
                self.daemon.observe("kv", mass, ids)

    def _paged_entry(self) -> dict | None:
        """The representative paged-attention cache entry (first in-pattern).

        Its pages are the KV payload rows the tiered store carries; sibling
        attention positions (and the dense prologue) share the same ring
        geometry and travel in preemption residuals (see preempt_lane)."""
        return next((c for c in self.cache["blocks"]
                     if isinstance(c, dict) and "page_len" in c), None)

    def _ring_view(self) -> tuple[np.ndarray, np.ndarray, np.ndarray] | None:
        """Host view of the paged ring: (page_len (B, S), cur_slot (B,),
        pos (B,)).  Group 0 is representative — all groups advance in
        lockstep, one appended token per step."""
        entry = self._paged_entry()
        if entry is None:
            return None
        plen = np.asarray(entry["page_len"])[0]              # (B, S)
        cur = np.asarray(entry["cur_slot"])[0]               # (B,)
        pos = np.broadcast_to(np.asarray(self.cache["pos"]), cur.shape)
        return plen, cur, pos

    @staticmethod
    def _ring_page_ids(plen: np.ndarray, cur: np.ndarray, pos: np.ndarray,
                       page_t: int) -> np.ndarray:
        """Per-row logical page id of every ring slot ((B, S); -1 = empty).

        cur_slot advances eagerly when a page fills, so the page being
        filled at cur is always floor(pos / page_t) — also on boundaries."""
        n_slots = plen.shape[1]
        cur_page = pos // page_t                             # (B,)
        slots = np.arange(n_slots)[None]                     # (1, S)
        ids = cur_page[:, None] - (cur[:, None] - slots) % n_slots
        return np.where((plen > 0) & (ids >= 0), ids, -1)

    def _kv_page_stream(self) -> tuple[jax.Array, jax.Array]:
        """Resident paged-KV window as (per-page fill, logical page ids).

        The fill (page_len) is the PROXY mass (``kv_mass_source="fill"``,
        and the change-tracking key for the slow-store flush); with the
        default kernel source the observer overrides it with the decode
        kernel's true per-page softmax mass (DESIGN.md §10).  Batch row 0
        is representative: all rows advance in lockstep."""
        view = self._ring_view()
        if view is None:
            return jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32)
        plen, cur, pos = view
        ids = self._ring_page_ids(plen, cur, pos, self.scfg.page_t)[0]
        return jnp.asarray(plen[0], jnp.float32), jnp.asarray(ids, jnp.int32)

    def _kv_lane_stream(self, active: np.ndarray | None = None,
                        ) -> tuple[np.ndarray, np.ndarray] | None:
        """Lane mode: (mass (L, S), global page ids (L, S)) — each lane's
        resident ring pages mapped into its slow-store segment's address
        space; lanes outside ``active`` (default: the live mask) are -1."""
        view = self._ring_view()
        if view is None:
            return None
        plen, cur, pos = view
        local = self._ring_page_ids(plen, cur, pos, self.scfg.page_t)
        act = self._lane_active if active is None else np.asarray(active, bool)
        gids = self._map_gids(local, act)
        mass = np.where(gids >= 0, plen, 0).astype(np.float32)
        return mass, gids

    def _map_gids(self, local: np.ndarray, act: np.ndarray) -> np.ndarray:
        """Resolve (L, S) local page ids to global store page ids through
        the per-lane page table: table entries (shared pool pages) win,
        everything else falls back to the private affine mapping
        ``segment * pages_per_seq + local``; invalid lanes/slots are -1."""
        seg = self._lane_segments[:, None].astype(np.int64)
        affine = seg * self.pages_per_seq + local
        lanes = np.arange(local.shape[0])[:, None]
        tabled = self._lane_pages[lanes, np.maximum(local, 0)]
        gids = np.where(tabled >= 0, tabled, affine)
        return np.where((local >= 0) & act[:, None] & (seg >= 0), gids, -1)

    def _flush_kv_slow(self) -> None:
        """Flush the resident paged-cache window down to the KV data plane.

        The ring of hot page slots is the authoritative copy of recent pages
        (DESIGN.md §3.2); before each daemon epoch the engine writes their
        payloads through ``write_rows`` — slow store always, plus the fast
        copies of promoted pages so neither reads nor demotion write-backs
        ever serve a stale snapshot.  Ring pages unchanged since the last
        flush (same page id, same fill) are skipped, and the flushed bytes
        are metered as ``flush_bytes``.  Batch row 0 is the representative
        payload, matching the mass proxy in _kv_page_stream.
        """
        h = self.daemon["kv"]
        if h.mem.buffers is None:
            return
        entry = self._paged_entry()
        if entry is None:
            return
        mass, ids = self._kv_page_stream()
        if not ids.size:
            return
        ids = np.asarray(ids)
        fill = np.asarray(mass, np.int64)            # per-slot page_len
        changed = np.array([
            self._kv_flushed.get((0, slot)) != (int(ids[slot]), int(fill[slot]))
            for slot in range(ids.shape[0])])
        ids = np.where(changed, ids, -1)             # -1 lanes are dropped
        if not (ids >= 0).any():
            return
        # batch row 0 is the representative payload; the [K|V] concat +
        # slot-major transpose + dual-tier scatter fuse in ONE donated op
        h.write_pages(ids, entry["k_pages"][:, :1], entry["v_pages"][:, :1])
        for slot in np.flatnonzero(ids >= 0):
            self._kv_flushed[(0, slot)] = (int(ids[slot]), int(fill[slot]))

    def _flush_kv_lanes(self, lanes=None, force: bool = False) -> None:
        """Lane-mode KV flush: every active lane's resident ring pages go
        down to its slow-store segment through ``write_rows`` (real per-lane
        payloads, unlike the single-request row-0 representative).  Pages
        unchanged since the last flush are skipped unless ``force`` —
        preemption forces a full flush of the evicted lane so the slow store
        is an exact snapshot of its ring.

        Copy-on-write over shared pool pages (DESIGN.md §12): a ring slot
        holding a CLEAN shared page (installed at admission, fill
        unchanged) is never written back — the pool is authoritative, even
        under ``force``.  A slot whose shared mapping went stale (the ring
        wrote into it) forks: the page-table entry reverts to the lane's
        private segment page and the payload flushes there, so other
        referencing lanes keep the pool copy untouched."""
        h = self.daemon["kv"]
        if h.mem.buffers is None:
            return
        entry = self._paged_entry()
        if entry is None:
            return
        view = self._ring_view()
        if view is None:
            return
        plen, cur, pos = view
        local = self._ring_page_ids(plen, cur, pos, self.scfg.page_t)
        if lanes is None:
            act = self._lane_active
        else:
            act = np.zeros(self.scfg.lanes, bool)
            act[np.asarray(lanes, int)] = True
        gids = self._map_gids(local, act)            # (L, S)
        fill = np.where(gids >= 0, plen, 0).astype(np.int64)
        base = self.reuse.base_gid if self.reuse is not None else None
        ids = gids.copy()
        for lane, slot in np.argwhere(ids >= 0):
            key = (int(lane), int(slot))
            state = (int(gids[lane, slot]), int(fill[lane, slot]))
            if base is not None and gids[lane, slot] >= base:
                if self._kv_flushed.get(key) == state:
                    ids[lane, slot] = -1             # clean shared page: CoW
                    continue
                lp = int(local[lane, slot])          # dirty: private fork
                self._lane_pages[lane, lp] = -1
                priv = (int(self._lane_segments[lane]) * self.pages_per_seq
                        + lp)
                ids[lane, slot] = gids[lane, slot] = priv
                state = (priv, int(fill[lane, slot]))
            if not force and self._kv_flushed.get(key) == state:
                ids[lane, slot] = -1
        if not (ids >= 0).any():
            return
        # bulk page-write verb: the (G, L, S, T, hkv, d) ring views go down
        # as ONE donated fused [K|V]-concat + transpose + dual-tier scatter
        h.write_pages(ids.reshape(-1), entry["k_pages"], entry["v_pages"])
        for lane, slot in np.argwhere(ids >= 0):
            self._kv_flushed[(int(lane), int(slot))] = (
                int(gids[lane, slot]), int(fill[lane, slot]))
            if fill[lane, slot] >= self.scfg.page_t:
                # witness: this local's slow row holds the complete page
                self._lane_full[lane, local[lane, slot]] = True

    def read_rows(self, name: str, page_ids) -> jax.Array:
        """Serve payload rows for a resource: fast-tier copy when the page
        is resident, slow-tier fallback otherwise (bit-exact either way)."""
        return self.daemon[name].read_rows(page_ids)

    @property
    def step_count(self) -> int:
        """Engine steps so far (decode steps + prefilled prompt positions)."""
        return self._clock.steps

    def _maybe_tick(self, n: int = 1) -> None:
        """Advance the engine step counter by ``n`` (1 for a decode step, the
        chunk length for a prefill chunk) and run one daemon tick per
        migration-interval boundary crossed, flushing the KV ring first."""
        ticks = self._clock.advance(n)
        if not self.daemon.resources:
            return
        if not self._daemon_owner:
            # an attached worker engine (DESIGN.md §13) never drives the
            # shared daemon: migration cadence is the owner's; this worker's
            # dirty pages flush per chunk / at hand-off, not per tick
            return
        for _ in range(ticks):
            if "kv" in self.daemon:
                if self.lane_mode:
                    self._flush_kv_lanes()
                else:
                    self._flush_kv_slow()
            self.daemon.tick()

    # -- telemetry ------------------------------------------------------------
    def tier_stats(self) -> dict[str, dict]:
        """Per-resource telemetry rows (the BENCH_serve.json schema)."""
        for h in self.daemon.resources.values():
            h.stats.decode_s = self._decode_s
        return self.daemon.snapshot()

    @property
    def kv_tier(self) -> tm.ResourceHandle | None:
        """Deprecated: the KV resource handle (None when not paged)."""
        return self.daemon["kv"] if "kv" in self.daemon else None
