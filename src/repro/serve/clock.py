"""TickClock — the daemon-cadence step counter, factored out of the engine.

``ServeEngine._maybe_tick`` advances the step counter by 1 per decode step
and by the CHUNK LENGTH per prefill chunk, and must fire one daemon tick
per migration-interval boundary the advance crosses — a chunk of length
``3 * interval`` owes exactly 3 ticks, and a chunk that lands exactly ON a
boundary owes the boundary's tick once (not zero, not twice).  The integer
arithmetic is easy to get off by one, so it lives here with its own tests
(tests/test_tick_clock.py) instead of inline in the engine.
"""
from __future__ import annotations


class TickClock:
    """Counts steps; reports how many interval boundaries each advance crossed.

    The boundary at step ``k * interval`` belongs to the advance that
    REACHES it: ``advance(n)`` returns ``floor((steps + n) / interval) -
    floor(steps / interval)``, so every boundary is counted exactly once
    across any partition of the step stream into advances.
    """

    def __init__(self, interval: int):
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        self.interval = int(interval)
        self.steps = 0

    def advance(self, n: int = 1) -> int:
        """Advance by ``n`` steps; return the number of ticks now due."""
        if n < 0:
            raise ValueError(f"cannot advance by {n} steps")
        ticks = (self.steps + n) // self.interval - self.steps // self.interval
        self.steps += n
        return ticks
