"""Sharded checkpointing with atomic commits, async writes, elastic restore.

Fault-tolerance contract (DESIGN.md §6):
  * save(step, tree): leaves are written one file per leaf (npy) under a
    step directory; the directory is committed by atomic rename, so a crash
    mid-save never corrupts the latest-good checkpoint;
  * writes can run on a background thread (async=True) double-buffered off
    the host copies so training doesn't stall;
  * restore(mesh=...) reassembles leaves and device_puts them with the
    CURRENT mesh's shardings — elastic remesh: a checkpoint written on a
    16x16 pod restores onto 2x16x16 (or a 2-device test mesh) unchanged;
  * keep=N garbage-collects old steps; latest_step() scans for resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int, tmp=False) -> str:
        return os.path.join(self.dir, ("tmp_" if tmp else "") + f"step_{step:010d}")

    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_")]
        return max(steps) if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        # Snapshot to host memory synchronously (cheap), write async.
        # Non-native dtypes (bfloat16) are stored as uint16 bit patterns
        # with the true dtype recorded in meta — np.load of ml_dtypes
        # arrays otherwise round-trips as void and can't be cast back.
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host, dtypes = [], []
        for l in leaves:
            arr = np.asarray(l)
            dtypes.append(str(arr.dtype))
            if arr.dtype.kind not in "biufc":   # bfloat16 & friends
                arr = arr.view(np.uint16) if arr.dtype.itemsize == 2 \
                    else arr.view(np.uint8)
            host.append(arr)
        meta = {"n_leaves": len(host), "treedef": str(treedef),
                "dtypes": dtypes}

        def write():
            tmp = self._step_dir(step, tmp=True)
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)             # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, like: Any, shardings: Any | None = None) -> Any:
        """Restore into the structure of ``like``; device_put with
        ``shardings`` (pytree matching ``like``) for elastic remesh."""
        d = self._step_dir(step)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        n = len(leaves_like)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        host = []
        for i, l in enumerate(leaves_like):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            want = meta.get("dtypes", [None] * n)[i]
            if want and arr.dtype.kind in "ui" and want not in (str(arr.dtype),):
                try:
                    import ml_dtypes
                    arr = arr.view(np.dtype(want))
                except TypeError:
                    pass
            if hasattr(l, "dtype") and arr.dtype != l.dtype:
                arr = arr.astype(l.dtype)
            host.append(arr)
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            dev = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
        else:
            dev = [jax.device_put(h) for h in host]
        return jax.tree_util.tree_unflatten(treedef, dev)
