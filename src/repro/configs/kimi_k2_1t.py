"""kimi-k2-1t-a32b [moe] — 61L d=7168 64H (kv=8) v=163840, 384e top-8.

Trillion-parameter MoE: 1 dense prologue layer + 60 MoE layers, expert
ff=2048, 1 shared expert.  THE flagship NeoMem expert-tiering target.
[arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128, rope_theta=50000.0,
    pattern=("moe",),
    moe=MoECfg(n_experts=384, top_k=8, expert_ff=2048, shared_ff=2048,
               n_dense_prologue=1, dense_ff=18432, bias_free_balance=True),
)

SMOKE_CONFIG = ArchConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
    pattern=("moe",),
    moe=MoECfg(n_experts=8, top_k=2, expert_ff=64, shared_ff=64,
               n_dense_prologue=1, dense_ff=128, bias_free_balance=True),
)
