"""deepseek-v3-671b [moe] — 61L d=7168 128H MLA v=129280, 256e top-8 + MTP.

MLA (latent KV), 3 dense prologue layers, 1 shared + 256 routed experts
top-8 with aux-loss-free balancing, multi-token-prediction head.
[arXiv:2412.19437; hf]
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, head_dim=128, rope_theta=10000.0,
    pattern=("moe",), mtp=True,
    mla=MLACfg(q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoECfg(n_experts=256, top_k=8, expert_ff=2048, shared_ff=2048,
               n_dense_prologue=3, dense_ff=18432, bias_free_balance=True),
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab=256, head_dim=16,
    pattern=("moe",), mtp=True,
    mla=MLACfg(q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16),
    moe=MoECfg(n_experts=8, top_k=2, expert_ff=64, shared_ff=64,
               n_dense_prologue=1, dense_ff=128, bias_free_balance=True),
)
