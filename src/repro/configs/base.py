"""Architecture config schema + the assigned input-shape registry."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    expert_ff: int
    shared_ff: int = 0
    n_dense_prologue: int = 0      # leading dense layers (deepseek: 3, kimi: 1)
    dense_ff: int = 0              # ffn width of the dense prologue layers
    bias_free_balance: bool = True  # DeepSeek-style aux-loss-free router bias


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    headdim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm: str = "rms"            # rms | rms+1 | ln
    mlp: str = "swiglu"          # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    pattern: tuple[str, ...] = ("attn",)
    # gemma2-isms
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0              # sliding window for attn_local blocks
    attn_scale: float | None = None
    post_norm: bool = False
    embed_scale: bool = False    # gemma multiplies embeddings by sqrt(d)
    # family extensions
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    mlstm_heads: int = 4
    # vlm / audio frontends (stubs produce the aux embeddings)
    n_aux_tokens: int = 0        # image patch tokens / audio frames
    encoder_layers: int = 0      # whisper encoder depth
    mtp: bool = False            # deepseek multi-token-prediction head

    @property
    def n_groups(self) -> int:
        body = self.n_layers - (self.moe.n_dense_prologue if self.moe else 0) \
            - self.encoder_layers
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} body layers not divisible by pattern "
            f"{len(self.pattern)}")
        return body // len(self.pattern)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    def active_params(self) -> float:
        """Analytic active-parameter count (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding (tied head counted once; lm head flops counted via 6ND anyway)
        for kind in self.pattern * self.n_groups:
            n += self._block_params(kind, active=True)
        if self.moe and self.moe.n_dense_prologue:
            n += self.moe.n_dense_prologue * self._block_params("attn_dense", active=True)
        if self.encoder_layers:
            n += self.encoder_layers * self._block_params("enc", active=True)
        return float(n)

    def total_params(self) -> float:
        d, v = self.d_model, self.vocab
        n = v * d
        for kind in self.pattern * self.n_groups:
            n += self._block_params(kind, active=False)
        if self.moe and self.moe.n_dense_prologue:
            n += self.moe.n_dense_prologue * self._block_params("attn_dense", active=False)
        if self.encoder_layers:
            n += self.encoder_layers * self._block_params("enc", active=False)
        return float(n)

    def _block_params(self, kind: str, active: bool) -> float:
        d = self.d_model
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.d_nope + m.d_rope)
                    + d * (m.kv_lora + m.d_rope)
                    + m.kv_lora * self.n_heads * (m.d_nope + m.d_v)
                    + self.n_heads * m.d_v * d)
        mlp_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        ffn = mlp_mult * d * self.d_ff
        if kind.startswith("attn_dense") and self.moe:
            return attn + mlp_mult * d * self.moe.dense_ff
        if kind == "moe":
            e_used = self.moe.top_k if active else self.moe.n_experts
            moe_ffn = e_used * 3 * d * self.moe.expert_ff \
                + 3 * d * self.moe.shared_ff + d * self.moe.n_experts
            return attn + moe_ffn
        if kind == "mamba":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.headdim
            return d * (2 * d_in + 2 * s.n_groups * s.d_state + nh) + d_in * d
        if kind in ("mlstm", "slstm"):
            return 5 * d * d
        if kind in ("cross", "enc", "dec"):
            return attn + ffn + (attn if kind == "dec" else 0)
        if kind == "shared_attn":
            # shared weights: count once across all groups when inactive?
            # counted per-use for FLOPs purposes (active) — weight reuse.
            return attn + ffn
        return attn + ffn


# ---------------------------------------------------------------------------
# Assigned input shapes (identical across the 10 archs)
# ---------------------------------------------------------------------------

SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode_paged", seq_len=524288, global_batch=1),
}

# per-arch skips, with reasons recorded in DESIGN.md §5
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-base", "long_500k"):
        "enc-dec audio model; 500K-token decoder context is meaningless "
        "(30s audio, 448-token decoder). Noted in DESIGN.md.",
}


def cell_is_skipped(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))
