"""qwen1.5-4b [dense] — 40L d=2560 20H (kv=20) ff=6912 v=151936, QKV bias.

[hf:Qwen/Qwen1.5-4B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, head_dim=128, qkv_bias=True,
)

SMOKE_CONFIG = ArchConfig(
    name="qwen1.5-4b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16, qkv_bias=True,
)
