"""llama3.2-3b [dense] — 28L d=3072 24H (kv=8) ff=8192 v=128256.

Small llama3.  [hf:meta-llama/Llama-3.2-3B; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab=128256, head_dim=128, rope_theta=500000.0,
)

SMOKE_CONFIG = ArchConfig(
    name="llama3.2-3b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, rope_theta=500000.0,
)
