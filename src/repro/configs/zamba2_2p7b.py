"""zamba2-2.7b [hybrid] — 54L d=2560 32H (kv=32) ff=10240 v=32000, ssm=64.

Mamba2 blocks + a SHARED attention(+MLP) block applied every 6th position
(one weight set reused across all 9 groups).  [arXiv:2411.15242; hf]
"""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, rope_theta=10000.0,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm=SSMCfg(d_state=64, headdim=64, expand=2, d_conv=4, n_groups=1),
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16,
    pattern=("mamba", "mamba", "shared_attn"),
    ssm=SSMCfg(d_state=16, headdim=16, expand=2, d_conv=4, n_groups=1, chunk=16),
)
