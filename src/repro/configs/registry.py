"""Architecture registry: --arch <id> -> ArchConfig."""
from __future__ import annotations

import importlib

ARCHS = [
    "llama-3.2-vision-11b",
    "zamba2-2.7b",
    "gemma2-27b",
    "llama3.2-3b",
    "stablelm-1.6b",
    "qwen1.5-4b",
    "whisper-base",
    "xlstm-1.3b",
    "kimi-k2-1t-a32b",
    "deepseek-v3-671b",
]

_MODULES = {
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "zamba2-2.7b": "zamba2_2p7b",
    "gemma2-27b": "gemma2_27b",
    "llama3.2-3b": "llama32_3b",
    "stablelm-1.6b": "stablelm_1p6b",
    "qwen1.5-4b": "qwen15_4b",
    "whisper-base": "whisper_base",
    "xlstm-1.3b": "xlstm_1p3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "deepseek-v3-671b": "deepseek_v3_671b",
}


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str):
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE_CONFIG


def list_archs():
    return list(ARCHS)
