"""whisper-base [audio] — 6L enc + 6L dec, d=512 8H ff=2048 v=51865.

Encoder-decoder; the conv audio frontend is a STUB (input_specs provide
1500 precomputed frame embeddings).  Positional scheme: RoPE substituted
for Whisper's learned absolute embeddings (noted in DESIGN.md — systems
behavior is unaffected).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=12, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64, norm="ln", mlp="gelu",
    pattern=("dec",), n_aux_tokens=1500,
)

SMOKE_CONFIG = ArchConfig(
    name="whisper-base-smoke", family="audio",
    n_layers=4, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16, norm="ln", mlp="gelu",
    pattern=("dec",), n_aux_tokens=25,
)
