"""xlstm-1.3b [ssm] — 48L d=2048 4H v=50304, d_ff=0 (block-internal proj).

sLSTM + mLSTM blocks at 1:7 ratio.  Attention-free: NeoMem applies to
embedding rows only (DESIGN.md §5).  [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=512, mlstm_heads=4,
    pattern=("mlstm",) * 7 + ("slstm",),
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=256, head_dim=16, mlstm_heads=4,
    pattern=("mlstm", "slstm"),
)
