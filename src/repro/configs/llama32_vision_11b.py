"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (kv=8) ff=14336 v=128256.

Cross-attention image layers every 5th position (8 cross layers in 40);
vision frontend is a STUB: input_specs provide precomputed patch embeddings
(n_aux_tokens x d_model).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=500000.0,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    n_aux_tokens=1601, tie_embeddings=True,
)

SMOKE_CONFIG = ArchConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, rope_theta=500000.0,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    n_aux_tokens=17,
)
