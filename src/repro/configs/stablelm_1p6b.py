"""stablelm-1.6b [dense] — 24L d=2048 32H (kv=32) ff=5632 v=100352.

LayerNorm + qkv bias.  [hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, head_dim=64, norm="ln", qkv_bias=True,
)

SMOKE_CONFIG = ArchConfig(
    name="stablelm-1.6b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16, norm="ln", qkv_bias=True,
)
