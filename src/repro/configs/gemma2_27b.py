"""gemma2-27b [dense] — 46L d=4608 32H (kv=16) ff=36864 v=256000.

Local(4K window)/global alternating attention, logit softcaps (50 attn /
30 final), GeGLU, RMSNorm(1+w) with post-norms, query scale 144^-0.5.
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000, head_dim=128, norm="rms+1", mlp="geglu",
    pattern=("attn_local", "attn_global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, attn_scale=144.0 ** -0.5,
    post_norm=True, embed_scale=True,
)

SMOKE_CONFIG = ArchConfig(
    name="gemma2-27b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=256, head_dim=16, norm="rms+1", mlp="geglu",
    pattern=("attn_local", "attn_global"), window=8,
    attn_softcap=50.0, final_softcap=30.0, attn_scale=16.0 ** -0.5,
    post_norm=True, embed_scale=True,
)
