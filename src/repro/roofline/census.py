"""HLO census: trip-count-aware FLOPs and collective-bytes accounting.

XLA's HloCostAnalysis counts while-loop bodies ONCE (scan bodies, grad-accum
loops), which silently undercounts a scan-over-layers program by ~G x M.
This module parses the compiled HLO text instead:

  * builds the computation call graph (fusions/calls/while bodies),
  * multiplies by ``known_trip_count`` on while ops,
  * counts dot FLOPs (2 * numel(result) * contraction) — the dominant term,
  * sums collective op bytes (result-shape proxy) with execution counts,

giving the per-device HLO_FLOPs and collective_bytes the roofline needs.
Validated against analytic MODEL_FLOPS in tests (within the remat factor).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
               "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[":{ ]+n["\s:]+"?(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shapes_of(type_str: str):
    """All (dtype, shape) in a possibly-tuple type string prefix."""
    out = []
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _numel(shape) -> int:
    return int(math.prod(shape)) if shape else 1


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    dtype: str
    shape: list
    line: str


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[Instr] = []
        self.shapes: dict[str, tuple] = {}   # first result only
        self.flops = 0.0
        self.coll = defaultdict(lambda: [0, 0.0])  # op -> [count, bytes]
        self.calls: list[tuple[str, float]] = []   # (callee, multiplier)


_OPCODE = re.compile(
    r"^(?:\(?[a-z][a-z0-9]*\[[0-9,]*\][^=]*?\s|\s*)?([a-z][a-z0-9\-]*)\(")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry: str | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # opcode = first op-word followed by "(" after the (possibly tuple) type
        opm = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
        opcode = opm.group(1) if opm else ""
        # result shapes: everything before the opcode token (handles tuples)
        shapes = _shapes_of(rhs[: opm.start()] if opm else rhs)
        dt, shp = (shapes[0] if shapes else ("f32", []))
        inst = Instr(name, opcode, dt, shp, line)
        cur.instrs.append(inst)
        cur.shapes[name] = (dt, shp)

        if opcode == "dot":
            # flops = 2 * numel(result) * contraction size (from lhs operand)
            cm = _CONTRACT.search(line)
            contract = 1
            if cm:
                dims = [int(d) for d in cm.group(1).split(",") if d != ""]
                # operands may carry an inline type ("dot(f32[64,32]{1,0} %a,")
                # or be bare names ("dot(%a,") depending on the XLA version
                ops = re.search(
                    r"dot\(\s*(?:[a-z][a-z0-9]*\[([0-9,]*)\](?:\{[^}]*\})?\s+)?"
                    r"%?([\w\.\-]+)", line)
                lhs_shape = None
                if ops and ops.group(1) is not None:
                    lhs_shape = [int(d) for d in ops.group(1).split(",")] \
                        if ops.group(1) else []
                elif ops and ops.group(2) in cur.shapes:
                    lhs_shape = cur.shapes[ops.group(2)][1]
                if lhs_shape is not None:
                    for d in dims:
                        if d < len(lhs_shape):
                            contract *= lhs_shape[d]
            cur.flops += 2.0 * _numel(shp) * contract
        elif opcode in ("convolution",):
            cur.flops += 2.0 * _numel(shp) * 9  # coarse; convs are stubs here
        elif opcode in COLLECTIVES:
            nbytes = sum(_numel(s) * DTYPE_BYTES[d] for d, s in shapes)
            cur.coll[opcode][0] += 1
            cur.coll[opcode][1] += nbytes

        if opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            tm = _TRIP.search(line)
            trips = float(tm.group(1)) if tm else 1.0
            if body:
                cur.calls.append((body.group(1), trips))
            if cond:
                cur.calls.append((cond.group(1), trips))
        else:
            for cm2 in _CALLS.finditer(line):
                if opcode != "while":
                    cur.calls.append((cm2.group(1), 1.0))
            bm = _COND_BRANCHES.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    cur.calls.append((b.strip().lstrip("%"), 1.0))

    comps["__entry__"] = comps.get(entry, next(iter(comps.values())))
    return comps


def census(text: str) -> dict:
    comps = parse_hlo(text)
    entry = comps["__entry__"]
    memo: dict[str, tuple] = {}

    def total(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return 0.0, {}
        memo[name] = (0.0, {})     # cycle guard
        c = comps[name]
        fl = c.flops
        coll = {k: list(v) for k, v in c.coll.items()}
        for callee, mult in c.calls:
            cf, cc = total(callee, depth + 1)
            fl += mult * cf
            for k, (n, b) in cc.items():
                cur = coll.setdefault(k, [0, 0.0])
                cur[0] += mult * n
                cur[1] += mult * b
        memo[name] = (fl, coll)
        return memo[name]

    fl, coll = total(entry.name)
    return {
        "flops_per_device": fl,
        "collectives": {k: {"count": v[0], "bytes": v[1]}
                        for k, v in coll.items()},
        "collective_bytes_per_device": sum(v[1] for v in coll.values()),
    }
