"""Roofline analysis: dryrun JSON -> three-term table (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    compute    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
    memory     = HLO_bytes_per_chip / HBM_bw              [s]
    collective = collective_bytes_per_chip / link_bw      [s]

HLO_FLOPs comes from the trip-count-aware census (repro.roofline.census) —
XLA's cost_analysis undercounts scan bodies (counted once), which we record
for reference but do not use.  HLO bytes come from cost_analysis
("bytes accessed", whole-program; divided by chips).  MODEL_FLOPS is the
analytic useful-work count; its ratio to HLO_FLOPs exposes remat /
redundancy waste.

Hardware model (TPU v5e-class, from the brief):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def chips(mesh_name: str) -> int:
    return 512 if mesh_name == "multi" else 256


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step (whole program, all chips)."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    b, s = shp["global_batch"], shp["seq_len"]
    n_act = cfg.active_params()
    if shp["kind"] == "train":
        tokens = b * s
        flops = 6.0 * n_act * tokens
        # attention quadratic term: 12 * L_attn * d_head_total * S^2 * B / 2
        flops += _attn_flops(cfg, b, s, train=True)
        return flops
    if shp["kind"] == "prefill":
        tokens = b * s
        return 2.0 * n_act * tokens + _attn_flops(cfg, b, s, train=False)
    if shp["kind"] == "decode":
        # one token per sequence, attention over the full cache
        return 2.0 * n_act * b + _attn_decode_flops(cfg, b, s)
    # decode_paged: attention over resident hot pages only
    from repro.launch.specs import HOT_SLOTS, PAGE_T
    resident = min(HOT_SLOTS * PAGE_T, s)
    return 2.0 * n_act * b + _attn_decode_flops(cfg, b, resident)


def _n_attn_layers(cfg) -> int:
    kinds = cfg.pattern * cfg.n_groups
    n = sum(1 for k in kinds if "attn" in k or k in ("moe", "cross", "dec"))
    if cfg.moe:
        n += cfg.moe.n_dense_prologue
    return n


def _attn_flops(cfg, b, s, train: bool) -> float:
    mult = 3.0 if train else 1.0   # fwd + 2x bwd
    dh_tot = cfg.n_heads * cfg.head_dim
    if cfg.mla:
        dh_tot = cfg.n_heads * (cfg.mla.d_nope + cfg.mla.d_rope)
    per_layer = 2.0 * 2.0 * b * s * s / 2 * dh_tot   # QK^T + PV, causal half
    return mult * _n_attn_layers(cfg) * per_layer


def _attn_decode_flops(cfg, b, cache_len) -> float:
    dh_tot = cfg.n_heads * cfg.head_dim
    if cfg.mla:
        dh_tot = cfg.n_heads * (cfg.mla.d_nope + cfg.mla.d_rope)
    return 2.0 * 2.0 * b * cache_len * dh_tot * _n_attn_layers(cfg)


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    hbm_gb_per_chip: float
    note: str = ""

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / bound — how close the USEFUL work runs to
        the hardware bound if perfectly overlapped."""
        n = chips(self.mesh)
        useful_t = self.model_flops / n / PEAK_FLOPS
        return useful_t / max(self.step_time_lower_bound, 1e-12)


def analyze(results: dict) -> list[RooflineRow]:
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok":
            continue
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        n = chips(mesh)
        census = rec.get("census", {})
        hlo_flops_dev = census.get("flops_per_device", 0.0)
        coll_dev = census.get("collective_bytes_per_device", 0.0)
        bytes_total = rec.get("cost", {}).get("bytes_accessed", 0.0)

        compute = hlo_flops_dev / PEAK_FLOPS
        memory = (bytes_total / n) / HBM_BW
        collective = coll_dev / LINK_BW

        mf = model_flops(arch, shape)
        hlo_total = hlo_flops_dev * n
        mem = rec.get("memory", {})
        hbm = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
               - mem.get("alias_bytes", 0)) / 1e9

        terms = {"compute": compute, "memory": memory, "collective": collective}
        dom = max(terms, key=terms.get)
        rows.append(RooflineRow(
            arch=arch, shape=shape, mesh=mesh,
            compute_s=compute, memory_s=memory, collective_s=collective,
            dominant=dom, model_flops=mf, hlo_flops_total=hlo_total,
            useful_ratio=mf / max(hlo_total, 1.0),
            hbm_gb_per_chip=hbm,
        ))
    return rows


def to_markdown(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | HBM GB/chip | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
                 f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
                 f"{r.hbm_gb_per_chip:.1f} | {r.useful_ratio:.2f} | "
                 f"{r.roofline_fraction:.2f} |\n")
    return hdr + body


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.json")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    rows = analyze(results)
    md = to_markdown(rows)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)


if __name__ == "__main__":
    main()
