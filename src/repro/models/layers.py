"""Shared model building blocks: norms, rotary embeddings, MLPs, embeddings.

Functional style: every block is (params pytree, pure apply fn).  Params are
bf16 by default with fp32 norm scales; softmax/rotary math is fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DTYPE = jnp.bfloat16


def _norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"] + 1.0 if plus_one else p["scale"]
    return (y * scale).astype(x.dtype)


def _ln_init(d):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def make_norm(kind: str, d: int):
    if kind == "rms":
        return _norm_init(d)
    if kind == "rms+1":  # gemma-style (weight stored as w, applied as 1+w)
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return _ln_init(d)


def apply_norm(kind: str, p, x):
    if kind == "rms":
        return rmsnorm(p, x)
    if kind == "rms+1":
        return rmsnorm(p, x, plus_one=True)
    return layernorm(p, x)


# -- rotary -----------------------------------------------------------------

def rope_freqs(dh: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs --------------------------------------------------------------------

def mlp_init(key, d: int, f: int, kind: str = "swiglu", dtype=DTYPE):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"w_out": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_in"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dtype)
        p["w_gate"] = (jax.random.normal(k2, (d, f)) * s_in).astype(dtype)
    else:  # gelu
        p["w_in"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dtype)
        p["b_in"] = jnp.zeros((f,), dtype)
        p["b_out"] = jnp.zeros((d,), dtype)
    return p


def mlp_apply(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
        return h @ p["w_out"]
    if kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_in"])
        return h @ p["w_out"]
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"], approximate=True)
    return h @ p["w_out"] + p["b_out"]


# -- embedding / logits --------------------------------------------------------

def embed_init(key, v: int, d: int, dtype=DTYPE):
    return {"table": (jax.random.normal(key, (v, d)) * (d ** -0.5)).astype(dtype)}


def embed_apply(p, tokens):
    return p["table"][tokens]


def logits_apply(p, x, softcap: float = 0.0):
    logits = (x @ p["table"].T).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
