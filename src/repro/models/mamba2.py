"""Mamba2 (SSD) block — chunked state-space duality implementation.

Training path: chunked SSD (Dao & Gu 2024): intra-chunk attention-like term +
inter-chunk recurrent state carry via lax.scan over chunks.  Decode path:
single-token recurrent state update (state (B, H, P, N) is the whole cache —
O(1) in sequence length, which is what makes long_500k native for zamba2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE


def mamba2_init(key, d, *, d_state=64, expand=2, headdim=64, d_conv=4,
                n_groups=1, dtype=DTYPE):
    d_inner = expand * d
    n_heads = d_inner // headdim
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner + 2 * n_groups * d_state))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * n_groups * d_state,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) * d_inner ** -0.5).astype(dtype),
    }
    return p


def _dims(p, d, headdim, n_groups, d_state):
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // headdim
    return d_inner, n_heads


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    b = zxbcdt[..., 2 * d_inner:2 * d_inner + n_groups * d_state]
    c = zxbcdt[..., 2 * d_inner + n_groups * d_state:
               2 * d_inner + 2 * n_groups * d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, x, b, c, dt


def _conv1d(x, w, b, cache=None):
    """Causal depthwise conv.  x: (B,S,C); w: (K,C).  cache: (B,K-1,C)."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_cache = xp[:, -(k - 1):, :]
    return jax.nn.silu(out + b), new_cache


def mamba2_apply(p, u, *, headdim=64, n_groups=1, d_state=64, chunk=128):
    """u: (B,S,D) -> (B,S,D).  Chunked SSD scan."""
    bsz, s, d = u.shape
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // headdim

    zxbcdt = u @ p["in_proj"]
    z, x, b, c, dt = _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads)
    xbc, _ = _conv1d(jnp.concatenate([x, b, c], -1), p["conv_w"], p["conv_b"])
    x = xbc[..., :d_inner].reshape(bsz, s, n_heads, headdim)
    b = xbc[..., d_inner:d_inner + n_groups * d_state].reshape(bsz, s, n_groups, d_state)
    c = xbc[..., d_inner + n_groups * d_state:].reshape(bsz, s, n_groups, d_state)
    # broadcast groups over heads
    hpg = n_heads // n_groups
    b = jnp.repeat(b, hpg, axis=2)                           # (B,S,H,N)
    c = jnp.repeat(c, hpg, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["A_log"])                                 # (H,)
    da = dt * a                                              # (B,S,H) log-decay

    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, n_heads, headdim).astype(jnp.float32)
    bc_ = b.reshape(bsz, nc, chunk, n_heads, d_state).astype(jnp.float32)
    cc = c.reshape(bsz, nc, chunk, n_heads, d_state).astype(jnp.float32)
    dac = da.reshape(bsz, nc, chunk, n_heads)
    dtc = dt.reshape(bsz, nc, chunk, n_heads)

    cum = jnp.cumsum(dac, axis=2)                            # (B,NC,Q,H)
    # intra-chunk: L[q,t] = exp(cum[q]-cum[t]) for t<=q.  Mask BEFORE exp:
    # exp of the (discarded) t>q entries can overflow and poison gradients.
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,NC,Q,Q,H)
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    l_mat = jnp.exp(jnp.where(causal, decay, -1e30))
    scores = jnp.einsum("bnqhs,bnths->bnqth", cc, bc_)        # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bnqth,bnqth,bnthp->bnqhp",
                         scores, l_mat, xc * dtc[..., None])

    # chunk states: S_n = sum_t exp(cum_end - cum_t) * b_t x_t^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,NC,Q,H)
    states = jnp.einsum("bnth,bnths,bnthp->bnhsp",
                        decay_to_end * dtc, bc_, xc)          # (B,NC,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,NC,H)

    def carry_fn(st, inp):
        s_n, g_n = inp                                       # (B,H,N,P), (B,H)
        new = st * g_n[..., None, None] + s_n
        return new, st                                       # emit state BEFORE chunk

    init = jnp.zeros((bsz, n_heads, d_state, headdim), jnp.float32)
    _, prev_states = jax.lax.scan(
        carry_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,NC,H,N,P)

    # inter-chunk: y_t += C_t exp(cum_t) S_prev
    y_inter = jnp.einsum("bnqhs,bnhsp->bnqhp",
                         cc * jnp.exp(cum)[..., None], prev_states)
    y = (y_intra + y_inter).reshape(bsz, s, n_heads, headdim)
    y = y + xc.reshape(bsz, s, n_heads, headdim) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (Mamba2's norm-then-gate)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm"]
    return (yf.astype(u.dtype)) @ p["out_proj"]


def mamba2_init_cache(batch, p, *, headdim=64, n_groups=1, d_state=64):
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // headdim
    k = p["conv_w"].shape[0]
    return {
        "ssm": jnp.zeros((batch, n_heads, d_state, headdim), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, d_inner + 2 * n_groups * d_state), DTYPE),
    }


def mamba2_decode(p, u_t, cache, *, headdim=64, n_groups=1, d_state=64):
    """u_t: (B,1,D) -> (y_t, cache).  O(1) recurrent update."""
    bsz = u_t.shape[0]
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // headdim
    zxbcdt = u_t @ p["in_proj"]
    z, x, b, c, dt = _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads)
    xbc, conv_cache = _conv1d(jnp.concatenate([x, b, c], -1),
                              p["conv_w"], p["conv_b"], cache["conv"])
    x = xbc[..., :d_inner].reshape(bsz, n_heads, headdim)
    b = xbc[..., d_inner:d_inner + n_groups * d_state].reshape(bsz, n_groups, d_state)
    c = xbc[..., d_inner + n_groups * d_state:].reshape(bsz, n_groups, d_state)
    hpg = n_heads // n_groups
    b = jnp.repeat(b, hpg, axis=1).astype(jnp.float32)
    c = jnp.repeat(c, hpg, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    g = jnp.exp(dt * a)                                       # (B,H)
    st = cache["ssm"] * g[..., None, None] + jnp.einsum(
        "bhs,bhp->bhsp", b * dt[..., None], x.astype(jnp.float32))
    y = jnp.einsum("bhs,bhsp->bhp", c, st)
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm"]
    return (yf.astype(u_t.dtype)) @ p["out_proj"], {"ssm": st, "conv": conv_cache}
