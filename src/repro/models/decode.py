"""Serving paths: prefill, full-cache decode, NeoMem paged long-context decode.

Cache layouts (stacked by pattern group so decode scans over groups):
  * attn blocks ......... {"k","v"}: (G, B, Smax, Hkv, dh)
  * MLA blocks .......... {"c_kv","k_rope"}: (G, B, Smax, kv_lora / d_rope)
  * mamba blocks ........ {"ssm","conv"} O(1) state
  * m/sLSTM blocks ...... {"c","n","m"} O(1) state
  * paged attn blocks ... {"k_pages","v_pages"}: (G, B, n_slots, T, Hkv, dh)
                          + {"page_len": (G, B, n_slots), "page_id": ...}

The paged cache IS the NeoMem fast tier: n_slots hot page slots per layer
group; the slow tier (full history) lives host-side and is managed by the
kv_tier adapter + daemon between steps.  The newest page is appended
in-step; page promotion/demotion happens at migration intervals.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models.layers import apply_norm, embed_apply, logits_apply, mlp_apply
from repro.kernels.paged_attn import ops as pa_ops
from repro.tiering.migrate import lookup_rows as _tier_lookup_rows


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _pos_col(pos: jax.Array, b: int) -> jax.Array:
    """Decode positions as a (B, 1) column: ``pos`` is the scalar lockstep
    counter (single-request serving) or a (B,) vector of per-lane positions
    (continuous batching — each lane advances independently, DESIGN.md §9)."""
    pos = jnp.asarray(pos)
    return jnp.broadcast_to(pos.reshape(-1, 1) if pos.ndim else pos, (b, 1))


def _embed_tokens(params, token, tiered):
    """Token embedding, served from the NeoMem tiered store when bound.

    With a ``tiered["embeddings"]`` view ({"fast", "slow", "page_slot",
    "rows_per_page"}), the row is gathered THROUGH the device-resident
    placement table inside the caller's jit (DESIGN.md §10): fast-buffer
    copy when the vocab row-block is promoted, slow-store fallback
    otherwise — bit-exact either way (tiers are inclusive), so the tiered
    read is a drop-in for the dense table gather."""
    tv = (tiered or {}).get("embeddings")
    if tv is None:
        return embed_apply(params["embed"], token)
    rpp = tv["rows_per_page"]
    rows = _tier_lookup_rows(tv["fast"], tv["slow"], tv["page_slot"],
                             token // rpp,
                             scale=tv.get("scale"))  # (B, 1, rpp, d)
    r = (token % rpp)[..., None, None]
    return jnp.take_along_axis(rows, r, axis=-2)[..., 0, :]


def _attn_cache(cfg, batch, smax, dtype):
    if cfg.mla is not None:
        return attn.mla_init_cache(batch, smax, cfg.mla.kv_lora, cfg.mla.d_rope, dtype)
    return attn.gqa_init_cache(batch, smax, cfg.n_kv_heads, cfg.head_dim, dtype)


def _block_cache(cfg: ArchConfig, kind: str, batch: int, smax: int, dtype):
    if kind == "mamba":
        s = cfg.ssm
        p_fake = {"out_proj": jnp.zeros((s.expand * cfg.d_model, cfg.d_model)),
                  "conv_w": jnp.zeros((s.d_conv, 1))}
        return m2.mamba2_init_cache(batch, p_fake, headdim=s.headdim,
                                    n_groups=s.n_groups, d_state=s.d_state)
    if kind == "mlstm":
        return xl.mlstm_init_cache(batch, cfg.d_model, cfg.mlstm_heads)
    if kind == "slstm":
        return xl.slstm_init_cache(batch, cfg.d_model)
    return _attn_cache(cfg, batch, smax, dtype)


def init_cache(cfg: ArchConfig, batch: int, smax: int, dtype=jnp.bfloat16):
    """Full (dense) KV cache pytree, group-stacked."""
    def one_group(_):
        return [_block_cache(cfg, kind, batch, smax, dtype) for kind in cfg.pattern]
    g = cfg.n_groups
    caches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (g,) + x.shape), one_group(0))
    out = {"blocks": caches, "pos": jnp.zeros((), jnp.int32)}
    if cfg.moe and cfg.moe.n_dense_prologue:
        out["prologue"] = [
            _block_cache(cfg, "attn", batch, smax, dtype)
            for _ in range(cfg.moe.n_dense_prologue)
        ]
    return out


def init_paged_cache(cfg: ArchConfig, batch: int, n_slots: int, page_t: int,
                     dtype=jnp.bfloat16, per_lane_pos: bool = False):
    """NeoMem fast-tier paged cache for attention blocks; O(1) SSM states.

    ``per_lane_pos=True`` makes ``pos`` a (batch,) vector so each batch row
    (a continuous-batching lane) advances independently — required by the
    request scheduler, which resets/preempts lanes mid-flight (DESIGN.md §9).
    """
    def one(kind):
        if kind in ("mamba", "mlstm", "slstm"):
            return _block_cache(cfg, kind, batch, 0, dtype)
        if cfg.mla is not None:
            dk = cfg.mla.kv_lora + cfg.mla.d_rope
            dv = cfg.mla.kv_lora
            hkv = 1
        else:
            dk = dv = cfg.head_dim
            hkv = cfg.n_kv_heads
        return {
            "k_pages": jnp.zeros((batch, n_slots, page_t, hkv, dk), dtype),
            "v_pages": jnp.zeros((batch, n_slots, page_t, hkv, dv), dtype),
            "page_len": jnp.zeros((batch, n_slots), jnp.int32),
            "cur_slot": jnp.zeros((batch,), jnp.int32),
        }
    g = cfg.n_groups
    caches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (g,) + x.shape),
        [one(kind) for kind in cfg.pattern])
    pos = jnp.zeros((batch,) if per_lane_pos else (), jnp.int32)
    out = {"blocks": caches, "pos": pos}
    if cfg.moe and cfg.moe.n_dense_prologue:
        out["prologue"] = [one("attn") for _ in range(cfg.moe.n_dense_prologue)]
    return out


# ---------------------------------------------------------------------------
# prefill (full sequence -> cache)  — reuses the training forward for hidden
# states, then projects K/V per layer.  For dry-run purposes we lower a
# dedicated prefill that computes logits for the last token + the full cache.
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params, tokens, *, aux_embeds=None, remat=True,
            ep_axes=None):
    """Returns (last-token logits, forward aux) — the dry-run lowering path.

    Uses the training forward for the full-sequence pass; it does NOT build
    a decode cache (the serve engine uses :func:`prefill_dense` /
    :func:`prefill_paged`, which fill the cache in the same pass).
    """
    from repro.models.transformer import forward
    x, aux = forward(cfg, params, tokens, aux_embeds=aux_embeds, remat=remat,
                     ep_axes=ep_axes)
    logits = logits_apply(params["embed"], x[:, -1:], cfg.final_softcap)
    # NOTE: the dry-run prefill cost is dominated by forward(); cache
    # materialization is modeled by re-projecting K/V in the serve adapter.
    return logits, aux


def merge_cache(old, new, active):
    """Commit a decode-step cache update only for ``active`` lanes.

    ``active`` is a (B,) bool mask over the batch (lane) axis; inactive
    lanes keep their OLD cache leaves — position, ring bookkeeping, page
    payloads and O(1) recurrent states all stay frozen, so a lane can sit
    out an engine step (or a chunked-prefill scan step) without drifting.
    Blocks leaves are group-stacked (G, B, ...); prologue leaves are
    (B, ...); ``pos`` must be the per-lane (B,) vector.
    """
    def mask(o, n, baxis):
        act = active.reshape((1,) * baxis + active.shape
                             + (1,) * (n.ndim - baxis - 1))
        return jnp.where(act, n, o)
    out = {"blocks": jax.tree.map(lambda o, n: mask(o, n, 1),
                                  old["blocks"], new["blocks"])}
    if jnp.ndim(new["pos"]) == 0:
        raise ValueError("merge_cache needs per-lane positions "
                         "(init_paged_cache(per_lane_pos=True))")
    out["pos"] = jnp.where(active, new["pos"], old["pos"])
    if "prologue" in old:
        out["prologue"] = jax.tree.map(lambda o, n: mask(o, n, 0),
                                       old["prologue"], new["prologue"])
    return out


def prefill_dense(cfg: ArchConfig, params, cache, tokens, *, aux_embeds=None,
                  ep_axes=None, tiered=None):
    """Single-pass dense prefill: ONE jitted scan of the decode-step body
    over the prompt, filling the cache and producing the last-token logits
    together (the prompt is never run twice).

    Returns ``(last-token logits (B, V), cache, streams)`` where
    ``streams["router"]`` stacks the per-step (G, n_moe, B, 1, k) expert
    stream on a leading prompt axis (None for dense-FFN archs) — one
    observation batch for the tiering daemon instead of S engine steps.
    """
    def body(cache, tok):
        logits, nc, streams = decode_step(
            cfg, params, cache, tok[:, None], aux_embeds=aux_embeds,
            ep_axes=ep_axes, return_streams=True, tiered=tiered)
        r = streams["router"]
        return nc, (logits[:, -1],
                    r if r is not None else jnp.zeros((0,), jnp.int32))
    cache, (logits_seq, router) = jax.lax.scan(
        body, cache, jnp.moveaxis(jnp.asarray(tokens, jnp.int32), 0, 1))
    return logits_seq[-1], cache, {
        "router": router if router.size else None}


def prefill_paged(cfg: ArchConfig, params, cache, tokens, *, page_t: int,
                  valid=None, active=None, ep_axes=None, smesh=None,
                  tiered=None, collect_mass: bool = False):
    """Chunked prefill through the paged ring: one jitted scan of the
    per-token paged decode body over a (B, C) prompt chunk.

    Each scan step IS :func:`decode_step_paged` on one token column, so the
    ring state after the chunk — page payloads, ``page_len``/``cur_slot``
    bookkeeping, per-lane positions — and the final logits are bit-exact
    with C token-at-a-time streaming calls; what the chunk removes is the
    per-token dispatch, host observation and daemon bookkeeping cost.

    ``valid`` (B, C) bool marks real tokens (False = ragged-tail padding: a
    padded step is a complete no-op for that lane, and the logits carried
    out are the last VALID step's).  ``active`` (B,) bool masks whole lanes
    — inactive lanes' cache leaves never change, so the serve engine can
    chunk-prefill one lane while other lanes' decode state sits untouched
    between their own steps (requires per-lane positions).

    Returns ``(last-valid logits (B, V) f32, cache, streams)``; streams
    stacks the per-step ``router`` / ``kv_mass`` streams on a leading chunk
    axis ((C, G, n_moe, B, 1, k) / (C, G, n_attn, B, S), or None).
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    b, _ = tokens.shape
    lane_act = None if active is None else jnp.asarray(active, bool)
    if valid is None and lane_act is None:
        step_act = None                      # every step fully live: no merge
    else:
        v = jnp.ones(tokens.shape, bool) if valid is None \
            else jnp.asarray(valid, bool)
        step_act = v if lane_act is None else v & lane_act[:, None]

    def body(carry, xs):
        cache, last = carry
        tok, act = xs
        logits, nc, streams = decode_step_paged(
            cfg, params, cache, tok[:, None], page_t=page_t, ep_axes=ep_axes,
            smesh=smesh, return_streams=True, tiered=tiered,
            collect_mass=collect_mass)
        step = logits[:, -1].astype(jnp.float32)
        if act is None:
            nc, last = nc, step
        else:
            nc = merge_cache(cache, nc, act)
            last = jnp.where(act[:, None], step, last)
        r, km = streams["router"], streams["kv_mass"]
        outs = (r if r is not None else jnp.zeros((0,), jnp.int32),
                km if km is not None else jnp.zeros((0,), jnp.float32))
        return (nc, last), outs

    xs = (jnp.moveaxis(tokens, 0, 1),
          None if step_act is None else jnp.moveaxis(step_act, 0, 1))
    last0 = jnp.zeros((b, cfg.vocab), jnp.float32)
    (cache, last), (router, kv_mass) = jax.lax.scan(body, (cache, last0), xs)
    return last, cache, {
        "router": router if router.size else None,
        "kv_mass": kv_mass if kv_mass.size else None,
    }


# ---------------------------------------------------------------------------
# single-token decode over the full cache
# ---------------------------------------------------------------------------

def _moe_block(p, cfg, h2, aux, ep_axes, tiered_moe):
    """The MoE position of a decode block: EP dispatch, or — when the serve
    engine passes the expert tier view for this position — the NeoMem
    EP-resident path: each selected expert's weight block is gathered
    through the device-resident placement table inside the jitted step
    (fast tier when promoted, slow store otherwise; DESIGN.md §10)."""
    if tiered_moe is not None:
        y, idx, _ = moe_lib.moe_apply_tiered(
            p["ffn"], h2, cfg.moe.top_k, bias=p.get("router_bias"),
            tier=tiered_moe["view"], group_id=tiered_moe["group_id"])
    else:
        y, idx, _ = moe_lib.moe_apply_ep(p["ffn"], h2, cfg.moe.top_k,
                                         bias=p.get("router_bias"),
                                         ep_axes=ep_axes)
    aux.setdefault("router_streams", []).append(idx)
    return y


def _decode_attn_block(p, cfg, kind, x_t, cache, pos, aux, ep_axes,
                       tiered_moe=None):
    h = apply_norm(cfg.norm, p["ln1"], x_t)
    window = cfg.window if kind == "attn_local" else 0
    if cfg.mla is not None:
        mla_kw = dataclasses.asdict(cfg.mla)
        o, cache = attn.mla_decode(p["attn"], h, cache, pos, h=cfg.n_heads,
                                   rope_theta=cfg.rope_theta, **mla_kw)
    else:
        o, cache = attn.gqa_decode(p["attn"], h, cache, pos, h=cfg.n_heads,
                                   hkv=cfg.n_kv_heads, dh=cfg.head_dim,
                                   rope_theta=cfg.rope_theta, window=window,
                                   softcap=cfg.attn_softcap, scale=cfg.attn_scale)
    if cfg.post_norm:
        o = apply_norm(cfg.norm, p["pn1"], o)
    x_t = x_t + o
    if kind == "cross" and aux.get("aux_embeds") is not None:
        hx = apply_norm(cfg.norm, p["lnx"], x_t)
        xo = attn.cross_apply(p["xattn"], hx, aux["aux_embeds"], h=cfg.n_heads,
                              hkv=cfg.n_kv_heads, dh=cfg.head_dim)
        x_t = x_t + (jnp.tanh(p["xgate"]) * xo.astype(jnp.float32)).astype(x_t.dtype)
    if kind == "dec" and aux.get("enc_out") is not None:
        hx = apply_norm(cfg.norm, p["lnx"], x_t)
        xo = attn.cross_apply(p["xattn"], hx, aux["enc_out"], h=cfg.n_heads,
                              hkv=cfg.n_kv_heads, dh=cfg.head_dim)
        x_t = x_t + xo
    h2 = apply_norm(cfg.norm, p["ln2"], x_t)
    if kind == "moe":
        y = _moe_block(p, cfg, h2, aux, ep_axes, tiered_moe)
    else:
        y = mlp_apply(p["ffn"], h2, cfg.mlp)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["pn2"], y)
    return x_t + y, cache


def _decode_block(p, shared, cfg, kind, x_t, cache, pos, aux, ep_axes,
                  tiered_moe=None):
    if kind == "mamba":
        s = cfg.ssm
        h = apply_norm(cfg.norm, p["ln"], x_t)
        o, cache = m2.mamba2_decode(p["mix"], h, cache, headdim=s.headdim,
                                    n_groups=s.n_groups, d_state=s.d_state)
        return x_t + o, cache
    if kind == "mlstm":
        h = apply_norm(cfg.norm, p["ln"], x_t)
        o, cache = xl.mlstm_decode(p["mix"], h, cache, n_heads=cfg.mlstm_heads)
        return x_t + o, cache
    if kind == "slstm":
        h = apply_norm(cfg.norm, p["ln"], x_t)
        o, cache = xl.slstm_decode(p["mix"], h, cache)
        return x_t + o, cache
    if kind == "shared_attn":
        return _decode_attn_block(shared, cfg, "attn", x_t, cache, pos, aux, ep_axes)
    return _decode_attn_block(p, cfg, kind, x_t, cache, pos, aux, ep_axes,
                              tiered_moe=tiered_moe)


def _tiered_moe_for(cfg: ArchConfig, tiered, i: int, gi):
    """Expert tier view for pattern position ``i`` (group index ``gi``), or
    None.  Only the FIRST MoE position reads through the tiered store — its
    weight blocks are the payload rows the serve engine bound (DESIGN.md
    §8); later MoE positions keep their dense weights."""
    if not tiered or "experts" not in tiered:
        return None
    if "moe" not in cfg.pattern or i != cfg.pattern.index("moe"):
        return None
    return {"view": tiered["experts"], "group_id": gi}


def decode_step(cfg: ArchConfig, params, cache, token, *, aux_embeds=None,
                ep_axes=None, return_streams: bool = False, tiered=None):
    """token: (B,1) int32 -> (logits (B,1,V), new cache).

    For encoder-decoder configs (whisper) ``aux_embeds`` must be the
    PRE-ENCODED encoder output (see transformer.encode) — serving computes it
    once at prefill; re-running the encoder per token would be wasteful.

    With ``return_streams`` the result is (logits, cache, streams) where
    ``streams["router"]`` is the (G, n_moe, B, 1, k) token->expert stream —
    the NeoMem profiling stream for the serve engine's expert resource.

    ``tiered`` binds reads in THIS jitted step to the NeoMem tiered store
    (DESIGN.md §10): ``tiered["embeddings"]`` serves the token embedding
    row through the device-resident placement table, ``tiered["experts"]``
    serves the first MoE position's expert weight blocks the same way —
    no host verb, no per-step round-trip."""
    pos = cache["pos"]
    x = _embed_tokens(params, token, tiered)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * cfg.d_model ** 0.5).astype(x.dtype)
    aux: dict[str, Any] = {"aux_embeds": aux_embeds}
    if cfg.encoder_layers and aux_embeds is not None:
        aux = {"enc_out": aux_embeds, "aux_embeds": None}

    new_pro = []
    for i, lp in enumerate(params.get("prologue", [])):
        x, c = _decode_attn_block(lp, cfg, "attn", x,
                                  cache["prologue"][i], pos, aux, ep_axes)
        new_pro.append(c)

    shared = params.get("shared_attn")

    def group_body(carry, xs):
        x, = carry
        gp, gc, gi = xs
        a_local = {"aux_embeds": aux.get("aux_embeds"),
                   "enc_out": aux.get("enc_out"), "router_streams": []}
        new_gc = []
        for i, kind in enumerate(cfg.pattern):
            x, c = _decode_block(gp[i], shared, cfg, kind, x, gc[i], pos,
                                 a_local, ep_axes,
                                 tiered_moe=_tiered_moe_for(cfg, tiered, i, gi))
            new_gc.append(c)
        streams = a_local["router_streams"]
        out = jnp.stack(streams) if streams else jnp.zeros((0,), jnp.int32)
        return (x,), (new_gc, out)

    g = cfg.n_groups
    (x,), (new_blocks, router) = jax.lax.scan(
        group_body, (x,),
        (params["blocks"], cache["blocks"], jnp.arange(g, dtype=jnp.int32)))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = logits_apply(params["embed"], x, cfg.final_softcap)
    new_cache = {"blocks": new_blocks, "pos": pos + 1}
    if new_pro:
        new_cache["prologue"] = new_pro
    if return_streams:
        return logits, new_cache, {"router": router if router.size else None}
    return logits, new_cache


# ---------------------------------------------------------------------------
# NeoMem paged decode (long_500k): attention over fast-tier hot pages only
# ---------------------------------------------------------------------------

def _append_attend_local(kp, vp, plen, cur_slot, k_new, v_new, q_eff, *,
                         scale, softcap, page_t, collect_mass):
    """Single-shard page append + flash-decode attention.

    With ``collect_mass`` the kernel additionally exports the (B, n_slots)
    per-page softmax mass — the hotness stream the "kv" tiered resource
    profiles (DESIGN.md §10); otherwise mass is None and the kernel runs
    its plain 3-output form (fill-proxy engines pay nothing extra)."""
    b = q_eff.shape[0]
    bidx = jnp.arange(b)
    off = plen[bidx, cur_slot]
    kp = kp.at[bidx, cur_slot, off].set(k_new.astype(kp.dtype))
    vp = vp.at[bidx, cur_slot, off].set(v_new.astype(vp.dtype))
    plen = plen.at[bidx, cur_slot].add(1)
    full = plen[bidx, cur_slot] >= page_t
    new_slot = jnp.where(full, (cur_slot + 1) % kp.shape[1], cur_slot)
    advanced = full & (new_slot != cur_slot)
    plen = jnp.where(
        advanced[:, None] & (jnp.arange(kp.shape[1])[None] == new_slot[:, None]),
        0, plen)
    if collect_mass:
        o, mass = pa_ops.paged_attention(q_eff, kp, vp, plen, scale=scale,
                                         softcap=softcap, return_mass=True)
    else:
        o, mass = pa_ops.paged_attention(q_eff, kp, vp, plen, scale=scale,
                                         softcap=softcap), None
    return o, kp, vp, plen, new_slot, mass


def _append_attend_sharded(kp, vp, plen, cur_slot, k_new, v_new, q_eff, *,
                           scale, softcap, page_t, smesh, collect_mass):
    """Page slots sharded over ``smesh['axes']``; per-shard kernel + combine.

    Cross-device flash-decoding: each shard attends over its resident hot
    pages and the (m, l, acc) partials are merged with a pmax/psum pair —
    the only per-step collective is O(B x H x dv).  The kernel's per-page
    partials are normalized by the SAME pair, so the (B, n_slots) global
    softmax-mass stream comes back shard-assembled for free."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh, axes = smesh["mesh"], smesh["axes"]

    def body(kp, vp, plen, cur_slot, k_new, v_new, q_eff):
        n_local = kp.shape[1]
        rank = jnp.zeros((), jnp.int32)
        for ax in axes:   # linear shard rank over the slot axes
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        lo = rank * n_local
        b = q_eff.shape[0]
        bidx = jnp.arange(b)
        lslot = cur_slot - lo
        own = (lslot >= 0) & (lslot < n_local)
        safe = jnp.clip(lslot, 0, n_local - 1)
        off = plen[bidx, safe]
        sel = own[:, None, None]          # broadcast over (Hkv, d)
        kp = kp.at[bidx, safe, off].set(
            jnp.where(sel, k_new, kp[bidx, safe, off]).astype(kp.dtype))
        vp = vp.at[bidx, safe, off].set(
            jnp.where(sel, v_new, vp[bidx, safe, off]).astype(vp.dtype))
        plen = plen.at[bidx, safe].add(own.astype(jnp.int32))
        # advance decision comes from the owning shard
        full_local = jnp.where(own, plen[bidx, safe] >= page_t, False)
        full = jax.lax.psum(full_local.astype(jnp.int32), axes) > 0
        n_total = n_local * jax.lax.psum(jnp.ones((), jnp.int32), axes)
        new_slot = jnp.where(full, (cur_slot + 1) % n_total, cur_slot)
        # zero the new slot's length wherever it lives
        nls = new_slot - lo
        nown = (nls >= 0) & (nls < n_local) & full & (new_slot != cur_slot)
        plen = plen.at[bidx, jnp.clip(nls, 0, n_local - 1)].set(
            jnp.where(nown, 0, plen[bidx, jnp.clip(nls, 0, n_local - 1)]))
        stats = pa_ops.paged_attention_local_stats(
            q_eff, kp, vp, plen, scale=scale, softcap=softcap,
            return_page_stats=collect_mass)
        if collect_mass:
            m, l, acc, pg_m, pg_l = stats
            o, mass = pa_ops.combine_stats(m, l, acc, axes,
                                           page_m=pg_m, page_l=pg_l)
            return o.astype(q_eff.dtype), kp, vp, plen, new_slot, mass
        o = pa_ops.combine_stats(*stats, axes)
        return o.astype(q_eff.dtype), kp, vp, plen, new_slot

    pagespec = P(None, axes, None, None, None)
    rep = P(*([None] * 3))
    out_specs = (rep, pagespec, pagespec, P(None, axes), P(None))
    if collect_mass:
        out_specs += (P(None, axes),)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(pagespec, pagespec, P(None, axes), P(None),
                  rep, rep, rep),
        out_specs=out_specs,
        check_rep=False,
    )(kp, vp, plen, cur_slot, k_new, v_new, q_eff)
    return out if collect_mass else out + (None,)


def _paged_attn_block(p, cfg, kind, x_t, cache, pos, aux, ep_axes, page_t,
                      smesh=None, tiered_moe=None, collect_mass=False):
    h = apply_norm(cfg.norm, p["ln1"], x_t)
    b = x_t.shape[0]
    if cfg.mla is not None:
        m = cfg.mla
        # build latent query: q_eff = [q_nope @ w_k_absorbed, q_rope]
        q = attn._rms(h @ p["attn"]["wq_a"], p["attn"]["q_norm"]) @ p["attn"]["wq_b"]
        q = q.reshape(b, cfg.n_heads, m.d_nope + m.d_rope)
        q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
        pos_b = _pos_col(pos, b)
        q_rope = attn.apply_rope(q_rope[:, None], pos_b, cfg.rope_theta)[:, 0]
        wkv_b = p["attn"]["wkv_b"].reshape(m.kv_lora, cfg.n_heads, m.d_nope + m.d_v)
        w_k = wkv_b[..., :m.d_nope]
        q_lat = jnp.einsum("bhd,khd->bhk", q_nope.astype(jnp.float32),
                           w_k.astype(jnp.float32))
        q_eff = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], -1)
        # new latent kv entry
        kv_a = h[:, 0] @ p["attn"]["wkv_a"]
        c_t = attn._rms(kv_a[..., :m.kv_lora], p["attn"]["kv_norm"])
        kr_t = attn.apply_rope(kv_a[:, None, None, m.kv_lora:], pos_b,
                               cfg.rope_theta)[:, 0, 0]
        k_new = jnp.concatenate([c_t, kr_t], -1)[:, None, :]   # (B,1,dk)
        v_new = c_t[:, None, :]
        scale = (m.d_nope + m.d_rope) ** -0.5
    else:
        q, k, v = attn._proj_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim)
        pos_b = _pos_col(pos, b)
        if cfg.rope_theta > 0:
            q = attn.apply_rope(q, pos_b, cfg.rope_theta)
            k = attn.apply_rope(k, pos_b, cfg.rope_theta)
        q_eff = q[:, 0]                                        # (B,H,dh)
        k_new, v_new = k[:, 0], v[:, 0]                        # (B,Hkv,dh)
        scale = (cfg.head_dim ** -0.5) if cfg.attn_scale is None else cfg.attn_scale

    # append the new K/V into the current page slot, attend over hot pages
    if cfg.mla is not None:
        k_new_p = k_new[:, 0][:, None, :]                      # (B,1,dk) hkv=1
        v_new_p = v_new[:, 0][:, None, :]
    else:
        k_new_p, v_new_p = k_new, v_new                        # (B,Hkv,dh)
    fn = _append_attend_local if smesh is None else functools.partial(
        _append_attend_sharded, smesh=smesh)
    o, kp, vp, plen, new_slot, mass = fn(
        cache["k_pages"], cache["v_pages"], cache["page_len"],
        cache["cur_slot"], k_new_p, v_new_p, q_eff.astype(jnp.float32),
        scale=scale, softcap=cfg.attn_softcap, page_t=page_t,
        collect_mass=collect_mass)                             # o: (B,H,dv)
    if mass is not None:
        # the kernel-true per-page softmax mass (B, n_slots) — the "kv"
        # resource's NeoProf stream (DESIGN.md §10)
        aux.setdefault("kv_mass_streams", []).append(mass)
    if cfg.mla is not None:
        wkv_b = p["attn"]["wkv_b"].reshape(m.kv_lora, cfg.n_heads, m.d_nope + m.d_v)
        w_v = wkv_b[..., m.d_nope:]
        o = jnp.einsum("bhk,khd->bhd", o, w_v.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads * m.d_v).astype(x_t.dtype) @ p["attn"]["wo"]
    else:
        o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(x_t.dtype) \
            @ p["attn"]["wo"]
    if cfg.post_norm:
        o = apply_norm(cfg.norm, p["pn1"], o)
    x_t = x_t + o

    h2 = apply_norm(cfg.norm, p["ln2"], x_t)
    if kind == "moe":
        y = _moe_block(p, cfg, h2, aux, ep_axes, tiered_moe)
    else:
        y = mlp_apply(p["ffn"], h2, cfg.mlp)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["pn2"], y)
    new_cache = dict(cache)
    new_cache.update(k_pages=kp, v_pages=vp, page_len=plen, cur_slot=new_slot)
    return x_t + y, new_cache


def decode_step_paged(cfg: ArchConfig, params, cache, token, *, page_t: int,
                      ep_axes=None, smesh=None, return_streams: bool = False,
                      tiered=None, collect_mass: bool | None = None):
    """Long-context decode over the NeoMem fast tier (hot pages only).

    ``cache["pos"]`` may be the scalar lockstep counter or a (B,) vector of
    per-lane positions (continuous batching, see :func:`init_paged_cache`).
    ``smesh``: {"mesh": Mesh, "axes": (...)} shards page slots across devices
    with cross-device flash-decode combining (production path).
    ``tiered`` as in :func:`decode_step` (in-jit embedding/expert reads).

    With ``return_streams`` the streams dict additionally carries
    ``streams["kv_mass"]``: the (G, n_attn, B, n_slots) kernel-exported
    per-page softmax mass of every paged-attention position — the
    hotness-true "kv" profiling stream (DESIGN.md §10), replacing the
    host-computed page-fill proxy.  Works for both the scalar-pos and the
    per-lane-pos (continuous-batching) cache variants.  ``collect_mass``
    (default: follow ``return_streams``) gates the kernel's page-stats
    export, so fill-proxy consumers run the plain 3-output kernel."""
    collect_mass = return_streams if collect_mass is None else collect_mass
    pos = cache["pos"]
    x = _embed_tokens(params, token, tiered)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * cfg.d_model ** 0.5).astype(x.dtype)
    aux: dict[str, Any] = {}

    new_pro = []
    for i, lp in enumerate(params.get("prologue", [])):
        x, c = _paged_attn_block(lp, cfg, "attn", x, cache["prologue"][i], pos,
                                 aux, ep_axes, page_t, smesh)
        new_pro.append(c)

    shared = params.get("shared_attn")

    def group_body(carry, xs):
        x, = carry
        gp, gc, gi = xs
        a_local: dict[str, Any] = {"router_streams": [],
                                   "kv_mass_streams": []}
        new_gc = []
        for i, kind in enumerate(cfg.pattern):
            tm = _tiered_moe_for(cfg, tiered, i, gi)
            if kind in ("mamba", "mlstm", "slstm"):
                x, c = _decode_block(gp[i], shared, cfg, kind, x, gc[i], pos,
                                     a_local, ep_axes)
            elif kind == "shared_attn":
                x, c = _paged_attn_block(shared, cfg, "attn", x, gc[i], pos,
                                         a_local, ep_axes, page_t, smesh,
                                         collect_mass=collect_mass)
            else:
                x, c = _paged_attn_block(gp[i], cfg, kind, x, gc[i], pos,
                                         a_local, ep_axes, page_t, smesh,
                                         tiered_moe=tm,
                                         collect_mass=collect_mass)
            new_gc.append(c)
        streams = a_local["router_streams"]
        out = jnp.stack(streams) if streams else jnp.zeros((0,), jnp.int32)
        masses = a_local["kv_mass_streams"]
        kv_mass = (jnp.stack(masses) if masses
                   else jnp.zeros((0,), jnp.float32))
        return (x,), (new_gc, out, kv_mass)

    g = cfg.n_groups
    (x,), (new_blocks, router, kv_mass) = jax.lax.scan(
        group_body, (x,),
        (params["blocks"], cache["blocks"], jnp.arange(g, dtype=jnp.int32)))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = logits_apply(params["embed"], x, cfg.final_softcap)
    new_cache = {"blocks": new_blocks, "pos": pos + 1}
    if new_pro:
        new_cache["prologue"] = new_pro
    if return_streams:
        return logits, new_cache, {
            "router": router if router.size else None,
            "kv_mass": kv_mass if kv_mass.size else None,
        }
    return logits, new_cache


# ---------------------------------------------------------------------------
# content-addressed page install (cross-request KV reuse, DESIGN.md §12)
# ---------------------------------------------------------------------------

def reuse_eligible(cfg: ArchConfig) -> bool:
    """True when a lane's full per-position decode state is carried by the
    KV slow store alone — the precondition for fast-forwarding a fresh
    lane over slow-store pages (DESIGN.md §12).  The tiered KV payload
    holds only the representative paged-attention entry, so reuse needs a
    single-position pattern (no sibling rings), no O(1) recurrent states
    and no dense-prologue ring (those travel only in preempt residuals)."""
    recurrent = any(k in ("mamba", "mlstm", "slstm") for k in cfg.pattern)
    prologue = bool(cfg.moe and cfg.moe.n_dense_prologue)
    return len(cfg.pattern) == 1 and not recurrent and not prologue


def install_pages(cache, lane: int, slot_ids, rows, *, dk: int, page_t: int,
                  new_pos: int) -> None:
    """Fast-forward one lane's paged ring to ``new_pos`` by installing
    pre-computed KV page payloads.

    ``rows`` is (G, n, T, hkv, dk+dv) slow-store [K | V] payload for ring
    slots ``slot_ids``.  Bit-exact with streaming the same tokens to the
    same position: installed slots hold full pages, and the new current
    slot's fill is zeroed — the eager-advance invariant of
    `_append_attend_local` (at a page boundary ``cur_slot`` has already
    advanced onto an empty slot).  Requires `reuse_eligible`: the
    representative entry must BE the whole per-position state.
    """
    entry = next(c for c in cache["blocks"]
                 if isinstance(c, dict) and "page_len" in c)
    n_slots = entry["page_len"].shape[-1]
    slot_ids = jnp.asarray(slot_ids, jnp.int32)
    entry["k_pages"] = entry["k_pages"].at[:, lane, slot_ids].set(
        rows[..., :dk].astype(entry["k_pages"].dtype))
    entry["v_pages"] = entry["v_pages"].at[:, lane, slot_ids].set(
        rows[..., dk:].astype(entry["v_pages"].dtype))
    entry["page_len"] = entry["page_len"].at[:, lane, slot_ids].set(page_t)
    cur = (new_pos // page_t) % n_slots
    entry["cur_slot"] = entry["cur_slot"].at[:, lane].set(cur)
    entry["page_len"] = entry["page_len"].at[:, lane, cur].set(0)
    cache["pos"] = cache["pos"].at[lane].set(new_pos)


# ---------------------------------------------------------------------------
# sampling — temperature / nucleus over the lane substrate (DESIGN.md §9)
# ---------------------------------------------------------------------------

@jax.jit
def fold_lane_keys(keys: jax.Array, idx: jax.Array) -> jax.Array:
    """Vectorized per-lane key derivation: fold each lane's (2,) uint32
    request-identity key with its emitted-token index — ONE dispatch for
    the whole lane batch (the per-token scheduler hot path)."""
    return jax.vmap(jax.random.fold_in)(keys, idx)


@functools.partial(jax.jit, static_argnames=("temperature", "top_p"))
def sample_tokens(logits: jax.Array, keys: jax.Array, *,
                  temperature: float = 0.0, top_p: float = 1.0) -> jax.Array:
    """Per-lane token sampling: (L, V) logits + (L, 2) uint32 PRNG keys.

    ``temperature <= 0`` is exact argmax (the keys are ignored), so greedy
    callers pay nothing.  Otherwise logits are temperature-scaled and,
    with ``top_p < 1``, nucleus-filtered: the smallest prefix of
    descending-probability tokens whose mass reaches ``top_p`` stays (the
    top-1 token always survives), everything else is masked to -inf.

    One key per lane: the scheduler derives it from (trace seed, request
    id, position), so a lane's draw depends only on the REQUEST's identity
    and progress — replays, preemptions, and lane reassignment cannot
    change a trace's sampled tokens.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        desc = jnp.sort(scaled, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < top_p          # mass BEFORE this token < top_p
        cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1,
                         keepdims=True)
        scaled = jnp.where(scaled >= cutoff, scaled, -jnp.inf)
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
