"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is a gated-linear-attention-style recurrence
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with exponential input gates stabilized by a running max m_t; implemented
chunkwise (intra-chunk attention + inter-chunk state carry), mirroring the
Mamba2 SSD structure.  sLSTM keeps per-head scalar memories with the same
max-stabilized exponential gating, implemented with an associative scan on
the linear (c, n) recurrences.

Decode paths carry (C, n, m) / (c, n, m) — O(1) state, so xlstm-1.3b runs
long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE


# -- mLSTM ---------------------------------------------------------------------

def mlstm_init(key, d, *, n_heads=4, dtype=DTYPE):
    dh = d // n_heads
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wi": (jax.random.normal(ks[3], (d, n_heads)) * s).astype(jnp.float32),
        "wf": (jax.random.normal(ks[4], (d, n_heads)) * s).astype(jnp.float32),
        "f_bias": jnp.full((n_heads,), 3.0, jnp.float32),
        "ogate": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "norm": jnp.ones((d,), jnp.float32),
        "wo": (jax.random.normal(jax.random.fold_in(key, 7), (d, d)) * s).astype(dtype),
    }


def mlstm_apply(p, u, *, n_heads=4, chunk=128):
    """Chunkwise-parallel mLSTM.  u: (B,S,D)."""
    bsz, s, d = u.shape
    dh = d // n_heads
    q = (u @ p["wq"]).reshape(bsz, s, n_heads, dh).astype(jnp.float32) * dh ** -0.5
    k = (u @ p["wk"]).reshape(bsz, s, n_heads, dh).astype(jnp.float32) * dh ** -0.5
    v = (u @ p["wv"]).reshape(bsz, s, n_heads, dh).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(u.astype(jnp.float32) @ p["wf"] + p["f_bias"])  # (B,S,H)
    logi = u.astype(jnp.float32) @ p["wi"]                                     # (B,S,H)

    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    shp = (bsz, nc, chunk, n_heads)
    qf = q.reshape(*shp, dh)
    kf = k.reshape(*shp, dh)
    vf = v.reshape(*shp, dh)
    lf = logf.reshape(shp)
    li = logi.reshape(shp)

    cum_f = jnp.cumsum(lf, axis=2)                            # (B,NC,Q,H)
    # stabilizer: within-chunk running max of (cum_f[t] ... simplified global
    # per-chunk max of (li - cum_f) keeps exp() bounded)
    a_log = li - cum_f                                        # contribution key
    m_c = jnp.max(a_log, axis=2, keepdims=True)               # (B,NC,1,H)

    # intra-chunk: w[q,t] = exp(cum_f[q]-cum_f[t]+li[t] - m) causal.
    # Mask BEFORE exp (overflowing discarded entries poison gradients).
    dec = cum_f[:, :, :, None, :] - cum_f[:, :, None, :, :] + li[:, :, None, :, :]
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    w_int = jnp.exp(jnp.where(causal, dec - m_c[:, :, :, None, :], -1e30))
    scores = jnp.einsum("bnqhd,bnthd->bnqth", qf, kf)
    num_intra = jnp.einsum("bnqth,bnqth,bnthd->bnqhd", scores, w_int, vf)
    den_intra = jnp.einsum("bnqth,bnqth,bnthd->bnqhd", scores * 0 + 1.0, w_int,
                           kf)  # sum of weighted k for normalizer

    # chunk summaries
    to_end = jnp.exp(cum_f[:, :, -1:, :] - cum_f + li - m_c)  # (B,NC,Q,H)
    c_state = jnp.einsum("bnth,bnthd,bnthe->bnhde", to_end, kf, vf)  # (B,NC,H,dh,dh)
    n_state = jnp.einsum("bnth,bnthd->bnhd", to_end, kf)
    g_chunk = cum_f[:, :, -1, :]                              # (B,NC,H) log decay
    m_chunk = m_c[:, :, 0, :]                                 # (B,NC,H)

    def carry(st, inp):
        c_prev, n_prev, m_prev = st
        c_n, n_n, g_n, m_n = inp
        m_new = jnp.maximum(m_prev + g_n, m_n)
        sc_prev = jnp.exp(m_prev + g_n - m_new)
        sc_new = jnp.exp(m_n - m_new)
        c_new = c_prev * sc_prev[..., None, None] + c_n * sc_new[..., None, None]
        n_new = n_prev * sc_prev[..., None] + n_n * sc_new[..., None]
        return (c_new, n_new, m_new), (c_prev, n_prev, m_prev)

    z = jnp.zeros((bsz, n_heads), jnp.float32)
    init = (jnp.zeros((bsz, n_heads, dh, dh), jnp.float32),
            jnp.zeros((bsz, n_heads, dh), jnp.float32), z - 1e30)
    _, (c_prevs, n_prevs, m_prevs) = jax.lax.scan(
        carry, init,
        (c_state.transpose(1, 0, 2, 3, 4), n_state.transpose(1, 0, 2, 3),
         g_chunk.transpose(1, 0, 2), m_chunk.transpose(1, 0, 2)))
    c_prevs = c_prevs.transpose(1, 0, 2, 3, 4)                # (B,NC,H,dh,dh)
    n_prevs = n_prevs.transpose(1, 0, 2, 3)
    m_prevs = m_prevs.transpose(1, 0, 2)

    # inter-chunk contribution with per-position rescaling;
    # normalize both branches to a common stabilizer per position:
    m_tot = jnp.maximum(m_prevs[:, :, None, :] + cum_f, m_c)  # (B,NC,Q,H)
    sc_int = jnp.exp(m_c - m_tot)
    sc_car = jnp.exp(m_prevs[:, :, None, :] + cum_f - m_tot)
    num_inter = jnp.einsum("bnqhd,bnhde->bnqhe", qf, c_prevs)
    den_inter = jnp.einsum("bnqhd,bnhd->bnqh", qf, n_prevs)

    num = num_intra * sc_int[..., None] + num_inter * sc_car[..., None]
    den_i = jnp.einsum("bnqhd,bnqhd->bnqh", qf, den_intra)
    den = den_i * sc_int + den_inter * sc_car
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))[..., None]

    h = h.reshape(bsz, s, d)
    o = jax.nn.sigmoid(u @ p["ogate"]).astype(jnp.float32)
    h = h * o
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * p["norm"]
    return h.astype(u.dtype) @ p["wo"]


def mlstm_init_cache(batch, d, n_heads=4):
    dh = d // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_decode(p, u_t, cache, *, n_heads=4):
    bsz, _, d = u_t.shape
    dh = d // n_heads
    q = (u_t @ p["wq"]).reshape(bsz, n_heads, dh).astype(jnp.float32) * dh ** -0.5
    k = (u_t @ p["wk"]).reshape(bsz, n_heads, dh).astype(jnp.float32) * dh ** -0.5
    v = (u_t @ p["wv"]).reshape(bsz, n_heads, dh).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(u_t[:, 0].astype(jnp.float32) @ p["wf"] + p["f_bias"])
    logi = u_t[:, 0].astype(jnp.float32) @ p["wi"]
    m_new = jnp.maximum(cache["m"] + logf, logi)
    sc_old = jnp.exp(cache["m"] + logf - m_new)
    sc_in = jnp.exp(logi - m_new)
    c = cache["c"] * sc_old[..., None, None] + sc_in[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = cache["n"] * sc_old[..., None] + sc_in[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(bsz, 1, d)
    o = jax.nn.sigmoid(u_t @ p["ogate"]).astype(jnp.float32)
    h = h * o
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * p["norm"]
    return h.astype(u_t.dtype) @ p["wo"], {"c": c, "n": n, "m": m_new}


# -- sLSTM ---------------------------------------------------------------------

def slstm_init(key, d, *, n_heads=4, dtype=DTYPE):
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wz": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
        "wi": (jax.random.normal(ks[1], (d, d)) * s).astype(jnp.float32),
        "wf": (jax.random.normal(ks[2], (d, d)) * s).astype(jnp.float32),
        "wo_gate": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "norm": jnp.ones((d,), jnp.float32),
        "wo": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
    }


def slstm_apply(p, u):
    """u: (B,S,D).  Associative scan over the stabilized linear recurrence."""
    z = jnp.tanh((u @ p["wz"]).astype(jnp.float32))
    logi = u.astype(jnp.float32) @ p["wi"]
    logf = jax.nn.log_sigmoid(u.astype(jnp.float32) @ p["wf"] + p["f_bias"])
    o = jax.nn.sigmoid(u @ p["wo_gate"]).astype(jnp.float32)

    # stabilized: m_t = max(logf_t + m_{t-1}, logi_t)  (max-plus scan)
    def mp_op(a, b):
        return (a[0] + b[0], jnp.maximum(b[1], b[0] + a[1]))
    _, m = jax.lax.associative_scan(mp_op, (logf, logi), axis=1)

    # c_t = f' c_{t-1} + i' z ; n_t = f' n_{t-1} + i'  with
    # f' = exp(logf + m_{t-1} - m_t), i' = exp(logi - m_t).
    m_prev = jnp.concatenate([jnp.full_like(m[:, :1], -1e30), m[:, :-1]], axis=1)
    fp = jnp.exp(logf + m_prev - m)
    ip = jnp.exp(logi - m)

    def lin_op(a, b):
        # pairs (A, Bc, Bn): x_t = A x_{t-1} + B
        return (a[0] * b[0], b[0] * a[1] + b[1], b[0] * a[2] + b[2])
    _, c, n = jax.lax.associative_scan(lin_op, (fp, ip * z, ip), axis=1)
    h = o * (c / jnp.maximum(n, 1.0))
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * p["norm"]
    return h.astype(u.dtype) @ p["wo"]


def slstm_init_cache(batch, d):
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def slstm_decode(p, u_t, cache):
    z = jnp.tanh((u_t[:, 0] @ p["wz"]).astype(jnp.float32))
    logi = u_t[:, 0].astype(jnp.float32) @ p["wi"]
    logf = jax.nn.log_sigmoid(u_t[:, 0].astype(jnp.float32) @ p["wf"] + p["f_bias"])
    o = jax.nn.sigmoid(u_t[:, 0] @ p["wo_gate"]).astype(jnp.float32)
    m_new = jnp.maximum(logf + cache["m"], logi)
    c = jnp.exp(logf + cache["m"] - m_new) * cache["c"] + jnp.exp(logi - m_new) * z
    n = jnp.exp(logf + cache["m"] - m_new) * cache["n"] + jnp.exp(logi - m_new)
    h = o * (c / jnp.maximum(n, 1.0))
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * p["norm"]
    return (h[:, None, :].astype(u_t.dtype)) @ p["wo"], {"c": c, "n": n, "m": m_new}
