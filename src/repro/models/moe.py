"""Mixture-of-Experts: top-k router + experts (dense-dispatch and EP paths).

Two dispatch strategies:
  * ``dense``  — einsum over all experts with a routing-weight mask.  O(E)
    compute but collective-free and fully shardable; the dry-run default for
    correctness and a clean roofline baseline.
  * ``gather`` — token-dropping capacity-based dispatch via one-hot matmuls
    (MXU-friendly), the optimized path used by the hillclimb; pairs with
    expert sharding so XLA emits all-to-alls on the `model` axis.

The router's token->expert stream is ALSO the NeoMem profiling stream: the
adapter (core/adapters/expert_cache.py) snoops `router_topk` outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE


def moe_init(key, d, e, f, *, shared_f: int = 0, dtype=DTYPE):
    ks = jax.random.split(key, 7)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    if shared_f > 0:
        p["sh_gate"] = (jax.random.normal(ks[4], (d, shared_f)) * s_in).astype(dtype)
        p["sh_in"] = (jax.random.normal(ks[5], (d, shared_f)) * s_in).astype(dtype)
        p["sh_out"] = (jax.random.normal(ks[6], (shared_f, d)) * shared_f ** -0.5).astype(dtype)
    return p


def router_topk(p, x, k: int, *, bias=None):
    """Returns (weights (B,S,k) fp32, indices (B,S,k) int32, probs fp32)."""
    logits = x.astype(jnp.float32) @ p["router"]
    if bias is not None:  # aux-loss-free balancing bias (DeepSeek-V3 style)
        sel_scores = jax.nn.sigmoid(logits) + bias
    else:
        sel_scores = logits
    w, idx = jax.lax.top_k(sel_scores, k)
    probs = jax.nn.softmax(logits, axis=-1)
    gate = jnp.take_along_axis(jax.nn.sigmoid(logits) if bias is not None
                               else probs, idx, axis=-1)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    return gate, idx.astype(jnp.int32), probs


def moe_apply_dense(p, x, k: int, *, bias=None):
    """Collective-free dispatch: mask-weighted einsum over all experts."""
    e = p["router"].shape[1]
    gate, idx, probs = router_topk(p, x, k, bias=bias)
    # combine weights per expert: (B,S,E)
    comb = jax.nn.one_hot(idx, e, dtype=jnp.float32) * gate[..., None]
    comb = jnp.sum(comb, axis=-2)                         # (B,S,E)

    h_gate = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    h_in = jnp.einsum("bsd,edf->bsef", x, p["w_in"])
    h = jax.nn.silu(h_gate) * h_in
    y = jnp.einsum("bsef,efd->bsed", h, p["w_out"])
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), comb).astype(x.dtype)
    out = out + _shared_expert(p, x)
    return out, idx, probs


def moe_apply_gather(p, x, k: int, *, capacity_factor: float = 1.25, bias=None):
    """Capacity-based dispatch via one-hot matmuls (token-dropping)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    gate, idx, probs = router_topk(p, x, k, bias=bias)
    xt = x.reshape(b * s, d)
    gate_f = gate.reshape(b * s, k)
    idx_f = idx.reshape(b * s, k)
    cap = max(1, int(capacity_factor * b * s * k / e))

    onehot = jax.nn.one_hot(idx_f, e, dtype=jnp.float32)       # (T,k,E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                  # slot within expert
    pos = jnp.sum(pos * onehot, axis=-1)                       # (T,k)
    keep = pos < cap
    disp = onehot * keep[..., None]                            # (T,k,E)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (T,k,C)
    # dispatch tensor (T, k, E, C) contracted on the fly:
    xe = jnp.einsum("td,tke,tkc->ecd", xt.astype(jnp.float32), disp, slot_oh)
    xe = xe.astype(x.dtype)                                    # (E,C,D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])             # (E,C,D)
    y = jnp.einsum("ecd,tke,tkc,tk->td", ye.astype(jnp.float32), disp, slot_oh,
                   gate_f)
    out = y.reshape(b, s, d).astype(x.dtype) + _shared_expert(p, x)
    return out, idx, probs


import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class EPContext:
    """Expert-parallel execution context (pjit + shard_map hybrid).

    Experts are sharded over ``expert_axis`` (TP/EP) and their inner dim is
    FSDP-sharded over ``fsdp_axis`` for storage; compute all-gathers the
    layer's expert weights over fsdp_axis (ZeRO-3 style), dispatches local
    tokens to locally-owned experts, and psums partial outputs over
    expert_axis — collective pattern: 1 all-gather (weights, over data) +
    1 all-reduce (activations, over model) per MoE layer.
    """

    mesh: Any
    expert_axis: str = "model"
    fsdp_axis: str | None = "data"
    dp_axes: tuple = ("data",)
    capacity_factor: float = 2.0


def _capacity(t: int, k: int, e: int, cf: float) -> int:
    """Expert capacity.  Small batches (decode / smoke) get exact capacity
    (zero drops — keeps decode/prefill parity); large batches use the
    standard cf * T * k / E dropping capacity."""
    if t * k <= 4096:
        return t * k
    return max(k, int(cf * t * k / e))


def _rank_in_bins(eids: jax.Array, n_bins: int) -> jax.Array:
    """Rank of each element within its bin value (sort-based, O(N log N))."""
    n = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_e = eids[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_bins + 1))
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return ranks_sorted[inv]


def _ep_local_body(x, router_w, bias, wg, wi, wo, *, k, e_total, cap,
                   expert_axis=None, fsdp_axis=None):
    """Per-device EP compute.  x: (B,S,D); wg/wi/wo: local expert shards."""
    if fsdp_axis is not None:
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axis, axis=1, tiled=True)
    e_loc = wg.shape[0]
    midx = jax.lax.axis_index(expert_axis) if expert_axis else 0

    b, s, d = x.shape
    logits = x.astype(jnp.float32).reshape(b * s, d) @ router_w
    if bias is not None:
        sel = jax.nn.sigmoid(logits) + bias
        gate_src = jax.nn.sigmoid(logits)
    else:
        sel = logits
        gate_src = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(sel, k)                       # (T, k)
    gate = jnp.take_along_axis(gate_src, idx, axis=-1)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    t = b * s
    eid = idx.reshape(t * k).astype(jnp.int32)
    lid = eid - midx * e_loc
    mine = (lid >= 0) & (lid < e_loc)
    rank = _rank_in_bins(jnp.where(mine, lid, e_loc), e_loc)
    keep = mine & (rank < cap)
    se = jnp.where(keep, lid, 0)
    sc = jnp.where(keep, rank, 0)

    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    x_flat = x.reshape(t, d)
    contrib = jnp.where(keep[:, None], x_flat[tok], 0).astype(x.dtype)
    xe = jnp.zeros((e_loc, cap, d), x.dtype).at[se, sc].add(contrib)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi)
    ye = jnp.einsum("ecf,efd->ecd", h, wo)               # (E_loc, C, D)

    y_asn = ye[se, sc].astype(jnp.float32) \
        * (keep[:, None] * gate.reshape(t * k)[:, None])
    y = jnp.sum(y_asn.reshape(t, k, d), axis=1)
    if expert_axis:
        y = jax.lax.psum(y, expert_axis)
    return y.reshape(b, s, d).astype(x.dtype), idx.reshape(b, s, k)


def _ep_resident_body(x, router_w, bias, res_map, wg, wi, wo,
                      fw_g, fw_i, fw_o, fetch_ids, *, k, e_total, cap,
                      expert_axis=None):
    """NeoMem-tiered serving dispatch (§Perf cell A).

    Only the HOT experts are HBM-resident (``wg/wi/wo``: (E_hot_loc, D, F)
    per model shard — the fast tier, populated by the expert-cache daemon);
    ``fw_*`` is the per-interval cold-fetch buffer (n_fetch experts DMA'd
    from host under the migration quota).  Tokens routed to non-resident,
    non-fetched experts take only the shared-expert path (counted as slow
    misses by the profiler).  No per-token weight collectives remain — the
    only collective is the output psum.
    """
    e_hot_loc = wg.shape[0]
    n_fetch = fw_g.shape[0]   # LOCAL fetch slots (buffer sharded over EP)
    midx = jax.lax.axis_index(expert_axis) if expert_axis else 0

    b, s, d = x.shape
    logits = x.astype(jnp.float32).reshape(b * s, d) @ router_w
    sel = jax.nn.sigmoid(logits) + (bias if bias is not None else 0.0)
    gate_src = jax.nn.sigmoid(logits) if bias is not None \
        else jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(sel, k)
    gate = jnp.take_along_axis(gate_src, idx, axis=-1)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    t = b * s
    eid = idx.reshape(t * k).astype(jnp.int32)
    slot = res_map[eid]                                  # global hot slot | -1
    mine_hot = (slot >= 0) & (slot // e_hot_loc == midx)
    lslot = slot - midx * e_hot_loc
    # cold-fetched experts: each shard DMA'd its own fetch slots, so a
    # fetched token is handled by whichever shard holds the expert
    fmatch = eid[:, None] == fetch_ids[None, :]          # (T*k, n_fetch_loc)
    fslot = jnp.argmax(fmatch, axis=1)
    is_fetched = jnp.any(fmatch, axis=1) & (slot < 0)

    e_loc = e_hot_loc + n_fetch
    lid = jnp.where(mine_hot, lslot,
                    jnp.where(is_fetched, e_hot_loc + fslot, e_loc))
    keep_pre = mine_hot | is_fetched
    rank = _rank_in_bins(jnp.where(keep_pre, lid, e_loc), e_loc)
    keep = keep_pre & (rank < cap)
    se = jnp.where(keep, lid, 0)
    sc = jnp.where(keep, rank, 0)

    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    x_flat = x.reshape(t, d)
    contrib = jnp.where(keep[:, None], x_flat[tok], 0).astype(x.dtype)
    xe = jnp.zeros((e_loc, cap, d), x.dtype).at[se, sc].add(contrib)

    wg_all = jnp.concatenate([wg, fw_g], axis=0)
    wi_all = jnp.concatenate([wi, fw_i], axis=0)
    wo_all = jnp.concatenate([wo, fw_o], axis=0)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg_all)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi_all)
    ye = jnp.einsum("ecf,efd->ecd", h, wo_all)

    y_asn = ye[se, sc].astype(jnp.float32) \
        * (keep[:, None] * gate.reshape(t * k)[:, None])
    y = jnp.sum(y_asn.reshape(t, k, d), axis=1)
    if expert_axis:
        y = jax.lax.psum(y, expert_axis)
    return y.reshape(b, s, d).astype(x.dtype), idx.reshape(b, s, k)


def moe_apply_ep(p, x, k: int, *, bias=None, ep_axes: EPContext | None = None):
    """Expert-parallel MoE layer; single-device fallback when ep_axes=None.

    Returns (y, idx, probs=None).  The token->expert ``idx`` stream is the
    NeoMem profiling stream.
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # newer jax
        from jax.sharding import shard_map  # type: ignore

    e = p["router"].shape[1]

    if "residency" in p:   # NeoMem-tiered serving path (hot experts resident)
        from jax.sharding import PartitionSpec as P
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:
            from jax.sharding import shard_map  # type: ignore
        b, s, d = x.shape
        # resident path: dispatch buffers sized to expected load (x8 head-
        # room), NOT to the no-drop bound — with E_hot+fetch local experts a
        # t*k capacity would pad the expert matmuls ~10x (measured in §Perf).
        cap = min(b * s * k, max(64, int(8.0 * b * s * k / e)))
        args = (x, p["router"], bias, p["residency"],
                p["w_gate"], p["w_in"], p["w_out"],
                p["fetch_gate"], p["fetch_in"], p["fetch_out"], p["fetch_ids"])
        if ep_axes is None:
            y, idx = _ep_resident_body(*args, k=k, e_total=e, cap=cap)
        else:
            ep = ep_axes
            body = functools.partial(_ep_resident_body, k=k, e_total=e,
                                     cap=cap, expert_axis=ep.expert_axis)
            rep3 = P(None, None, None)
            wspec = P(ep.expert_axis, None, None)
            # fetch buffers + ids are sharded over the EP axis too: each
            # shard DMA's its own cold experts under the migration quota
            y, idx = shard_map(
                body, mesh=ep.mesh,
                in_specs=(rep3, P(None, None),
                          P(None) if bias is not None else None, P(None),
                          wspec, wspec, wspec, wspec, wspec, wspec,
                          P(ep.expert_axis)),
                out_specs=(rep3, rep3),
                check_rep=False,
            )(*args)
        return y + _shared_expert(p, x), idx, None

    if ep_axes is None:
        b, s, d = x.shape
        cap = _capacity(b * s, k, e, 2.0)
        y, idx = _ep_local_body(
            x, p["router"], bias, p["w_gate"], p["w_in"], p["w_out"],
            k=k, e_total=e, cap=cap)
    else:
        ep = ep_axes
        b, s, d = x.shape
        import numpy as np
        dp_size = int(np.prod([ep.mesh.shape[ax] for ax in ep.dp_axes])) \
            if ep.dp_axes else 1
        # decode / tiny batches can't be DP-sharded: replicate tokens instead
        dp_axes = ep.dp_axes if (b % max(dp_size, 1) == 0 and b >= dp_size) \
            else ()
        b_loc = b // dp_size if dp_axes else b
        cap = _capacity(b_loc * s, k, e, ep.capacity_factor)
        body = functools.partial(
            _ep_local_body, k=k, e_total=e, cap=cap,
            expert_axis=ep.expert_axis, fsdp_axis=ep.fsdp_axis)
        dp = P(dp_axes, None, None) if dp_axes else P(None, None, None)
        wspec = P(ep.expert_axis, ep.fsdp_axis, None)
        y, idx = shard_map(
            body, mesh=ep.mesh,
            in_specs=(dp, P(None, None), P(None) if bias is not None else None,
                      wspec, wspec, wspec),
            out_specs=(dp, dp),
            check_rep=False,
        )(x, p["router"], bias, p["w_gate"], p["w_in"], p["w_out"])

    y = y + _shared_expert(p, x)
    return y, idx, None


def moe_apply_tiered(p, x, k: int, *, bias=None, tier, group_id):
    """NeoMem EP-resident dispatch: expert weights served from the tiered
    store INSIDE the jitted step (DESIGN.md §10).

    Instead of touching the dense (E, D, F) weight tensors, each selected
    expert's flattened [w_gate | w_in | w_out] payload row is gathered
    through the device-resident placement table
    (:func:`repro.tiering.migrate.lookup_rows`): promoted experts come from
    the HBM fast buffer, cold experts stream from the slow store in the
    same fused gather — the serving analogue of a CXL slow-tier load, a
    miss is only slower, never an error.  ``tier`` is the resource's
    ``{"fast", "slow", "page_slot"}`` view (plus the int8 codec's optional
    ``"scale"`` — cold rows dequantize inside the same fused gather,
    DESIGN.md §14); ``group_id`` the layer-group
    index (page_id = group * n_experts + expert).  Gathered compute is
    per-token (B, S, k) einsums — at decode shapes (S=1, small k) this
    touches k weight blocks per token instead of all E.

    Single-device (replicated) path only: the buffers and placement table
    are unsharded, so an EP-configured engine must NOT route here — EP
    meshes keep `moe_apply_ep`'s shard_map dispatch, whose "residency"
    params are the EP-sharded form of the same tiering (the serve engine
    gates on ``ep_axes`` accordingly).
    """
    from repro.tiering.migrate import lookup_rows

    e = p["router"].shape[1]
    gate, idx, probs = router_topk(p, x, k, bias=bias)
    _, d, f = p["w_gate"].shape
    rows = lookup_rows(tier["fast"], tier["slow"], tier["page_slot"],
                       group_id * e + idx,
                       scale=tier.get("scale"))         # (B, S, k, 3*d*f)
    rows = rows.astype(p["w_gate"].dtype)
    wg = rows[..., : d * f].reshape(idx.shape + (d, f))
    wi = rows[..., d * f: 2 * d * f].reshape(idx.shape + (d, f))
    wo = rows[..., 2 * d * f:].reshape(idx.shape + (f, d))
    h = jax.nn.silu(jnp.einsum("bsd,bskdf->bskf", x, wg)) \
        * jnp.einsum("bsd,bskdf->bskf", x, wi)
    y = jnp.einsum("bskf,bskfd->bskd", h, wo)
    out = jnp.einsum("bskd,bsk->bsd", y.astype(jnp.float32),
                     gate).astype(x.dtype)
    return out + _shared_expert(p, x), idx, probs


def _shared_expert(p, x):
    if "sh_in" not in p:
        return jnp.zeros_like(x)
    h = jax.nn.silu(x @ p["sh_gate"]) * (x @ p["sh_in"])
    return h @ p["sh_out"]


def aux_load_balance_loss(probs, idx, e: int, k: int) -> jax.Array:
    """Switch-style load-balancing loss (used when bias-free balancing off)."""
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    onehot = jax.nn.one_hot(idx.reshape(-1, k), e, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / k
    return e * jnp.sum(me * ce)
