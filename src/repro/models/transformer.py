"""Generic multi-family decoder LM: init / train / prefill / decode paths.

One model covers all ten assigned architectures via ArchConfig.pattern —
block kinds: attn, attn_local, attn_global, cross, mamba, shared_attn,
mlstm, slstm, moe, attn_dense (MoE prologue).  Layers are stacked by
*pattern group* and iterated with ``jax.lax.scan`` (+ optional per-group
remat) so the HLO stays compact at any depth — essential for the 512-device
dry-run compile times and for activation memory at train_4k.

The NeoMem hook: every block that produces an index stream (MoE router,
paged-KV page ids, embedding token ids) reports it in the returned ``aux``
dict; the adapters feed those streams to NeoProf.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models.layers import (
    DTYPE, apply_norm, cross_entropy, embed_apply, embed_init, logits_apply,
    make_norm, mlp_apply, mlp_init,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg: ArchConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": make_norm(cfg.norm, d), "ln2": make_norm(cfg.norm, d)}
    if cfg.post_norm:
        p["pn1"] = make_norm(cfg.norm, d)
        p["pn2"] = make_norm(cfg.norm, d)
    if cfg.mla is not None and kind != "cross":
        p["attn"] = attn.mla_init(k1, d, cfg.n_heads, **dataclasses.asdict(cfg.mla))
    else:
        p["attn"] = attn.gqa_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, bias=cfg.qkv_bias)
    if kind == "moe":
        mc = cfg.moe
        p["ffn"] = moe_lib.moe_init(k2, d, mc.n_experts, mc.expert_ff,
                                    shared_f=mc.shared_ff)
        if mc.bias_free_balance:
            p["router_bias"] = jnp.zeros((mc.n_experts,), jnp.float32)
    elif kind == "attn_dense":
        p["ffn"] = mlp_init(k2, d, cfg.moe.dense_ff, cfg.mlp)
    else:
        p["ffn"] = mlp_init(k2, d, cfg.d_ff, cfg.mlp)
    if kind == "cross":
        p["lnx"] = make_norm(cfg.norm, d)
        p["xattn"] = attn.gqa_init(k3, d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, bias=cfg.qkv_bias)
        p["xgate"] = jnp.zeros((1,), jnp.float32)  # llama-vision gated x-attn
    if kind == "dec":  # whisper decoder: self + cross + mlp
        p["lnx"] = make_norm(cfg.norm, d)
        p["xattn"] = attn.gqa_init(k3, d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, bias=cfg.qkv_bias)
    return p


def _block_init(key, cfg: ArchConfig, kind: str):
    d = cfg.d_model
    if kind == "mamba":
        s = cfg.ssm
        return {"ln": make_norm(cfg.norm, d),
                "mix": m2.mamba2_init(key, d, d_state=s.d_state, expand=s.expand,
                                      headdim=s.headdim, d_conv=s.d_conv,
                                      n_groups=s.n_groups)}
    if kind == "mlstm":
        return {"ln": make_norm(cfg.norm, d),
                "mix": xl.mlstm_init(key, d, n_heads=cfg.mlstm_heads)}
    if kind == "slstm":
        return {"ln": make_norm(cfg.norm, d),
                "mix": xl.slstm_init(key, d, n_heads=cfg.mlstm_heads)}
    if kind == "shared_attn":
        return {}  # weights live once in params["shared_attn"]
    return _attn_block_init(key, cfg, kind)


def init_params(cfg: ArchConfig, key: jax.Array):
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": embed_init(keys[0], cfg.vocab, cfg.d_model)}

    # group-stacked body params: leaf shapes (G, ...)
    def one_group(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return [
            _block_init(ks[i], cfg, kind) for i, kind in enumerate(cfg.pattern)
        ]

    gkeys = jax.random.split(keys[1], cfg.n_groups)
    params["blocks"] = jax.vmap(one_group)(gkeys)

    if "shared_attn" in cfg.pattern:
        params["shared_attn"] = _attn_block_init(keys[2], cfg, "attn")
    if cfg.moe and cfg.moe.n_dense_prologue:
        pk = jax.random.split(keys[3], cfg.moe.n_dense_prologue)
        params["prologue"] = [
            _block_init(pk[i], cfg, "attn_dense")
            for i in range(cfg.moe.n_dense_prologue)
        ]
    if cfg.encoder_layers:
        ek = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = [_block_init(ek[i], cfg, "enc") for i in range(cfg.encoder_layers)]
        params["enc_norm"] = make_norm(cfg.norm, cfg.d_model)
    if cfg.mtp:
        params["mtp"] = {
            "block": _block_init(keys[5], cfg, "attn_dense" if cfg.moe else "attn"),
            "proj": (jax.random.normal(keys[6], (2 * cfg.d_model, cfg.d_model))
                     * (2 * cfg.d_model) ** -0.5).astype(DTYPE),
            "norm": make_norm(cfg.norm, cfg.d_model),
        }
    params["final_norm"] = make_norm(cfg.norm, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# forward blocks (training / prefill, full-sequence)
# ---------------------------------------------------------------------------

def _apply_attn_block(p, cfg: ArchConfig, kind: str, x, aux, ep_axes):
    d = cfg.d_model
    h = apply_norm(cfg.norm, p["ln1"], x)
    window = cfg.window if kind == "attn_local" else 0
    causal = kind != "enc_self"   # whisper encoder is bidirectional
    if cfg.mla is not None and kind != "cross":
        o = attn.mla_apply(p["attn"], h, h=cfg.n_heads,
                           rope_theta=cfg.rope_theta,
                           **dataclasses.asdict(cfg.mla))
    else:
        o = attn.gqa_apply(p["attn"], h, h=cfg.n_heads, hkv=cfg.n_kv_heads,
                           dh=cfg.head_dim, rope_theta=cfg.rope_theta,
                           causal=causal, window=window,
                           softcap=cfg.attn_softcap, scale=cfg.attn_scale)
    if cfg.post_norm:
        o = apply_norm(cfg.norm, p["pn1"], o)
    x = x + o

    if kind == "cross" and aux.get("aux_embeds") is not None:
        hx = apply_norm(cfg.norm, p["lnx"], x)
        xo = attn.cross_apply(p["xattn"], hx, aux["aux_embeds"],
                              h=cfg.n_heads, hkv=cfg.n_kv_heads, dh=cfg.head_dim)
        x = x + (jnp.tanh(p["xgate"]) * xo.astype(jnp.float32)).astype(x.dtype)
    if kind == "dec" and aux.get("enc_out") is not None:
        hx = apply_norm(cfg.norm, p["lnx"], x)
        xo = attn.cross_apply(p["xattn"], hx, aux["enc_out"],
                              h=cfg.n_heads, hkv=cfg.n_kv_heads, dh=cfg.head_dim)
        x = x + xo

    h2 = apply_norm(cfg.norm, p["ln2"], x)
    if kind == "moe":
        bias = p.get("router_bias")
        y, idx, probs = moe_lib.moe_apply_ep(
            p["ffn"], h2, cfg.moe.top_k, bias=bias, ep_axes=ep_axes)
        aux.setdefault("router_streams", []).append(idx)
    else:
        y = mlp_apply(p["ffn"], h2, cfg.mlp)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["pn2"], y)
    return x + y, aux


def _apply_block(p, shared, cfg: ArchConfig, kind: str, x, aux, ep_axes):
    if kind == "mamba":
        s = cfg.ssm
        h = apply_norm(cfg.norm, p["ln"], x)
        return x + m2.mamba2_apply(p["mix"], h, headdim=s.headdim,
                                   n_groups=s.n_groups, d_state=s.d_state,
                                   chunk=s.chunk), aux
    if kind == "mlstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        return x + xl.mlstm_apply(p["mix"], h, n_heads=cfg.mlstm_heads), aux
    if kind == "slstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        return x + xl.slstm_apply(p["mix"], h), aux
    if kind == "shared_attn":
        return _apply_attn_block(shared, cfg, "attn", x, aux, ep_axes)
    return _apply_attn_block(p, cfg, kind, x, aux, ep_axes)


def forward(cfg: ArchConfig, params, tokens, *, aux_embeds=None,
            remat: bool = True, ep_axes=None):
    """tokens: (B, S) -> final hidden states (B, S, D), aux dict."""
    x = embed_apply(params["embed"], tokens)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * cfg.d_model ** 0.5).astype(x.dtype)
    aux: dict[str, Any] = {"token_stream": tokens}

    enc_out = None
    if cfg.encoder_layers and aux_embeds is not None:
        enc = aux_embeds
        for lp in params["encoder"]:
            enc, _ = _apply_attn_block(lp, cfg, "enc_self", enc,
                                       {"enc_out": None}, ep_axes)
        enc_out = apply_norm(cfg.norm, params["enc_norm"], enc)
        aux["enc_out"] = enc_out
    elif aux_embeds is not None:
        aux["aux_embeds"] = aux_embeds

    for lp in params.get("prologue", []):
        x, aux = _apply_attn_block(lp, cfg, "attn_dense", x, aux, ep_axes)

    shared = params.get("shared_attn")

    def group_body(x, gp):
        a_local = {"aux_embeds": aux.get("aux_embeds"),
                   "enc_out": aux.get("enc_out"),
                   "router_streams": []}
        for i, kind in enumerate(cfg.pattern):
            x, a_local = _apply_block(gp[i], shared, cfg, kind, x, a_local, ep_axes)
        streams = a_local["router_streams"]
        out = jnp.stack(streams) if streams else jnp.zeros((0,), jnp.int32)
        return x, out

    body = jax.checkpoint(group_body) if remat else group_body
    x, router_streams = jax.lax.scan(body, x, params["blocks"])
    if router_streams.size:
        aux["router_streams"] = router_streams   # (G, n_moe_in_group, B, S, k)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x, aux


def encode(cfg: ArchConfig, params, aux_embeds, *, ep_axes=None):
    """Run the encoder stack (whisper): frame embeddings -> enc_out.

    Serving computes this ONCE at prefill and caches the result; decode steps
    take the precomputed enc_out as their aux_embeds."""
    enc = aux_embeds
    for lp in params["encoder"]:
        enc, _ = _apply_attn_block(lp, cfg, "enc_self", enc,
                                   {"enc_out": None}, ep_axes)
    return apply_norm(cfg.norm, params["enc_norm"], enc)


def train_loss(cfg: ArchConfig, params, batch, *, remat=True, ep_axes=None):
    tokens = batch["tokens"]
    labels = batch["labels"]
    x, aux = forward(cfg, params, tokens, aux_embeds=batch.get("aux_embeds"),
                     remat=remat, ep_axes=ep_axes)
    logits = logits_apply(params["embed"], x, cfg.final_softcap)
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    metrics = {"loss": loss}
    if cfg.mtp:   # predict t+2 from (h_t, emb_{t+1})
        mp = params["mtp"]
        emb_next = embed_apply(params["embed"], jnp.roll(tokens, -1, axis=1))
        h2 = jnp.concatenate([x, emb_next.astype(x.dtype)], axis=-1) @ mp["proj"]
        h2, _ = _apply_attn_block(
            mp["block"], cfg, "attn_dense" if cfg.moe else "attn", h2,
            {"aux_embeds": None, "enc_out": None, "router_streams": []}, ep_axes)
        h2 = apply_norm(cfg.norm, mp["norm"], h2)
        mtp_logits = logits_apply(params["embed"], h2, cfg.final_softcap)
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mtp_loss = cross_entropy(mtp_logits, mtp_labels, batch.get("loss_mask"))
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss
        metrics["loss"] = loss
    return loss, (metrics, aux)
