"""Attention variants: GQA (full / sliding-window / cross) and DeepSeek MLA.

All attention math runs in fp32; params bf16.  Each variant exposes
  init(key, cfg-ish dims) -> params
  apply(params, x, ..., mode) -> y                    (training, full seq)
  decode(params, x_t, cache, pos) -> (y_t, cache)     (single-token decode)

Caches are dicts of arrays so they stack cleanly across scanned layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE, apply_rope

NEG_INF = -1e30


# -- GQA ----------------------------------------------------------------------

def gqa_init(key, d, h, hkv, dh, bias=False, dtype=DTYPE):
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * dh, d)) * (h * dh) ** -0.5).astype(dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _proj_qkv(p, x, h, hkv, dh):
    b, s, _ = x.shape
    q = x @ p["wq"] + p.get("bq", 0)
    k = x @ p["wk"] + p.get("bk", 0)
    v = x @ p["wv"] + p.get("bv", 0)
    return (q.reshape(b, s, h, dh), k.reshape(b, s, hkv, dh),
            v.reshape(b, s, hkv, dh))


def sdpa(q, k, v, *, causal=True, window: int = 0, softcap: float = 0.0,
         scale=None, q_offset: int | jax.Array = 0,
         k_offset: int | jax.Array = 0):
    """q: (B,Sq,H,dh), k/v: (B,Sk,Hkv,dh).  window>0 = sliding-window causal.

    q_offset / k_offset: absolute positions of q[0] / k[0] (decode, chunked
    prefill, windowed-KV slices)."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale   # (B,Hkv,g,Sq,Sk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk) + k_offset
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    dv = v.shape[-1]   # MLA uses d_v != d_qk
    return out.reshape(b, sq, h, dv).astype(q.dtype)


CHUNK_Q = 512          # query-chunked attention kicks in above this length


def sdpa_chunked(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                 chunk=CHUNK_Q):
    """Memory-bounded attention: lax.scan over query chunks.

    Peak live scores are (B, H, chunk, Sk) instead of (B, H, Sq, Sk) — the
    XLA-level analogue of flash attention's O(S) memory (the inner softmax
    is still fused by XLA; only the chunk x Sk panel is ever live).
    """
    b, sq, h, dh = q.shape
    if sq <= chunk or sq % chunk != 0:   # small or ragged: plain path
        return sdpa(q, k, v, causal=causal, window=window, softcap=softcap,
                    scale=scale)
    qc = q.reshape(b, sq // chunk, chunk, h, dh).swapaxes(0, 1)

    # sliding-window layers only ever need the trailing `window` keys per
    # query chunk: slice K/V instead of masking the full row (perf pass §C:
    # drops local-layer attention FLOPs from O(S^2) to O(S * window)).
    sk = k.shape[1]
    kv_span = min(sk, window + chunk) if (window > 0 and causal) else sk

    def body(_, args):
        i, q_i = args
        if kv_span < sk:
            start = jnp.clip(i * chunk - (kv_span - chunk), 0, sk - kv_span)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
        else:
            start = 0
            k_i, v_i = k, v
        o_i = sdpa(q_i, k_i, v_i, causal=causal, window=window,
                   softcap=softcap, scale=scale, q_offset=i * chunk,
                   k_offset=start)
        return None, o_i

    _, oc = jax.lax.scan(body, None,
                         (jnp.arange(sq // chunk), qc))
    return oc.swapaxes(0, 1).reshape(b, sq, h, -1)   # -1: MLA has dv != dk


def gqa_apply(p, x, *, h, hkv, dh, rope_theta=10000.0, causal=True,
              window=0, softcap=0.0, positions=None, scale=None):
    b, s, d = x.shape
    q, k, v = _proj_qkv(p, x, h, hkv, dh)
    pos = jnp.arange(s)[None, :] if positions is None else positions
    if rope_theta > 0:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    o = sdpa_chunked(q, k, v, causal=causal, window=window, softcap=softcap,
                     scale=scale)
    return o.reshape(b, s, h * dh) @ p["wo"]


def gqa_init_cache(batch, smax, hkv, dh, dtype=DTYPE):
    return {
        "k": jnp.zeros((batch, smax, hkv, dh), dtype),
        "v": jnp.zeros((batch, smax, hkv, dh), dtype),
    }


def gqa_decode(p, x_t, cache, pos, *, h, hkv, dh, rope_theta=10000.0,
               window=0, softcap=0.0, scale=None):
    """x_t: (B,1,D); pos: () current position; full-cache decode."""
    b = x_t.shape[0]
    q, k, v = _proj_qkv(p, x_t, h, hkv, dh)
    pos_b = jnp.full((b, 1), pos)
    if rope_theta > 0:
        q = apply_rope(q, pos_b, rope_theta)
        k = apply_rope(k, pos_b, rope_theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    smax = ck.shape[1]
    scale_ = (dh ** -0.5) if scale is None else scale
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, 1, hkv, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, ck.astype(jnp.float32)) * scale_
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(smax)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, cv.astype(jnp.float32))
    o = o.reshape(b, 1, h * dh).astype(x_t.dtype)
    return o @ p["wo"], {"k": ck, "v": cv}


# -- cross attention (vision / encoder-decoder) --------------------------------

def cross_apply(p, x, kv_src, *, h, hkv, dh):
    """x: (B,Sq,D) queries; kv_src: (B,Sk,D) keys/values source (no rope)."""
    b, sq, _ = x.shape
    sk = kv_src.shape[1]
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, sq, h, dh)
    k = (kv_src @ p["wk"] + p.get("bk", 0)).reshape(b, sk, hkv, dh)
    v = (kv_src @ p["wv"] + p.get("bv", 0)).reshape(b, sk, hkv, dh)
    o = sdpa(q, k, v, causal=False)
    return o.reshape(b, sq, h * dh) @ p["wo"]


# -- DeepSeek-V3 MLA -----------------------------------------------------------

MLA_DEFAULTS = dict(q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128)


def mla_init(key, d, h, *, q_lora=1536, kv_lora=512, d_nope=128, d_rope=64,
             d_v=128, dtype=DTYPE):
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d, q_lora)) * s).astype(dtype),
        "q_norm": jnp.ones((q_lora,), jnp.float32),
        "wq_b": (jax.random.normal(ks[1], (q_lora, h * (d_nope + d_rope)))
                 * q_lora ** -0.5).astype(dtype),
        "wkv_a": (jax.random.normal(ks[2], (d, kv_lora + d_rope)) * s).astype(dtype),
        "kv_norm": jnp.ones((kv_lora,), jnp.float32),
        "wkv_b": (jax.random.normal(ks[3], (kv_lora, h * (d_nope + d_v)))
                  * kv_lora ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[4], (h * d_v, d)) * (h * d_v) ** -0.5).astype(dtype),
    }


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * w).astype(x.dtype)


def mla_apply(p, x, *, h, q_lora=1536, kv_lora=512, d_nope=128, d_rope=64,
              d_v=128, rope_theta=10000.0, positions=None):
    """Training-time MLA (latent KV decompressed on the fly)."""
    b, s, d = x.shape
    pos = jnp.arange(s)[None, :] if positions is None else positions
    q = _rms(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(b, s, h, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope, pos, rope_theta)

    kv_a = x @ p["wkv_a"]                                  # (B,S,kv_lora+d_rope)
    c_kv = _rms(kv_a[..., :kv_lora], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., None, kv_lora:], pos, rope_theta)  # (B,S,1,dr)
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, d_nope + d_v)
    k_nope, v = kv[..., :d_nope], kv[..., d_nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, d_rope))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    scale = (d_nope + d_rope) ** -0.5
    o = sdpa_chunked(q_full, k, v, causal=True, scale=scale)
    return o.reshape(b, s, h * d_v) @ p["wo"]


def mla_init_cache(batch, smax, kv_lora=512, d_rope=64, dtype=DTYPE):
    """MLA caches the COMPRESSED latent + rope key — its signature trick."""
    return {
        "c_kv": jnp.zeros((batch, smax, kv_lora), dtype),
        "k_rope": jnp.zeros((batch, smax, d_rope), dtype),
    }


def mla_decode(p, x_t, cache, pos, *, h, q_lora=1536, kv_lora=512,
               d_nope=128, d_rope=64, d_v=128, rope_theta=10000.0):
    b = x_t.shape[0]
    pos_b = jnp.full((b, 1), pos)
    q = _rms(x_t @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(b, 1, h, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope, pos_b, rope_theta)

    kv_a = x_t @ p["wkv_a"]
    c_t = _rms(kv_a[..., :kv_lora], p["kv_norm"])
    kr_t = apply_rope(kv_a[..., None, kv_lora:], pos_b, rope_theta)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_t.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_t.astype(cache["k_rope"].dtype), (0, pos, 0))

    # absorbed-attention decode: score via latent space
    wkv_b = p["wkv_b"].reshape(kv_lora, h, d_nope + d_v)
    w_k, w_v = wkv_b[..., :d_nope], wkv_b[..., d_nope:]
    # q_nope projected into latent: (B,1,H,kv_lora)
    q_lat = jnp.einsum("bqhd,khd->bqhk", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))
    s_lat = jnp.einsum("bqhk,bsk->bhqs", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scale = (d_nope + d_rope) ** -0.5
    s = (s_lat + s_rope) * scale
    smax = c_kv.shape[1]
    mask = jnp.arange(smax) <= pos
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsk->bqhk", w, c_kv.astype(jnp.float32))  # latent out
    o = jnp.einsum("bqhk,khd->bqhd", o_lat, w_v.astype(jnp.float32))
    o = o.reshape(b, 1, h * d_v).astype(x_t.dtype)
    return o @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}
