"""Train-step builder: pjit-sharded, microbatched, NeoMem-instrumented.

build_train_step(cfg, mesh, ...) returns (step_fn, shardings) where step_fn
is jit-able with explicit in/out shardings and performs:

  1. grad-accumulation scan over microbatches (activation-memory knob),
  2. per-layer remat inside the layer-group scan,
  3. EP MoE via shard_map (models.moe.EPContext) when the config is MoE,
  4. AdamW / Adafactor / ZeRO-1 update (per opt config),
  5. optional int8+error-feedback gradient compression,
  6. NeoMem profiling: the MoE router streams from the forward pass are fed
     to the on-device NeoProf sketch INSIDE the step (zero extra host work —
     the paper's device-side offload, expressed in XLA).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                # jax<=0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map
except ImportError:                 # newer jax promoted it to the top level
    from jax import shard_map       # type: ignore

from repro.configs.base import ArchConfig
from repro.core.neoprof import NeoProfParams, neoprof_init, neoprof_observe
from repro.core.sketch import SketchParams
from repro.dist import compression
from repro.dist.sharding import batch_pspec, param_pspecs
from repro.models import transformer as tr
from repro.models.moe import EPContext
from repro.optim import zero1
from repro.optim.optimizers import OptConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    remat: bool = True
    grad_compression: bool = False
    zero1: bool = False
    compress_collective: bool = False  # int8+EF ZeRO-1 delta gather (§14)
    fsdp: bool = False                 # ZeRO-3 weight sharding over 'data'
    local_grads: bool = False          # defer the DP grad all-reduce out of
                                       # the microbatch loop (§Perf cell B)
    offload_master: bool = False       # ZeRO-1 m/v/ef on the pinned-host
                                       # slow tier; prefetched back during
                                       # the backward (DESIGN.md §15)
    profile_experts: bool = True       # NeoMem router-stream profiling
    sketch_width: int = 1 << 14


def _ep_context(cfg: ArchConfig, mesh) -> EPContext | None:
    if cfg.moe is None or mesh is None:
        return None
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return EPContext(mesh=mesh, expert_axis="model", fsdp_axis="data",
                     dp_axes=dp)


def build_train_step(cfg: ArchConfig, mesh, tcfg: TrainConfig = TrainConfig()):
    ep = _ep_context(cfg, mesh)
    opt_init, opt_update = make_optimizer(tcfg.opt)
    prof_params = NeoProfParams(sketch=SketchParams(width=tcfg.sketch_width))
    z1spec = None
    if tcfg.zero1:
        # the flat spec is trace-time static (shapes + treedef only), so it
        # lives in the closure, never in the jitted state pytree
        p_shapes = jax.eval_shape(
            lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
        z1spec = zero1.flat_spec(p_shapes, zero1._n_shards(mesh))

    def loss_fn(params, mb):
        loss, (metrics, aux) = tr.train_loss(cfg, params, mb,
                                             remat=tcfg.remat, ep_axes=ep)
        streams = aux.get("router_streams")
        return loss, (metrics, streams)

    def train_step(state, batch):
        params, opt_state, prof = state["params"], state["opt"], state["prof"]
        if tcfg.zero1 and tcfg.offload_master:
            # promote the parked master vectors FIRST: the fetch has no data
            # dependency on the grads, so XLA overlaps the host→device copy
            # with the whole backward below (prefetch-before-optimizer-step)
            opt_state = zero1.fetch_opt(opt_state, mesh)

        def micro(carry, mb):
            gacc, lacc = carry
            (loss, (_, streams)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gacc, grads)
            return (gacc, lacc + loss), streams

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # (B, ...) -> (M, B/M, ...) WITHOUT cross-shard movement: group rows
        # per DP shard first (dim0 stays DP-sharded), then swap to put the
        # microbatch axis in front.  batch.reshape(M, B/M, ...) would shuffle
        # rows across shards (all-to-all); this form is layout-local.
        m = tcfg.microbatches
        mbs = jax.tree.map(
            lambda x: x.reshape((x.shape[0] // m, m) + x.shape[1:]).swapaxes(0, 1),
            batch)

        if tcfg.local_grads and mesh is not None:
            # §Perf cell B: under plain pjit every microbatch's value_and_grad
            # ends in a full DP grad all-reduce INSIDE the scan (M x the
            # bytes).  Going manual over the DP axes keeps grads shard-local
            # through the accumulation; one psum after the loop does the job.
            dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            # satellite of ROADMAP item 4: under grad_compression the DP
            # all-reduce itself runs through the shared int8+EF core — each
            # shard quantizes its local sum and the wire carries int8 + one
            # fp32 scale per tensor instead of fp32 everywhere
            dp_compress = tcfg.grad_compression

            def grad_loop(params_l, mbs_l, ef_l):
                z = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params_l)

                def f(carry, mb):
                    gacc, lacc = carry
                    (loss, _), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params_l, mb)
                    gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                        gacc, grads)
                    return (gacc, lacc + loss), None

                (gsum, lsum), _ = jax.lax.scan(f, (z, 0.0), mbs_l)
                if dp_compress:
                    gsum, ef_l = compression.compress_psum(gsum, ef_l, dp)
                else:
                    gsum = jax.lax.psum(gsum, dp)
                lsum = jax.lax.psum(lsum, dp) / jax.lax.psum(1.0, dp)
                return gsum, lsum, ef_l

            pspec = jax.tree.map(lambda _: P(), params)
            mspec = jax.tree.map(lambda _: P(None, dp), mbs)
            ef_in = state["ef"] if dp_compress else jax.tree.map(
                lambda _: jnp.zeros((0,), jnp.float32), params)
            smap_kw = dict(mesh=mesh,
                           in_specs=(pspec, mspec, pspec),
                           out_specs=(pspec, P(), pspec),
                           check_rep=False)
            other = frozenset(mesh.axis_names) - frozenset(dp)
            if other:       # leave non-DP axes to the partitioner
                smap_kw["auto"] = other
            gsum, lsum, new_ef = shard_map(grad_loop, **smap_kw)(
                params, mbs, ef_in)
            streams = None
        else:
            (gsum, lsum), streams = jax.lax.scan(micro, (zero_g, 0.0), mbs)
            dp_compress = False
        grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        loss = lsum / tcfg.microbatches

        # NeoMem: profile the token->expert stream on-device
        if tcfg.profile_experts and cfg.moe is not None and streams is not None \
                and getattr(streams, "size", 0):
            page_stream = streams.reshape(-1)[: 8192].astype(jnp.int32)
            prof = neoprof_observe(prof, page_stream, prof_params)

        if tcfg.grad_compression and not dp_compress:
            # link-sim mode: compress AFTER the (uncompressed) reduce; under
            # local_grads the reduce itself was the compressed hop above
            qs, new_ef = compression.compress_grads(grads, state["ef"])
            grads = compression.decompress_grads(qs)
        if tcfg.zero1:
            new_params, new_opt, om = zero1.zero1_update(
                tcfg.opt, params, grads, opt_state, z1spec, mesh,
                compress_collective=tcfg.compress_collective)
            if tcfg.offload_master:
                new_opt = zero1.offload_opt(new_opt, mesh)
        else:
            new_params, new_opt, om = opt_update(params, grads, opt_state)

        new_state = dict(state, params=new_params, opt=new_opt, prof=prof)
        if tcfg.grad_compression:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, **om}
        if tcfg.local_grads and mesh is not None:
            # wire bytes ONE shard contributes to the DP grad reduce (static)
            metrics["dp_psum_bytes"] = compression.psum_bytes(
                grads, compressed=dp_compress)
        return new_state, metrics

    return train_step


def make_state_shapes(cfg: ArchConfig, tcfg: TrainConfig, mesh=None):
    """abstract (ShapeDtypeStruct) train state — no allocation (dry-run)."""
    opt_init, _ = make_optimizer(tcfg.opt)
    prof_params = NeoProfParams(sketch=SketchParams(width=tcfg.sketch_width))

    def init():
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "prof": neoprof_init(prof_params)}
        if tcfg.zero1:
            # zero1 state built separately (needs mesh) — placeholder zeros
            state["opt"] = {"m": jnp.zeros((1,), jnp.float32),
                            "v": jnp.zeros((1,), jnp.float32),
                            "step": jnp.zeros((), jnp.int32)}
            if tcfg.compress_collective:
                state["opt"]["ef"] = jnp.zeros((1,), jnp.float32)
        else:
            state["opt"] = opt_init(params)
        if tcfg.grad_compression:
            state["ef"] = compression.ef_init(params)
        return state

    return jax.eval_shape(init)


def state_shardings(state_shapes, mesh, fsdp: bool = False):
    """Shardings for the train state: params/opt by rule; prof replicated."""
    pspecs = param_pspecs(state_shapes["params"], mesh, fsdp=fsdp)

    def opt_specs(o):
        if isinstance(o, dict) and "m" in o and isinstance(o["m"], dict):
            return {"m": pspecs, "v": pspecs, "step": P()}      # AdamW
        if isinstance(o, dict) and "s" in o:                     # Adafactor
            def fact(shape_struct, ps):
                parts = tuple(ps)
                if len(shape_struct.shape) >= 2 and shape_struct.shape[-1] > 1 \
                        and shape_struct.shape[-2] > 1:
                    return {"vr": P(*parts[:-1]),
                            "vc": P(*(parts[:-2] + parts[-1:]))}
                return {"v": ps}
            s_specs = jax.tree.map(
                fact, state_shapes["params"], pspecs,
                is_leaf=lambda x: hasattr(x, "shape") or isinstance(x, P))
            return {"s": s_specs, "step": P()}
        # zero1: flat fp32 vectors sharded over every mesh axis
        def leaf(kp, l):
            if l.ndim == 1 and l.shape[0] > 1 << 16:
                return P(tuple(mesh.axis_names))
            return P(*([None] * l.ndim))
        return jax.tree_util.tree_map_with_path(leaf, o)

    specs = {
        "params": pspecs,
        "opt": opt_specs(state_shapes["opt"]),
        "prof": jax.tree.map(lambda l: P(*([None] * l.ndim)),
                             state_shapes["prof"]),
    }
    if "ef" in state_shapes:
        specs["ef"] = pspecs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(cfg: ArchConfig, mesh, with_aux: bool):
    bspec = batch_pspec(mesh)
    out = {"tokens": NamedSharding(mesh, bspec),
           "labels": NamedSharding(mesh, bspec)}
    if with_aux:
        out["aux_embeds"] = NamedSharding(
            mesh, P(bspec[0] if len(bspec) else None, None, None))
    return out
