"""Name/shape-based PartitionSpec inference for params, caches, and batches.

The placement policy of the distribution layer: every tensor is assigned a
tier (mesh axes) from its *name* (what role it plays) and its *shape* (what
actually divides).  Rules follow the Megatron conventions the model code is
written against:

  * MoE expert stacks (E, D, F)      -> expert dim over 'model' (EP)
  * column weights (D, F) / qkv proj -> output dim over 'model'
  * row weights (F, D) / out proj    -> contract dim over 'model'
  * embedding table (V, D)           -> vocab over 'model'
  * norms, biases, routers, scalars  -> replicated

Every rule checks divisibility against the mesh axis size and falls back to
replication when the dim does not divide — a spec produced here is always
valid to ``device_put`` against, on any mesh shape.  Leaves may be concrete
arrays or ``ShapeDtypeStruct``s (dry-run); only ``.shape`` is consulted, so
``jax.sharding.AbstractMesh`` works as the mesh in tests.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

_DP_AXES = ("pod", "data")

# trailing param names -> sharding role
_ROW = ("wo", "w_out", "sh_out", "out_proj")              # (F, D): shard F
_REPLICATED = ("router", "router_bias", "residency", "fetch_ids", "xgate")


def path_str(kp) -> str:
    """'blocks/0/ffn/w_in'-style string for a tree_util key path."""
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _dp(mesh) -> tuple:
    return tuple(a for a in _DP_AXES if a in mesh.axis_names)


def _mesh_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _fits(size: int, mesh, axes) -> bool:
    n = _mesh_size(mesh, axes)
    return n >= 1 and size >= n and size % n == 0


def param_pspecs(params, mesh, *, fsdp: bool = False):
    """PartitionSpec tree for a param pytree (same structure, P leaves).

    fsdp=True additionally shards one remaining dim of each >=2-D weight
    over the data axes (ZeRO-3 storage; compute all-gathers per layer).
    """
    model = "model" if "model" in mesh.axis_names else None
    dp = _dp(mesh)

    def infer(kp, leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd == 0:
            return P()
        p = path_str(kp)
        name = p.rsplit("/", 1)[-1]
        # params["blocks"] leaves are vmap-stacked over layer groups: the
        # leading G dim is scanned over, never sharded.
        lead = 1 if p.startswith("blocks") else 0
        dims = [None] * nd
        if name in _REPLICATED or nd - lead < 2:
            return P(*dims)          # norms, biases, routers, small maps

        if nd - lead == 3 and name.startswith(("w_", "fetch_")):
            tp = lead                # MoE expert stack (E, D, F): EP over E
        elif name in _ROW:
            tp = lead                # (F, D): row-parallel
        elif name == "table":
            tp = lead                # (V, D): shard the vocab
        else:
            tp = nd - 1              # column-parallel default
        if model is not None and _fits(shape[tp], mesh, model):
            dims[tp] = model

        if fsdp and dp:
            for axes in ((dp,) if len(dp) == 1 else (dp, dp[-1:])):
                hit = next((i for i in range(lead, nd)
                            if dims[i] is None and _fits(shape[i], mesh, axes)),
                           None)
                if hit is not None:
                    dims[hit] = axes if len(axes) > 1 else axes[0]
                    break
        return P(*dims)

    return jax.tree_util.tree_map_with_path(
        infer, params, is_leaf=lambda x: hasattr(x, "shape"))


def batch_pspec(mesh) -> P:
    """(B, S) token batches: rows over the data-parallel axes."""
    dp = _dp(mesh)
    return P(dp if dp else None, None)


def cache_pspecs(cache_shapes, mesh, *, slot_axes: tuple | None = None):
    """PartitionSpec tree for KV caches (full-sequence or paged).

    Default (full caches): batch dim over the data axes, k/v sequence dim
    over 'model' (the baseline decode layout — XLA all-gathers per layer).
    With ``slot_axes`` (paged caches, B=1 long-context): page slots sharded
    over the given axes, everything else replicated.
    """
    if slot_axes is not None:
        n_shards = _mesh_size(mesh, tuple(slot_axes))

        def leaf_paged(kp, l):
            p = path_str(kp)
            nd = len(l.shape)
            if nd == 0:
                return P()
            lead = 1 if "blocks" in p else 0
            dims = [None] * nd
            if any(s in p for s in ("k_pages", "v_pages", "page_len")) \
                    and nd > lead + 1 and l.shape[lead + 1] % n_shards == 0:
                dims[lead + 1] = tuple(slot_axes)
            return P(*dims)

        return jax.tree_util.tree_map_with_path(leaf_paged, cache_shapes)

    dp = _dp(mesh)
    dp_size = _mesh_size(mesh, dp)
    m = "model" if "model" in mesh.axis_names else None

    def leaf_full(kp, l):
        p = path_str(kp)
        nd = len(l.shape)
        if nd == 0:
            return P()
        lead = 1 if "blocks" in p else 0
        dims = [None] * nd
        if dp and nd > lead and l.shape[lead] % max(dp_size, 1) == 0 \
                and l.shape[lead] >= dp_size:
            dims[lead] = dp
        # seq dim of k/v caches: (lead, B, S, ...) -> index lead+1
        if any(p.endswith(suf) for suf in ("/k", "/v", "c_kv", "k_rope")) \
                and nd > lead + 1 and m \
                and l.shape[lead + 1] % mesh.shape["model"] == 0:
            dims[lead + 1] = m
        return P(*dims)

    return jax.tree_util.tree_map_with_path(leaf_full, cache_shapes)
