"""Fast/slow memory-tier placement by JAX ``memory_kind`` — NeoMem's tiers.

Device HBM is the fast tier (DRAM in the paper), pinned host memory the
slow tier (CXL-attached memory).  ``to_slow_tier`` / ``to_fast_tier`` move
an array between them with an explicit ``device_put``, the software
equivalent of a page migration.  Backends without memory-kind support
(CPU) degrade to *logical* separation: the array keeps its sharding and
the tier distinction is bookkeeping only, so tiering policy code runs
unchanged everywhere.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

SLOW_KIND = "pinned_host"

# success-only memo: a probe that fails (backend not up yet) is retried on
# the next call rather than pinning "no offload" for the whole process
_probe_cache: dict = {}


def _memory_kinds() -> tuple:
    if "kinds" not in _probe_cache:
        try:
            dev = jax.devices()[0]
            _probe_cache["kinds"] = tuple(
                sorted({m.kind for m in dev.addressable_memories()}))
        except Exception:
            return ()
    return _probe_cache["kinds"]


def _fast_kind() -> str | None:
    if "fast" not in _probe_cache:
        try:
            _probe_cache["fast"] = jax.devices()[0].default_memory().kind
        except Exception:
            return None
    return _probe_cache["fast"]


def supports_memory_kinds() -> bool:
    """True when the backend exposes a distinct host tier to offload into."""
    kinds = _memory_kinds()
    return SLOW_KIND in kinds and len(kinds) > 1


def _put(x, mesh, spec, kind):
    if kind is not None and supports_memory_kinds():
        return jax.device_put(x, NamedSharding(mesh, spec, memory_kind=kind))
    return jax.device_put(x, NamedSharding(mesh, spec))


def to_slow_tier(x, mesh, spec):
    """Demote: place x in the slow tier (pinned host) under ``spec``."""
    return _put(x, mesh, spec, SLOW_KIND)


def to_fast_tier(x, mesh, spec):
    """Promote: place x back in the fast tier (device memory)."""
    return _put(x, mesh, spec, _fast_kind())
