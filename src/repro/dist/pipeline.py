"""GPipe-style microbatch pipeline over one mesh axis (shard_map + ppermute).

Stage i's weights live on mesh shard i; microbatches enter at stage 0 and
flow stage-to-stage through a ``ppermute`` ring, one hop per tick — the
DMA engine of the distribution layer, overlapping stage compute with
activation movement.  The schedule is plain GPipe: m microbatches through
n stages take m + n - 1 ticks with the usual (n-1)/(m+n-1) bubble.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax
    from jax import shard_map  # type: ignore


def pipeline_apply(stage_fn, stage_params, x, *, mesh, axis: str):
    """Apply ``stage_fn(w_i, .)`` for i = 0..n-1 as a microbatch pipeline.

    stage_fn:     (stage weights, (mb, ...) activations) -> (mb, ...)
                  activations, shape- and dtype-preserving.
    stage_params: pytree with leaves stacked (n_stages, ...) — leaf i on
                  mesh shard i along ``axis``.
    x:            (n_micro, mb, ...) microbatched input, replicated.
    Returns stage_{n-1}(...stage_0(x)) per microbatch: (n_micro, mb, ...),
    replicated over the mesh.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    total = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(w_loc, x_all):
        w = jax.tree.map(lambda a: a[0], w_loc)      # this shard's stage
        idx = jax.lax.axis_index(axis)
        pad = jnp.zeros((n_stages - 1,) + x_all.shape[1:], x_all.dtype)
        feed = jnp.concatenate([x_all, pad], axis=0)   # (total, mb, ...)

        def tick(buf, t):
            # stage 0 pulls a fresh microbatch; others consume the ring
            inp = jnp.where(idx == 0, feed[t], buf)
            out = stage_fn(w, inp)
            return jax.lax.ppermute(out, axis, perm), out

        _, outs = jax.lax.scan(tick, jnp.zeros_like(x_all[0]),
                               jnp.arange(total))
        # microbatch j finishes on the last stage at tick j + n_stages - 1
        y = outs[n_stages - 1:]
        return jax.lax.psum(
            jnp.where(idx == n_stages - 1, y, jnp.zeros_like(y)), axis)

    wspec = jax.tree.map(lambda _: P(axis), stage_params)
    xspec = P(*([None] * x.ndim))
    return shard_map(body, mesh=mesh, in_specs=(wspec, xspec),
                     out_specs=xspec, check_rep=False)(stage_params, x)
