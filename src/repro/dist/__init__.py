"""Distribution layer: the software analogue of NeoMem's hardware tiers.

NeoMem co-designs a CXL-device-side profiler (NeoProf) with an OS tiering
engine so that hot pages live in fast DRAM and cold pages in slow CXL
memory, with migrations riding a bandwidth-limited link.  At production
scale the same three resources — fast memory, slow memory, and the
constrained channel between them — reappear inside a sharded training/
serving system.  Each module here maps one NeoMem hardware concept onto
its JAX/XLA equivalent:

  sharding.py      Page->tier placement maps.  Name/shape-based
                   PartitionSpec inference (``param_pspecs`` /
                   ``cache_pspecs`` / ``batch_pspec``) decides where every
                   tensor lives, with divisibility fallback to replication
                   — the static placement policy of the tiering engine.

  compression.py   The bandwidth-limited CXL link.  int8 + error-feedback
                   gradient compression (``compress_grads`` /
                   ``decompress_grads``) shrinks cross-device migration
                   traffic the way NeoMem's migration quota bounds
                   page-move bandwidth, while error feedback keeps the
                   stream unbiased over repeated transfers.

  pipeline.py      The DMA engine overlapping movement with compute.
                   ``pipeline_apply`` is a GPipe-style microbatch pipeline
                   (shard_map + ppermute) that keeps every device busy
                   while activations stream stage-to-stage.

  host_offload.py  The DRAM/CXL tier pair itself.  ``to_fast_tier`` /
                   ``to_slow_tier`` place arrays by JAX ``memory_kind``
                   (device HBM = fast, pinned host = slow) and degrade to
                   logical separation on backends without memory-kind
                   support (CPU), mirroring the paper's fallback to
                   software-managed tiering.
"""
from repro.dist import compression, host_offload, pipeline, sharding

__all__ = ["compression", "host_offload", "pipeline", "sharding"]
