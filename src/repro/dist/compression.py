"""int8 gradient compression with error feedback (the bandwidth-limited link).

Per-tensor symmetric quantization: scale = max|x| / 127, q = round(x/scale)
as int8 — a 4x traffic cut on the fp32 gradient all-reduce, the software
analogue of NeoMem's migration-bandwidth quota on the CXL link.  Error
feedback carries the quantization residual into the next step's input, so
the *accumulated* transferred signal is unbiased: over n repeats of the
same gradient the dequantized sum converges to n*g to within one quantum.

State contract (matches ``repro.train.step``):
    ef  = ef_init(params)                     # fp32 residuals, zeros
    qs, ef = compress_grads(grads, ef)        # qs is a pytree of packets
    grads  = decompress_grads(qs)             # original dtypes restored
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    """Zero error-feedback residuals: one fp32 buffer per param tensor."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _is_packet(x) -> bool:
    return isinstance(x, dict) and "q" in x and "scale" in x


def _compress_leaf(g, e):
    x = g.astype(jnp.float32) + e
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale > 0.0, scale, 1.0)   # all-zero tensor: q == 0
    q = jnp.round(x / scale).astype(jnp.int8)    # |x|/scale <= 127 by constr.
    packet = {"q": q, "scale": scale,
              # zero-size carrier so the original dtype survives the pytree
              "meta": jnp.zeros((0,), g.dtype)}
    # residual against what the receiver actually applies — including the
    # cast back to the gradient dtype — so low-precision grads stay unbiased
    applied = (q.astype(jnp.float32) * scale).astype(g.dtype)
    return packet, x - applied.astype(jnp.float32)


def compress_grads(grads, ef):
    """-> (packet pytree, new error-feedback residuals)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = treedef.flatten_up_to(ef)
    out = [_compress_leaf(g, e) for g, e in zip(leaves, ef_leaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def decompress_grads(qs):
    """Dequantize a packet pytree back to tensors in their original dtypes."""
    def one(t):
        return (t["q"].astype(jnp.float32) * t["scale"]).astype(t["meta"].dtype)

    return jax.tree.map(one, qs, is_leaf=_is_packet)


def compressed_bytes(qs) -> int:
    """Wire size of a packet tree (int8 payload + fp32 scale per tensor)."""
    total = 0
    for t in jax.tree_util.tree_leaves(qs, is_leaf=_is_packet):
        if _is_packet(t):
            total += int(t["q"].size) + 4
    return total
