"""int8 gradient compression with error feedback (the bandwidth-limited link).

Per-tensor symmetric quantization: scale = max|x| / 127, q = round(x/scale)
as int8 — a 4x traffic cut on the fp32 gradient all-reduce, the software
analogue of NeoMem's migration-bandwidth quota on the CXL link.  Error
feedback carries the quantization residual into the next step's input, so
the *accumulated* transferred signal is unbiased: over n repeats of the
same gradient the dequantized sum converges to n*g to within one quantum.

State contract (matches ``repro.train.step``):
    ef  = ef_init(params)                     # fp32 residuals, zeros
    qs, ef = compress_grads(grads, ef)        # qs is a pytree of packets
    grads  = decompress_grads(qs)             # original dtypes restored

The symmetric-int8 math itself lives in :mod:`repro.tiering.codec`
(DESIGN.md §14) — the same quantize/dequantize core the slow-tier row
codecs use, applied here with a per-TENSOR scale instead of per-row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# a LEAF module (jax-only imports): safe against the package-level
# tiering <-> dist import cycle in either import order
from repro.tiering.codec import dequantize_int8, quantize_int8


def ef_init(params):
    """Zero error-feedback residuals: one fp32 buffer per param tensor."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _is_packet(x) -> bool:
    return isinstance(x, dict) and "q" in x and "scale" in x


def _compress_leaf(g, e):
    x = g.astype(jnp.float32) + e
    q, scale = quantize_int8(x)                  # per-tensor symmetric scale
    packet = {"q": q, "scale": scale,
              # zero-size carrier so the original dtype survives the pytree
              "meta": jnp.zeros((0,), g.dtype)}
    # residual against what the receiver actually applies — including the
    # cast back to the gradient dtype — so low-precision grads stay unbiased
    applied = dequantize_int8(q, scale, g.dtype)
    return packet, x - applied.astype(jnp.float32)


def compress_grads(grads, ef):
    """-> (packet pytree, new error-feedback residuals)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = treedef.flatten_up_to(ef)
    out = [_compress_leaf(g, e) for g, e in zip(leaves, ef_leaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def decompress_grads(qs):
    """Dequantize a packet pytree back to tensors in their original dtypes."""
    def one(t):
        return dequantize_int8(t["q"], t["scale"], t["meta"].dtype)

    return jax.tree.map(one, qs, is_leaf=_is_packet)


def compress_psum(tree, ef, axes):
    """int8+EF compressed cross-shard ``psum`` — the DP gradient all-reduce
    under ``TrainConfig.local_grads`` (ROADMAP item 4's leftover).

    Each shard quantizes ``g + ef`` per tensor with the shared symmetric
    int8 core, the DEQUANTIZED tensors are summed across ``axes`` (on real
    fabrics the int8 payload + one fp32 scale per tensor is what the wire
    carries — see :func:`psum_bytes`), and the residual is psum-AVERAGED so
    the error-feedback state stays replicated across the manual axes:
    ``n * avg_residual`` equals the total un-sent signal, so the
    accumulated applied sum stays unbiased exactly as in
    :func:`compress_grads`.  Call INSIDE shard_map; returns
    ``(summed tree, new ef)`` with the sum cast back to each gradient's
    dtype.
    """
    n = jax.lax.psum(1.0, axes)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        applied = dequantize_int8(q, scale, jnp.float32)
        total = jax.lax.psum(applied, axes).astype(g.dtype)
        return total, jax.lax.psum(x - applied, axes) / n

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    ef_leaves = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(leaves, ef_leaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def psum_bytes(tree, compressed: bool) -> int:
    """Wire bytes ONE shard contributes to the DP grad psum: int8 payload
    plus a fp32 scale per tensor when compressed, the raw element bytes
    otherwise.  Static (shapes only) — computable outside the shard_map."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        total += (int(l.size) + 4 if compressed
                  else int(l.size) * l.dtype.itemsize)
    return total


def compressed_bytes(qs) -> int:
    """Wire size of a packet tree (int8 payload + fp32 scale per tensor)."""
    total = 0
    for t in jax.tree_util.tree_leaves(qs, is_leaf=_is_packet):
        if _is_packet(t):
            total += int(t["q"].size) + 4
    return total
