"""Data pipeline: deterministic, shardable, restart-safe token batches.

Two sources behind one interface:
  * SyntheticLM — seeded on-the-fly token streams (zipf-ish unigram mix so
    embedding-row tiering sees realistic skew);
  * MemmapDataset — flat uint16/int32 token files (numpy memmap), the
    production path: no copies, O(1) open, byte-range reads per host.

Batch indexing is a pure function of (step, dp_rank) — a restored checkpoint
resumes mid-epoch with zero state (fault tolerance requirement: the pipeline
itself never needs checkpointing).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab: int = 32000
    seed: int = 1234
    path: str | None = None      # memmap file -> MemmapDataset
    zipf_s: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_s)
        self.cdf = np.cumsum(w) / np.sum(w)
        self.perm = rng.permutation(cfg.vocab)

    def batch(self, step: int, dp_rank: int, dp_size: int):
        cfg = self.cfg
        assert cfg.global_batch % dp_size == 0
        local = cfg.global_batch // dp_size
        rng = np.random.default_rng(
            (cfg.seed, step, dp_rank))               # deterministic resume
        u = rng.random((local, cfg.seq_len + 1))
        toks = self.perm[np.searchsorted(self.cdf, u)].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapDataset:
    def __init__(self, cfg: DataConfig, dtype=np.int32):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_tokens = len(self.data)

    def batch(self, step: int, dp_rank: int, dp_size: int):
        cfg = self.cfg
        local = cfg.global_batch // dp_size
        span = cfg.seq_len + 1
        n_seqs = self.n_tokens // span
        rng = np.random.default_rng((cfg.seed, step))
        order = rng.permutation(n_seqs)              # per-step shuffle window
        base = (step * cfg.global_batch + dp_rank * local) % n_seqs
        idx = order[(base + np.arange(local)) % n_seqs]
        rows = np.stack([
            np.asarray(self.data[i * span:(i + 1) * span]) for i in idx
        ]).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_dataset(cfg: DataConfig):
    return MemmapDataset(cfg) if cfg.path else SyntheticLM(cfg)
