"""Jitted wrappers for paged decode attention.

``paged_attention``          — single-device (or replicated) call.
``paged_attention_sharded``  — fast-tier pages sharded across mesh axes;
    each shard runs the kernel over its local slots, then the partial
    (m, l, acc) flash-decode stats are combined with a max/psum pair —
    cross-device flash-decoding, the optimized serve path for long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn.paged_attn import (
    paged_attention as _kernel,
    paged_attention_raw as _kernel_raw,
)


def _interp():
    return jax.default_backend() != "tpu"


def paged_attention(q, k_pages, v_pages, page_lengths, *,
                    scale=None, softcap: float = 0.0, interpret=None):
    if interpret is None:
        interpret = _interp()
    return _kernel(q, k_pages, v_pages, page_lengths,
                   scale=scale, softcap=softcap, interpret=interpret)


def paged_attention_local_stats(q, k_pages, v_pages, page_lengths, *,
                                scale=None, softcap: float = 0.0,
                                interpret=None):
    if interpret is None:
        interpret = _interp()
    return _kernel_raw(q, k_pages, v_pages, page_lengths,
                       scale=scale, softcap=softcap, interpret=interpret)


def combine_stats(m, l, acc, axis_names):
    """Flash-decoding cross-shard softmax combine over ``axis_names``."""
    m_glob = jax.lax.pmax(m, axis_names)
    w = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * w, axis_names)
    acc_glob = jax.lax.psum(acc * w, axis_names)
    return acc_glob / jnp.maximum(l_glob, 1e-30)
