"""Jitted wrappers for paged decode attention.

``paged_attention``          — single-device (or replicated) call; with
    ``return_mass=True`` also yields the kernel-exported per-page softmax
    mass (the NeoProf-true "kv" hotness stream, DESIGN.md §10).
``paged_attention_local_stats`` — raw flash-decode stats; with
    ``return_page_stats=True`` additionally the page-local (m, l) partials.
    For fast-tier pages sharded across mesh axes, each shard runs this over
    its local slots (``models/decode.py::_append_attend_sharded`` — the
    cross-device flash-decoding serve path for long_500k) and merges via:
``combine_stats``            — the cross-shard combine (pmax/psum pair);
    given the page partials it also returns each LOCAL page's share of the
    GLOBAL softmax mass, normalized by the same pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn.paged_attn import (
    page_mass,
    paged_attention as _kernel,
    paged_attention_raw as _kernel_raw,
)

__all__ = ["paged_attention", "paged_attention_local_stats", "combine_stats",
           "page_mass"]


def _interp():
    return jax.default_backend() != "tpu"


def paged_attention(q, k_pages, v_pages, page_lengths, *,
                    scale=None, softcap: float = 0.0, interpret=None,
                    return_mass: bool = False):
    if interpret is None:
        interpret = _interp()
    return _kernel(q, k_pages, v_pages, page_lengths,
                   scale=scale, softcap=softcap, interpret=interpret,
                   return_mass=return_mass)


def paged_attention_local_stats(q, k_pages, v_pages, page_lengths, *,
                                scale=None, softcap: float = 0.0,
                                interpret=None,
                                return_page_stats: bool = False):
    if interpret is None:
        interpret = _interp()
    return _kernel_raw(q, k_pages, v_pages, page_lengths,
                       scale=scale, softcap=softcap, interpret=interpret,
                       return_page_stats=return_page_stats)


def combine_stats(m, l, acc, axis_names, page_m=None, page_l=None):
    """Flash-decoding cross-shard softmax combine over ``axis_names``.

    With the kernel's page partials (``page_m``/``page_l``, each shard's
    (B, P_local, H)) the result is ``(out, mass)`` where ``mass`` is the
    (B, P_local) share of the GLOBAL attention mass held by each local
    page — the normalizers (pmax/psum) are the very pair the output
    combine already needs, so the mass export adds no extra collective.
    """
    m_glob = jax.lax.pmax(m, axis_names)
    w = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * w, axis_names)
    acc_glob = jax.lax.psum(acc * w, axis_names)
    out = acc_glob / jnp.maximum(l_glob, 1e-30)
    if page_m is None:
        return out
    return out, page_mass(m_glob, l_glob, page_m, page_l)
