"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, page_lengths,
                        scale=None, softcap: float = 0.0):
    b, h, dk = q.shape
    _, p, t, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    groups = h // hkv
    scale = (dk ** -0.5) if scale is None else scale

    k = jnp.repeat(k_pages, groups, axis=3).reshape(b, p * t, h, dk)
    v = jnp.repeat(v_pages, groups, axis=3).reshape(b, p * t, h, dv)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tok = jnp.arange(p * t) % t
    page = jnp.arange(p * t) // t
    valid = tok[None, :] < page_lengths[:, page]            # (B, P*T)
    s = jnp.where(valid[:, None, :], s, -1e30)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = jnp.where(valid[:, None, :], w, 0.0)
    out = jnp.einsum("bht,bthd->bhd", w, v.astype(jnp.float32))
    out = out / jnp.maximum(jnp.sum(w, axis=-1)[..., None], 1e-30)
    return out.astype(q.dtype)
