"""Pure-jnp oracle for paged decode attention (+ per-page softmax mass)."""
from __future__ import annotations

import jax.numpy as jnp


def _scores(q, k_pages, page_lengths, scale, softcap):
    b, h, dk = q.shape
    _, p, t, hkv, _ = k_pages.shape
    groups = h // hkv
    scale = (dk ** -0.5) if scale is None else scale
    k = jnp.repeat(k_pages, groups, axis=3).reshape(b, p * t, h, dk)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tok = jnp.arange(p * t) % t
    page = jnp.arange(p * t) // t
    valid = tok[None, :] < page_lengths[:, page]            # (B, P*T)
    return jnp.where(valid[:, None, :], s, -1e30), valid


def paged_attention_ref(q, k_pages, v_pages, page_lengths,
                        scale=None, softcap: float = 0.0):
    b, h, _ = q.shape
    _, p, t, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    groups = h // hkv
    v = jnp.repeat(v_pages, groups, axis=3).reshape(b, p * t, h, dv)
    s, valid = _scores(q, k_pages, page_lengths, scale, softcap)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = jnp.where(valid[:, None, :], w, 0.0)
    out = jnp.einsum("bht,bthd->bhd", w, v.astype(jnp.float32))
    out = out / jnp.maximum(jnp.sum(w, axis=-1)[..., None], 1e-30)
    return out.astype(q.dtype)


def softmax_denominator_ref(q, k_pages, page_lengths,
                            scale=None, softcap: float = 0.0):
    """(max (B,H), denom (B,H)): the flash-decode (m, l) ground truth —
    global score max and Σ exp(s - m) over every valid token."""
    s, valid = _scores(q, k_pages, page_lengths, scale, softcap)
    m = jnp.max(s, axis=-1)                                 # (B, H)
    w = jnp.where(valid[:, None, :], jnp.exp(s - m[..., None]), 0.0)
    return m, jnp.sum(w, axis=-1)


def page_mass_ref(q, k_pages, page_lengths,
                  scale=None, softcap: float = 0.0):
    """(B, P) per-page share of softmax mass, head-averaged (valid pages
    sum to 1) — the oracle for the kernel's page-stats export."""
    b, h, _ = q.shape
    _, p, t, _, _ = k_pages.shape
    s, valid = _scores(q, k_pages, page_lengths, scale, softcap)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = jnp.where(valid[:, None, :], w, 0.0)                # (B, H, P*T)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    per_page = jnp.sum(w.reshape(b, h, p, t), axis=-1)      # (B, H, P)
    return jnp.mean(per_page, axis=1)
