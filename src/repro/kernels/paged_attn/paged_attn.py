"""Paged decode attention over NeoMem-resident hot KV pages (Pallas TPU).

The serving hot path for tiered long-context decode (DESIGN.md §3.2): one new
query token attends over the fast-tier-resident KV *pages* selected by the
NeoMem policy.  Flash-decoding style online softmax, gridded over pages so
each page's KV block streams HBM->VMEM exactly once; (m, l, acc) running
stats live in revisited output blocks (the TPU grid is sequential over the
last axis, so read-modify-write accumulation is well-defined).

Supports GQA (q heads grouped over kv heads), per-page token counts (partial
last page), invalid-page masking (pages the tiering layer could not promote)
and gemma2-style logit soft-capping.

NeoProf mass export (DESIGN.md §10): with ``return_page_stats=True`` the
kernel additionally writes per-page PER-HEAD softmax partials — the page's
local score max ``page_m`` and local denominator ``page_l = Σ exp(s -
page_m)`` — in the SAME VMEM pass that computes the output (the hardware
analogue of NeoProf snooping access intensity at line rate: zero extra HBM
reads).  Rescaled against the global (m, l) they yield each page's true
share of the step's attention mass; that rescale lives in ``ops.page_mass``
and, for the sharded path, ``ops.combine_stats``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_attn_kernel(
    q_ref,        # (1, H, dh)
    k_ref,        # (1, 1, T, Hkv, dh)  — one page
    v_ref,        # (1, 1, T, Hkv, dh)
    len_ref,      # (1, 1) int32 — valid tokens in this page (0 => invalid)
    m_ref,        # (1, H, 1)  f32 running max
    l_ref,        # (1, H, 1)  f32 running denom
    acc_ref,      # (1, H, dh) f32 running numerator
    pm_ref=None,  # (1, 1, H)  f32 page-local score max (page-stats mode)
    pl_ref=None,  # (1, 1, H)  f32 page-local denom     (page-stats mode)
    *, scale: float, softcap: float, groups: int,
):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (H, dh)
    k = k_ref[0, 0].astype(jnp.float32)                   # (T, Hkv, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    t, hkv, dh = k.shape
    h = q.shape[0]
    n_valid = len_ref[0, 0]

    # GQA: repeat kv heads across the query-head groups.
    k = jnp.repeat(k, groups, axis=1)                     # (T, H, dh)
    v = jnp.repeat(v, groups, axis=1)

    s = jnp.einsum("hd,thd->ht", q, k,
                   preferred_element_type=jnp.float32) * scale   # (H, T)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tok = jax.lax.broadcasted_iota(jnp.int32, (h, t), 1)
    s = jnp.where(tok < n_valid, s, NEG_INF)

    m_page = jnp.max(s, axis=1)                           # (H,) page-local max
    m_prev = m_ref[0, :, 0]                               # (H,)
    m_cur = jnp.maximum(m_prev, m_page)
    # guard fully-masked pages: keep m finite math stable
    alpha = jnp.exp(jnp.minimum(m_prev - m_cur, 0.0))
    p_ij = jnp.exp(s - m_cur[:, None])
    p_ij = jnp.where(tok < n_valid, p_ij, 0.0)

    l_cur = l_ref[0, :, 0] * alpha + jnp.sum(p_ij, axis=1)
    acc = acc_ref[0] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p_ij, v, preferred_element_type=jnp.float32)

    m_ref[0, :, 0] = m_cur
    l_ref[0, :, 0] = l_cur
    acc_ref[0] = acc

    if pm_ref is not None:
        # page-local partials under the page's OWN max — rescaled to the
        # global max outside the kernel (ops.page_mass / combine_stats), so
        # this page's block never needs revisiting.
        p_loc = jnp.where(tok < n_valid, jnp.exp(s - m_page[:, None]), 0.0)
        pm_ref[0, 0] = m_page
        pl_ref[0, 0] = jnp.sum(p_loc, axis=1)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret",
                                             "return_page_stats"))
def paged_attention_raw(
    q: jax.Array,          # (B, H, dh)
    k_pages: jax.Array,    # (B, P, T, Hkv, dk)
    v_pages: jax.Array,    # (B, P, T, Hkv, dv)
    page_lengths: jax.Array,  # (B, P) int32 — 0 marks an invalid page
    *, scale: float | None = None, softcap: float = 0.0,
    interpret: bool = True, return_page_stats: bool = False,
):
    """Unnormalized flash-decode stats (m, l, acc) — for cross-shard combine.

    With ``return_page_stats`` the result is (m, l, acc, page_m, page_l)
    where ``page_m``/``page_l`` are the (B, P, H) page-local softmax
    partials (see module docstring) — fully-masked pages report
    ``page_m = NEG_INF, page_l = 0``.
    """
    b, h, dh = q.shape
    _, p, t, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    groups = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    kern = functools.partial(
        _paged_attn_kernel, scale=scale, softcap=softcap, groups=groups)

    out_specs = [
        pl.BlockSpec((1, h, 1), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, h, 1), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, h, dv), lambda i, j: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        jax.ShapeDtypeStruct((b, h, dv), jnp.float32),
    ]
    if return_page_stats:
        out_specs += [pl.BlockSpec((1, 1, h), lambda i, j: (i, j, 0)),
                      pl.BlockSpec((1, 1, h), lambda i, j: (i, j, 0))]
        out_shape += [jax.ShapeDtypeStruct((b, p, h), jnp.float32),
                      jax.ShapeDtypeStruct((b, p, h), jnp.float32)]

    outs = pl.pallas_call(
        kern,
        grid=(b, p),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, t, hkv, dh), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, t, hkv, dv), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q, k_pages, v_pages, page_lengths.astype(jnp.int32))
    return tuple(outs)


def paged_attention(q, k_pages, v_pages, page_lengths, *,
                    scale=None, softcap: float = 0.0, interpret: bool = True,
                    return_mass: bool = False):
    """Normalized paged decode attention.

    ``return_mass=True`` additionally returns the (B, P) per-page share of
    the step's softmax mass (head-averaged; masses of the valid pages sum
    to 1) — the kernel-true hotness stream for the "kv" tiered resource
    (DESIGN.md §10)."""
    if not return_mass:
        m, l, acc = paged_attention_raw(
            q, k_pages, v_pages, page_lengths,
            scale=scale, softcap=softcap, interpret=interpret)
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    m, l, acc, page_m, page_l = paged_attention_raw(
        q, k_pages, v_pages, page_lengths, scale=scale, softcap=softcap,
        interpret=interpret, return_page_stats=True)
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out, page_mass(m, l, page_m, page_l)


def page_mass(m: jax.Array, l: jax.Array,
              page_m: jax.Array, page_l: jax.Array) -> jax.Array:
    """Normalize page-local partials into per-page softmax mass.

    ``m``/``l``: (B, H, 1) global running max/denominator; ``page_m``/
    ``page_l``: (B, P, H) page-local partials.  Returns (B, P) f32 — each
    page's head-averaged share of total attention mass (valid pages sum to
    1; fully-masked pages contribute exactly 0)."""
    m_glob = jnp.swapaxes(m, 1, 2)                        # (B, 1, H)
    l_glob = jnp.swapaxes(l, 1, 2)
    mass = page_l * jnp.exp(page_m - m_glob) / jnp.maximum(l_glob, 1e-30)
    return jnp.mean(mass, axis=-1)
