"""Paged decode attention over NeoMem-resident hot KV pages (Pallas TPU).

The serving hot path for tiered long-context decode (DESIGN.md §3.2): one new
query token attends over the fast-tier-resident KV *pages* selected by the
NeoMem policy.  Flash-decoding style online softmax, gridded over pages so
each page's KV block streams HBM->VMEM exactly once; (m, l, acc) running
stats live in revisited output blocks (the TPU grid is sequential over the
last axis, so read-modify-write accumulation is well-defined).

Supports GQA (q heads grouped over kv heads), per-page token counts (partial
last page), invalid-page masking (pages the tiering layer could not promote)
and gemma2-style logit soft-capping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_attn_kernel(
    q_ref,        # (1, H, dh)
    k_ref,        # (1, 1, T, Hkv, dh)  — one page
    v_ref,        # (1, 1, T, Hkv, dh)
    len_ref,      # (1, 1) int32 — valid tokens in this page (0 => invalid)
    m_ref,        # (1, H, 1)  f32 running max
    l_ref,        # (1, H, 1)  f32 running denom
    acc_ref,      # (1, H, dh) f32 running numerator
    *, scale: float, softcap: float, groups: int,
):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (H, dh)
    k = k_ref[0, 0].astype(jnp.float32)                   # (T, Hkv, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    t, hkv, dh = k.shape
    h = q.shape[0]
    n_valid = len_ref[0, 0]

    # GQA: repeat kv heads across the query-head groups.
    k = jnp.repeat(k, groups, axis=1)                     # (T, H, dh)
    v = jnp.repeat(v, groups, axis=1)

    s = jnp.einsum("hd,thd->ht", q, k,
                   preferred_element_type=jnp.float32) * scale   # (H, T)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tok = jax.lax.broadcasted_iota(jnp.int32, (h, t), 1)
    s = jnp.where(tok < n_valid, s, NEG_INF)

    m_prev = m_ref[0, :, 0]                               # (H,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked pages: keep m finite math stable
    alpha = jnp.exp(jnp.minimum(m_prev - m_cur, 0.0))
    p_ij = jnp.exp(s - m_cur[:, None])
    p_ij = jnp.where(tok < n_valid, p_ij, 0.0)

    l_cur = l_ref[0, :, 0] * alpha + jnp.sum(p_ij, axis=1)
    acc = acc_ref[0] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p_ij, v, preferred_element_type=jnp.float32)

    m_ref[0, :, 0] = m_cur
    l_ref[0, :, 0] = l_cur
    acc_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def paged_attention_raw(
    q: jax.Array,          # (B, H, dh)
    k_pages: jax.Array,    # (B, P, T, Hkv, dk)
    v_pages: jax.Array,    # (B, P, T, Hkv, dv)
    page_lengths: jax.Array,  # (B, P) int32 — 0 marks an invalid page
    *, scale: float | None = None, softcap: float = 0.0,
    interpret: bool = True,
):
    """Unnormalized flash-decode stats (m, l, acc) — for cross-shard combine."""
    b, h, dh = q.shape
    _, p, t, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    groups = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    kern = functools.partial(
        _paged_attn_kernel, scale=scale, softcap=softcap, groups=groups)

    m, l, acc = pl.pallas_call(
        kern,
        grid=(b, p),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, t, hkv, dh), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, t, hkv, dv), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, h, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, h, dv), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_pages, v_pages, page_lengths.astype(jnp.int32))
    return m, l, acc


def paged_attention(q, k_pages, v_pages, page_lengths, *,
                    scale=None, softcap: float = 0.0, interpret: bool = True):
    m, l, acc = paged_attention_raw(
        q, k_pages, v_pages, page_lengths,
        scale=scale, softcap=softcap, interpret=interpret)
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)
