"""NeoProf sketch-update Pallas TPU kernel (paper Fig. 7/8, TPU-native).

Hardware adaptation (DESIGN.md §2): the ASIC pipeline's per-address
scatter-increment has no efficient TPU analogue (VMEM scatter serializes on
the VPU), so the update is re-expressed as a *segment-tiled one-hot
compare-reduce*: the sketch row is tiled into lane-aligned segments (the
grid dimension — the TPU version of the paper's K=128 memory sub-blocks),
and within a (stream-block x segment) cell the counter deltas are a bincount
computed as a reduction over the S x Wseg one-hot matrix — MXU/VPU-friendly
dense work instead of serialized scatter.

Two passes over the segment grid:
  pass A (update):  counts += bincount(h(p)); emits per-element post-update
                    counter reads (est) and pre-update hot-bit reads,
                    accumulated across segments (each element lands in
                    exactly one segment per lane).
  pass B (mark):    after the host of the kernel (ops.py) reduces est ->
                    is_hot, scatter the hot bits with the same one-hot trick.

H3 hashing (paper Eq. 5) is an unrolled 30-step XOR-select over the page-id
bits — pure VPU bit logic, identical to the hardware reduction tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sketch import PAGE_ID_BITS

DEFAULT_SEG = 512  # lanes per sketch segment (multiple of 128)


def _h3_all_lanes(page_ids: jax.Array, seeds: jax.Array, depth: int) -> jax.Array:
    """(S,) ids + (D, PAGE_ID_BITS) seeds -> (D, S) hashed indices."""
    h = jnp.zeros((depth, page_ids.shape[0]), jnp.int32)
    for bit in range(PAGE_ID_BITS):
        mask = ((page_ids >> bit) & 1) > 0          # (S,)
        h = jnp.where(mask[None, :], h ^ seeds[:, bit][:, None], h)
    return h


def _update_kernel(
    # scalar-prefetch style inputs arrive as plain refs (all in VMEM)
    ids_ref,      # (1, S) int32 page ids (-1 pad)
    seeds_ref,    # (D, PAGE_ID_BITS) int32
    meta_ref,     # (1, 4) int32: [cur_epoch, counter_max, valid(unused), S]
    counts_ref,   # (D, Wseg) int32   — block of the sketch segment
    epochs_ref,   # (D, Wseg) int32
    hot_ref,      # (D, Wseg) int32
    out_counts,   # (D, Wseg) int32
    out_epochs,   # (D, Wseg) int32
    est_ref,      # (D, S) int32      — accumulated across segments
    hotbefore_ref,  # (D, S) int32
    *, seg: int, depth: int,
):
    k = pl.program_id(0)
    ids = ids_ref[0, :]                              # (S,)
    valid = (ids >= 0)
    h = _h3_all_lanes(jnp.where(valid, ids, 0), seeds_ref[...], depth)  # (D,S)

    cur_epoch = meta_ref[0, 0]
    cmax = meta_ref[0, 1]

    local = h - k * seg                               # (D, S)
    in_seg = (local >= 0) & (local < seg) & valid[None, :]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (depth, ids.shape[0], seg), 2)
    onehot = (local[:, :, None] == lanes) & in_seg[:, :, None]   # (D,S,Wseg)
    onehot_i = onehot.astype(jnp.int32)

    delta = jnp.sum(onehot_i, axis=1)                 # (D, Wseg) bincount
    live = jnp.where(epochs_ref[...] == cur_epoch, counts_ref[...], 0)
    new_counts = jnp.minimum(live + delta, cmax)
    out_counts[...] = new_counts
    out_epochs[...] = jnp.full_like(epochs_ref[...], cur_epoch)

    # per-element post-update counter read + pre-update hot-bit read,
    # via the same one-hot matrix (each element is in exactly one segment)
    est_seg = jnp.sum(onehot_i * new_counts[:, None, :], axis=2)      # (D,S)
    hot_seg = jnp.sum(onehot_i * hot_ref[...][:, None, :], axis=2)    # (D,S)

    @pl.when(k == 0)
    def _init():
        est_ref[...] = jnp.zeros_like(est_ref)
        hotbefore_ref[...] = jnp.zeros_like(hotbefore_ref)

    est_ref[...] += est_seg
    hotbefore_ref[...] += hot_seg


def _mark_kernel(
    ids_ref, seeds_ref, ishot_ref,
    hot_ref, out_hot,
    *, seg: int, depth: int,
):
    k = pl.program_id(0)
    ids = ids_ref[0, :]
    valid = ids >= 0
    h = _h3_all_lanes(jnp.where(valid, ids, 0), seeds_ref[...], depth)
    local = h - k * seg
    is_hot = (ishot_ref[0, :] > 0) & valid
    in_seg = (local >= 0) & (local < seg) & is_hot[None, :]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (depth, ids.shape[0], seg), 2)
    onehot = (local[:, :, None] == lanes) & in_seg[:, :, None]
    mark = jnp.max(onehot.astype(jnp.int32), axis=1)          # (D, Wseg)
    out_hot[...] = jnp.maximum(hot_ref[...], mark)


@functools.partial(
    jax.jit, static_argnames=("seg", "depth", "width", "interpret"))
def sketch_update_pallas(
    counts: jax.Array,   # (D, W) int32
    epochs: jax.Array,   # (D, W) int32
    hot: jax.Array,      # (D, W) int32
    page_ids: jax.Array,  # (S,) int32
    seeds: jax.Array,    # (D, PAGE_ID_BITS) int32
    cur_epoch: jax.Array,  # () int32
    counter_max: int,
    *, seg: int = DEFAULT_SEG, depth: int = 2, width: int = 1 << 14,
    interpret: bool = True,
):
    """Pass A: returns (new_counts, new_epochs, est (D,S), hot_before (D,S))."""
    s = page_ids.shape[0]
    grid = width // seg
    assert grid * seg == width, "width must be a multiple of seg"
    meta = jnp.stack([
        cur_epoch.astype(jnp.int32), jnp.int32(counter_max),
        jnp.int32(0), jnp.int32(s)]).reshape(1, 4)
    kern = functools.partial(_update_kernel, seg=seg, depth=depth)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, s), lambda k: (0, 0)),                 # ids
            pl.BlockSpec((depth, PAGE_ID_BITS), lambda k: (0, 0)),  # seeds
            pl.BlockSpec((1, 4), lambda k: (0, 0)),                 # meta
            pl.BlockSpec((depth, seg), lambda k: (0, k)),           # counts
            pl.BlockSpec((depth, seg), lambda k: (0, k)),           # epochs
            pl.BlockSpec((depth, seg), lambda k: (0, k)),           # hot
        ],
        out_specs=[
            pl.BlockSpec((depth, seg), lambda k: (0, k)),           # counts'
            pl.BlockSpec((depth, seg), lambda k: (0, k)),           # epochs'
            pl.BlockSpec((depth, s), lambda k: (0, 0)),             # est
            pl.BlockSpec((depth, s), lambda k: (0, 0)),             # hot_before
        ],
        out_shape=[
            jax.ShapeDtypeStruct((depth, width), jnp.int32),
            jax.ShapeDtypeStruct((depth, width), jnp.int32),
            jax.ShapeDtypeStruct((depth, s), jnp.int32),
            jax.ShapeDtypeStruct((depth, s), jnp.int32),
        ],
        interpret=interpret,
    )(page_ids.reshape(1, -1), seeds, meta, counts, epochs, hot)


@functools.partial(
    jax.jit, static_argnames=("seg", "depth", "width", "interpret"))
def sketch_mark_hot_pallas(
    hot: jax.Array,       # (D, W) int32
    page_ids: jax.Array,  # (S,) int32
    is_hot: jax.Array,    # (S,) int32/bool
    seeds: jax.Array,
    *, seg: int = DEFAULT_SEG, depth: int = 2, width: int = 1 << 14,
    interpret: bool = True,
):
    """Pass B: OR the hot bits of every detected-hot element's entries."""
    s = page_ids.shape[0]
    grid = width // seg
    kern = functools.partial(_mark_kernel, seg=seg, depth=depth)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, s), lambda k: (0, 0)),
            pl.BlockSpec((depth, PAGE_ID_BITS), lambda k: (0, 0)),
            pl.BlockSpec((1, s), lambda k: (0, 0)),
            pl.BlockSpec((depth, seg), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((depth, seg), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        interpret=interpret,
    )(page_ids.reshape(1, -1), seeds, is_hot.astype(jnp.int32).reshape(1, -1), hot)
