"""Pure-jnp oracle for the neoprof_update kernel (block-synchronous semantics).

This mirrors repro.core.sketch.sketch_update exactly; it exists separately so
kernel tests compare kernel vs oracle without importing the stateful API.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import PAGE_ID_BITS


def h3_ref(page_ids: jax.Array, seeds: jax.Array) -> jax.Array:
    depth = seeds.shape[0]
    h = jnp.zeros((depth,) + page_ids.shape, jnp.int32)
    for bit in range(PAGE_ID_BITS):
        mask = ((page_ids >> bit) & 1).astype(jnp.bool_)
        h = jnp.where(mask[None], h ^ seeds[:, bit][:, None], h)
    return h


def update_ref(counts, epochs, hot, page_ids, seeds, cur_epoch, counter_max):
    """Returns (new_counts, new_epochs, est (D,S), hot_before (D,S))."""
    valid = page_ids >= 0
    idx = h3_ref(jnp.where(valid, page_ids, 0), seeds)           # (D, S)
    live = jnp.where(epochs == cur_epoch, counts, 0)
    new_counts = jax.vmap(lambda c, i: c.at[i].add(valid.astype(jnp.int32)))(live, idx)
    new_counts = jnp.minimum(new_counts, counter_max)
    new_epochs = jnp.full_like(epochs, cur_epoch)
    est = jax.vmap(lambda c, i: c[i])(new_counts, idx)
    est = jnp.where(valid[None], est, 0)
    hot_before = jax.vmap(lambda hh, i: hh[i])(hot, idx)
    hot_before = jnp.where(valid[None], hot_before, 0)
    return new_counts, new_epochs, est, hot_before


def mark_hot_ref(hot, page_ids, is_hot, seeds):
    valid = (page_ids >= 0) & (is_hot > 0)
    idx = h3_ref(jnp.where(page_ids >= 0, page_ids, 0), seeds)
    return jax.vmap(lambda hh, i: hh.at[i].max(valid.astype(jnp.int32)))(hot, idx)
