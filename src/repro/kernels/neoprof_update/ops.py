"""Jitted public wrapper: full sketch_update with the Pallas fast path.

Drop-in replacement for repro.core.sketch.sketch_update (same signature and
semantics) that routes the heavy per-segment work through the TPU kernel and
keeps the cheap cross-lane reduction (min over lanes, hot filter,
first-occurrence dedup) in plain jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sketch import SketchParams, SketchState, _first_occurrence
from repro.kernels.neoprof_update import neoprof_update as ku


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def sketch_update(
    state: SketchState,
    page_ids: jax.Array,
    theta: jax.Array,
    params: SketchParams,
    interpret: bool | None = None,
) -> tuple[SketchState, jax.Array]:
    interpret = _interpret_default() if interpret is None else interpret
    valid = page_ids >= 0
    counts = state.counts
    epochs = state.epochs.astype(jnp.int32)
    hot = state.hot.astype(jnp.int32)

    new_counts, new_epochs, est, hot_before = ku.sketch_update_pallas(
        counts, epochs, hot, page_ids, state.seeds,
        state.cur_epoch.astype(jnp.int32), params.counter_max,
        depth=params.depth, width=params.width, interpret=interpret,
    )
    est_min = jnp.min(est, axis=0)
    already_hot = jnp.all(hot_before > 0, axis=0)
    is_hot = valid & (est_min > theta)
    newly_hot = is_hot & ~already_hot & _first_occurrence(
        jnp.where(valid, page_ids, 0), valid)

    new_hot = ku.sketch_mark_hot_pallas(
        hot, page_ids, is_hot, state.seeds,
        depth=params.depth, width=params.width, interpret=interpret,
    )
    new_state = state._replace(
        counts=new_counts,
        epochs=new_epochs.astype(state.epochs.dtype),
        hot=new_hot.astype(state.hot.dtype),
        n_seen=state.n_seen + jnp.sum(valid, dtype=jnp.int32),
    )
    return new_state, newly_hot
