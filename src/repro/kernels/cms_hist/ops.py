"""Jitted wrapper: sketch histogram via the Pallas histogram unit."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import SketchParams, SketchState
from repro.kernels.cms_hist import cms_hist as kh


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def sketch_histogram(state: SketchState, params: SketchParams,
                     interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    edges = jnp.asarray(sk.hist_edges(params.counter_bits))
    return kh.hist_pallas(
        state.counts[0], state.epochs[0].astype(jnp.int32),
        state.cur_epoch.astype(jnp.int32), edges,
        width=params.width, interpret=interpret,
    )
