"""Pure-jnp oracle for the cms_hist kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sketch import HIST_BINS


def hist_ref(counts_row0, epochs_row0, cur_epoch, edges):
    live = jnp.where(epochs_row0 == cur_epoch, counts_row0, 0)
    bin_idx = jnp.clip(jnp.searchsorted(edges, live, side="right") - 1, 0, HIST_BINS - 1)
    return jnp.zeros((HIST_BINS,), jnp.int32).at[bin_idx].add(1)
