"""NeoProf histogram-unit Pallas kernel (paper Fig. 9).

64-bin histogram over the row-0 sketch counters, so the host reads 64 scalars
instead of W counters (the paper's argument: don't ship the sketch over the
link).  Segment-gridded compare-reduce: for each lane-aligned segment of the
counter row, bin membership is a (Wseg x 64) comparison against the static
bin edges, reduced over the segment and accumulated across the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sketch import HIST_BINS

DEFAULT_SEG = 512


def _hist_kernel(counts_ref, epochs_ref, meta_ref, edges_ref, out_ref, *, seg):
    k = pl.program_id(0)
    cur_epoch = meta_ref[0, 0]
    live = jnp.where(epochs_ref[0, :] == cur_epoch, counts_ref[0, :], 0)  # (Wseg,)
    lo = edges_ref[0, :]                       # (HIST_BINS,) lower edges
    hi = edges_ref[1, :]                       # (HIST_BINS,) upper edges
    member = (live[:, None] >= lo[None, :]) & (live[:, None] < hi[None, :])
    part = jnp.sum(member.astype(jnp.int32), axis=0)        # (HIST_BINS,)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :] += part


@functools.partial(jax.jit, static_argnames=("seg", "width", "interpret"))
def hist_pallas(
    counts_row0: jax.Array,   # (W,) int32
    epochs_row0: jax.Array,   # (W,) int32
    cur_epoch: jax.Array,     # () int32
    edges: jax.Array,         # (HIST_BINS + 1,) int32
    *, seg: int = DEFAULT_SEG, width: int = 1 << 14, interpret: bool = True,
) -> jax.Array:
    grid = width // seg
    assert grid * seg == width
    lo_hi = jnp.stack([edges[:-1], edges[1:]])               # (2, HIST_BINS)
    meta = cur_epoch.astype(jnp.int32).reshape(1, 1)
    kern = functools.partial(_hist_kernel, seg=seg)
    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, seg), lambda k: (0, k)),
            pl.BlockSpec((1, seg), lambda k: (0, k)),
            pl.BlockSpec((1, 1), lambda k: (0, 0)),
            pl.BlockSpec((2, HIST_BINS), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, HIST_BINS), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, HIST_BINS), jnp.int32),
        interpret=interpret,
    )(counts_row0.reshape(1, -1), epochs_row0.reshape(1, -1), meta, lo_hi)
    return out[0]
