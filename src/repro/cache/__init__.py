"""Content-addressed KV page cache (DESIGN.md §12).

Cross-request reuse of completed paged-KV pages: page-granular content +
chain hashing, a refcounted shared pool over the KV slow store, and
prefix / interior-substring admission matching for `serve.sched`.
"""
from repro.cache.store import KVReuseStore, MatchResult, hash_pages

__all__ = ["KVReuseStore", "MatchResult", "hash_pages"]
