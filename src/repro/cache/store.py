"""Content-addressed KV page store — cross-request paged-cache reuse.

The serve engine's paged ring flushes completed KV pages into per-request
slow-store segments (DESIGN.md §9).  This module adds a *shared pool* of
slow-store pages behind a content-addressed index so identical prompt
spans — system prompts, RAG documents, multi-turn conversation history —
are prefilled once and re-admitted pre-resident (DESIGN.md §12).

Hash scheme (two hashes per completed page):

* ``content[j]`` — FNV-1a over page ``j``'s own token ids.  Position- and
  context-independent: the *index key*.  Identical token spans anywhere
  in any prompt map to the same bucket.
* ``chain[j]`` — ``content[j]`` folded over every preceding page's
  content hash.  A transformer KV page's bytes depend on the FULL causal
  prefix (every earlier token attends into it) and on the page's absolute
  position (RoPE is applied to K at append time), so byte-exact reuse
  requires prefix identity at the same offset.  ``chain`` witnesses the
  prefix; the per-page position offset stored with each entry guards the
  absolute position.  A lookup hits only when content, chain AND offset
  all agree — which makes every hit bit-exact by construction.

Matching modes (`KVReuseStore.match`):

* ``prefix`` — walk pages from offset 0, stop at the first miss
  (vLLM-style prefix caching).
* ``substring`` — verify every full page of the prompt independently and
  skip holes: a miss at page j does not forfeit a verified run at j+1.
  Strictly a superset of ``prefix``.  The gap is what agentic workloads
  measure (SNIPPETS.md Snippet 1: MemGPT substring 93.4% vs prefix
  43.9%): capacity churn evicts the LRU *front* of a sleeping
  conversation's history while its interior stays indexed, and a
  mutating working-context block invalidates the tail — stop-at-first-
  miss recovers nothing, hole-skipping recovers the surviving interior.

Refcount lifecycle: ``match`` acquires one reference per matched page for
the admitted request; the scheduler releases them when the request
finishes (references survive preempt/resume — the lane changes, the
request's claim does not).  ``publish`` indexes a finished request's
pages into pool pages, evicting refcount-zero entries in LRU order when
the pool is full; pages still referenced by a live lane are never
reclaimed.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def hash_pages(tokens, page_t: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-page content hashes and rolling chain hashes.

    tokens: 1-D int token ids; only complete pages are hashed.
    Returns ``(content, chain)`` uint64 arrays of length ``len(tokens)
    // page_t``: ``content[j]`` covers page j's tokens alone, ``chain[j]``
    folds ``content[0..j]`` in order (the causal-prefix witness).
    """
    toks = np.asarray(tokens).astype(np.int64, copy=False).ravel()
    n_full = toks.size // page_t
    content = np.empty(n_full, np.uint64)
    chain = np.empty(n_full, np.uint64)
    h_chain = _FNV_OFFSET
    for j in range(n_full):
        h = _FNV_OFFSET
        for t in toks[j * page_t:(j + 1) * page_t]:
            h = ((h ^ (int(t) & _MASK64)) * _FNV_PRIME) & _MASK64
        content[j] = h
        h_chain = ((h_chain ^ h) * _FNV_PRIME) & _MASK64
        chain[j] = h_chain
    return content, chain


@dataclasses.dataclass
class MatchResult:
    """Admission-time match: ``pages`` maps local page index -> pool gid."""

    pages: dict[int, int]
    n_matchable: int


class KVReuseStore:
    """Refcounted content-addressed index over a pool of slow-store pages.

    The pool is ``n_pages`` extra pages appended to the KV slow store,
    global ids ``[base_gid, base_gid + n_pages)`` — segment pages below
    ``base_gid`` stay private to their request.  The store only does
    bookkeeping (index, refcounts, LRU, free list); payload movement is
    the engine's job (`ServeEngine.publish_lane` / `install_lane_pages`).
    """

    def __init__(self, n_pages: int, base_gid: int, page_t: int):
        if n_pages <= 0:
            raise ValueError("reuse pool needs n_pages > 0")
        self.n_pages = int(n_pages)
        self.base_gid = int(base_gid)
        self.page_t = int(page_t)
        self.free: list[int] = list(range(self.base_gid + self.n_pages - 1,
                                          self.base_gid - 1, -1))
        # content hash -> {(chain hash, page offset): pool gid}
        self.index: dict[int, dict[tuple[int, int], int]] = {}
        self.ref: dict[int, int] = {}
        self.key_of: dict[int, tuple[int, int, int]] = {}
        self.lru: OrderedDict[int, None] = OrderedDict()  # oldest first
        # counters (lifetime; benches diff them per arm/window)
        self.lookups = 0
        self.matchable = 0
        self.page_hits = 0
        self.tokens_saved = 0
        self.published = 0
        self.evicted = 0
        self.rejected = 0

    # ------------------------------------------------------------- match
    def is_shared(self, gid: int) -> bool:
        return gid >= self.base_gid

    def lookup_page(self, content: int, chain: int, offset: int):
        return self.index.get(int(content), {}).get((int(chain), int(offset)))

    def match(self, tokens, mode: str = "substring") -> MatchResult:
        """Match a prompt's full pages against the index.

        Only pages whose last token is strictly before the prompt's final
        token are matchable — the final token's forward pass produces the
        first-token logits, so its page must be scanned, never installed.
        Acquires one reference per matched page (release on finish).

        ``lookups``/``matchable``/``page_hits`` are LOOKUP stats, counted
        here; ``tokens_saved`` is counted only when the engine actually
        consumes an install run (`note_consumed`) — a match abandoned
        before installation saves nothing.
        """
        if mode not in ("prefix", "substring"):
            raise ValueError(f"unknown match mode {mode!r}")
        toks = np.asarray(tokens).ravel()
        content, chain = hash_pages(toks, self.page_t)
        n_match = max(0, (toks.size - 1) // self.page_t)
        matched: dict[int, int] = {}
        for j in range(n_match):
            gid = self.lookup_page(content[j], chain[j], j)
            if gid is None:
                if mode == "prefix":
                    break
                continue
            matched[j] = gid
        self.lookups += 1
        self.matchable += n_match
        self.page_hits += len(matched)
        for gid in matched.values():
            self.ref[gid] = self.ref.get(gid, 0) + 1
            self.lru.move_to_end(gid)
        return MatchResult(pages=matched, n_matchable=n_match)

    def note_consumed(self, n_pages: int) -> None:
        """Record ``n_pages`` matched pages actually installed into a lane
        (prefill work truly skipped) — the engine calls this from
        `install_lane_pages`, so ``tokens_saved`` never counts a match
        that was preempted and abandoned before consumption."""
        self.tokens_saved += int(n_pages) * self.page_t

    def release(self, gids) -> None:
        """Drop one reference per gid (request finished / match abandoned)."""
        for gid in gids:
            r = self.ref.get(int(gid), 0)
            if r <= 0:
                raise ValueError(f"release of unreferenced pool page {gid}")
            self.ref[int(gid)] = r - 1

    # ----------------------------------------------------------- publish
    def publish(self, tokens, n_pages: int,
                mask=None) -> list[tuple[int, int]]:
        """Index the first ``n_pages`` full pages of a finished stream.

        Returns ``[(local page idx, pool gid)]`` for pages that are NEW —
        the caller must copy their payload into the pool before the next
        match can hand them out.  Already-indexed pages are deduplicated
        (and LRU-touched); pages that don't fit once every refcount-zero
        entry is evicted are dropped and counted in ``rejected``.
        ``mask[j]=False`` skips page j (the caller couldn't witness a
        valid slow-store payload for it — e.g. it wrapped off the ring
        between flushes).
        """
        toks = np.asarray(tokens).ravel()
        content, chain = hash_pages(toks, self.page_t)
        out: list[tuple[int, int]] = []
        for j in range(min(int(n_pages), content.size)):
            if mask is not None and not mask[j]:
                continue
            key = (int(chain[j]), j)
            c = int(content[j])
            dup = self.index.get(c, {}).get(key)
            if dup is not None:
                self.lru.move_to_end(dup)
                continue
            gid = self._alloc()
            if gid is None:
                self.rejected += 1
                continue
            # _alloc's eviction may have mutated (or deleted) this content
            # bucket — bind it only now, after allocation succeeded.
            self.index.setdefault(c, {})[key] = gid
            self.key_of[gid] = (c,) + key
            self.ref.setdefault(gid, 0)
            self.lru[gid] = None
            self.published += 1
            out.append((j, gid))
        return out

    def _alloc(self) -> int | None:
        if self.free:
            return self.free.pop()
        for gid in self.lru:  # oldest first; only refcount-zero reclaimable
            if self.ref.get(gid, 0) == 0:
                self._evict(gid)
                return gid
        return None

    def _evict(self, gid: int) -> None:
        c, ch, off = self.key_of.pop(gid)
        bucket = self.index[c]
        del bucket[(ch, off)]
        if not bucket:
            del self.index[c]
        del self.lru[gid]
        self.ref.pop(gid, None)
        self.evicted += 1

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "pool_pages": self.n_pages,
            "indexed": len(self.key_of),
            "free": len(self.free),
            "shared_refs": int(sum(self.ref.values())),
            "lookups": self.lookups,
            "matchable": self.matchable,
            "page_hits": self.page_hits,
            "hit_rate": self.page_hits / max(1, self.matchable),
            "tokens_saved": self.tokens_saved,
            "published": self.published,
            "evicted": self.evicted,
            "rejected": self.rejected,
        }
