"""Trace-driven multi-tenant workloads: the traffic NeoMem's payoff needs.

The paper evaluates adaptivity against *shifting* access patterns (dynamic
hotspots, antagonist scans); HybridTier stresses workload drift, and the
CXL-at-scale study shows contention tails are the metric that matters.  This
module generates the request-level analogue: seeded, replayable arrival
traces for the scheduler (`serve/sched.py`), where each tenant draws prompt
CONTENT from a distribution the tiering daemon can (or cannot) exploit:

  * ``zipf-hot``        — every tenant samples tokens from a static Zipf
                          head: a stable hot set the sketch should find and
                          pin (the daemon's best case).
  * ``diurnal-shift``   — the Zipf head rotates through the vocab every
                          ``shift_period`` scheduler steps: the hot set
                          drifts and the placement map must follow
                          (Fig. 16-style convergence, continuously).
  * ``scan-antagonist`` — tenant 0 keeps its Zipf head while tenant 1 sweeps
                          the vocab sequentially: the scan has no reusable
                          hot set, thrashes promotions, and drags the
                          steady-state hit rate below ``zipf-hot`` — the
                          adaptivity gap the traffic benchmark asserts.
  * ``prefill-heavy``   — a prompt-length mixture built for the prefill/
                          decode disaggregation A/B (DESIGN.md §13): a
                          "chat" tenant streams short prompts with LONG
                          outputs (steady decode occupancy) while a "doc"
                          tenant drops long prompts with short outputs
                          (each arrival is a prefill wall).  Under the
                          unified scheduler every doc prompt's chunk scans
                          stall the chat tenant's decode steps; with a
                          dedicated prefill pool the walls move off the
                          decode worker's clock — the TPOT-flatness gate
                          ``benchmarks/traffic_bench.py`` asserts.  Token
                          content is the static Zipf head (as ``zipf-hot``);
                          the SHAPE mixture is the workload.  Defaults to
                          :data:`PREFILL_HEAVY_TENANTS` when no explicit
                          tenant set is passed.
  * ``prod-mixture``    — production prompt-LENGTH mixture: each arrival
                          draws its prompt length from a two-component
                          lognormal — a dominant short conversational mode
                          plus a long-context document tail — the bimodal
                          shape public serving traces show (the Azure LLM
                          inference traces of the Splitwise/DistServe line
                          of work: most requests are short, the byte mass
                          lives in the tail).  Token content is the static
                          Zipf head (as ``zipf-hot``), so against
                          ``zipf-hot`` it isolates what REALISTIC length
                          dispersion — ragged prefill walls, uneven segment
                          occupancy — does to tiering and scheduling.
                          Lengths are clipped to the KV segment budget
                          (``max_total`` minus the output reservation).
  * ``agentic``         — multi-turn tool-agent sessions, the workload the
                          content-addressed KV store (DESIGN.md §12) exists
                          for.  Each tenant owns one fixed system prompt S;
                          each conversation replays its FULL context every
                          turn: ``prompt_t = S + u_1 .. u_t + W_t`` where
                          the user-turn history is append-only and ``W_t``
                          is a fixed-length working block (scratchpad /
                          tool output) that MUTATES between turns.  Because
                          the mutation sits at the END, every history page
                          keeps a stable causal-chain hash turn over turn —
                          so cross-turn KV reuse is exact, and when pool
                          pressure evicts front-of-history pages, substring
                          matching recovers the surviving tail while prefix
                          matching stalls at the first hole (the
                          MemGPT-style gap ``kv_reuse`` asserts).  Turns of
                          one conversation are spaced ``turn_gap`` steps
                          apart so turn ``t`` publishes before ``t+1``
                          arrives; for this kind ``prompt_len`` bounds the
                          per-TURN user block, not the whole prompt.

Arrival PROCESSES are deliberately identical across the three content kinds
for the same (seed, arrival) pair (same per-step draws, same prompt/output
lengths) — only token content differs, so hit-rate deltas between traces
measure the access pattern, not accidental load differences.  ``agentic``
and ``prod-mixture`` are the exceptions: the agentic session structure
(spaced turns, growing prompts) and the lognormal length draws ARE those
workloads, so their structural draw sequences diverge from the shared-load
trio by construction.

Two arrival processes (the CXL-at-scale study's point: tails live in the
bursts, not the means):

  * ``bernoulli`` — independent P(arrival)=rate per tenant per step (the
    original process; the default).
  * ``mmpp``      — a 2-state Markov-modulated Bernoulli process: one
    hidden calm/burst chain (drawn from the shared STRUCTURAL stream, so
    every kind sees the same bursts) scales all tenants' rates by
    ``calm_scale``/``burst_scale``.  The stationary mean rate equals the
    Bernoulli process's, so MMPP changes burstiness — queueing, p99,
    preemption pressure — with the same offered load.  (Mean parity
    requires ``rate * MMPP_BURST_SCALE <= 1``; a hotter tenant saturates
    at probability 1 during bursts and ``make_trace`` warns.)
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

TRACE_KINDS = ("zipf-hot", "diurnal-shift", "scan-antagonist",
               "prefill-heavy", "agentic", "prod-mixture")
ARRIVAL_KINDS = ("bernoulli", "mmpp")

# ``prod-mixture`` length model: (meanlog, sdlog) per lognormal component
# and the short component's mixture share.  exp(meanlog) ~ median length:
# ~7-token conversational prompts ~70% of the time, a ~27-token document
# tail otherwise — the bimodal public-trace shape scaled to the serve
# benches' max_seq=56 segments.
PROD_MIX_SHORT = (1.9, 0.45)
PROD_MIX_LONG = (3.3, 0.25)
PROD_MIX_SHORT_SHARE = 0.7

# MMPP defaults: calm->burst 0.05, burst->calm 0.25 => stationary burst
# share 1/6; burst triples the rate and calm_scale is solved so the
# stationary mean equals the plain Bernoulli rate.
MMPP_P01, MMPP_P10 = 0.05, 0.25
MMPP_BURST_SCALE = 3.0
_PI_B = MMPP_P01 / (MMPP_P01 + MMPP_P10)
MMPP_CALM_SCALE = (1.0 - _PI_B * MMPP_BURST_SCALE) / (1.0 - _PI_B)


@dataclasses.dataclass(frozen=True)
class TenantProfile:
    """One tenant's traffic shape (weights feed the scheduler's fair split)."""

    name: str
    weight: float = 1.0
    rate: float = 0.2              # P(one arrival) per scheduler step
    prompt_len: tuple[int, int] = (8, 17)    # [lo, hi) token range
    out_len: tuple[int, int] = (4, 13)       # [lo, hi) output-token range


@dataclasses.dataclass(frozen=True)
class Arrival:
    step: int                      # scheduler step the request arrives at
    tenant: str
    tokens: np.ndarray             # (P,) int32 prompt
    max_new: int


@dataclasses.dataclass(frozen=True)
class Trace:
    kind: str
    seed: int
    vocab: int
    n_steps: int
    tenants: tuple[TenantProfile, ...]
    arrivals: tuple[Arrival, ...]
    arrival: str = "bernoulli"     # arrival process (see module docstring)

    def by_step(self) -> dict[int, list[Arrival]]:
        out: dict[int, list[Arrival]] = {}
        for a in self.arrivals:
            out.setdefault(a.step, []).append(a)
        return out


DEFAULT_TENANTS = (
    TenantProfile("interactive", weight=2.0, rate=0.22,
                  prompt_len=(6, 13), out_len=(4, 9)),
    TenantProfile("batch", weight=1.0, rate=0.12,
                  prompt_len=(10, 21), out_len=(8, 17)),
)

# The disaggregation A/B's shape mixture (``kind="prefill-heavy"``):
# "chat" keeps decode lanes streaming, "doc" keeps dropping prompt walls.
# Sized for the serve benches' max_seq=56 segments (prompt + out <= 45).
PREFILL_HEAVY_TENANTS = (
    TenantProfile("chat", weight=2.0, rate=0.25,
                  prompt_len=(4, 9), out_len=(14, 21)),
    TenantProfile("doc", weight=1.0, rate=0.09,
                  prompt_len=(28, 41), out_len=(2, 5)),
)


@functools.lru_cache(maxsize=8)
def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1, dtype=np.float64) ** a
    return p / p.sum()


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int, a: float,
                 phase: int) -> np.ndarray:
    """Zipf-ranked tokens; ``phase`` rotates which ids form the hot head."""
    ranks = rng.choice(vocab, size=n, p=_zipf_probs(vocab, a))
    return ((ranks + phase) % vocab).astype(np.int32)


def _agentic_arrivals(struct: np.random.Generator,
                      content: np.random.Generator,
                      tenants: tuple[TenantProfile, ...], *, n_steps: int,
                      vocab: int, zipf_a: float, turn_gap: int,
                      sys_len: int, n_convs: int, work_len: int,
                      max_total: int) -> list[Arrival]:
    """Multi-turn sessions: ``prompt_t = S + u_1 .. u_t + W_t``.

    The system prompt S is per-TENANT (every conversation of a tenant
    shares it — those pages stay hot in the reuse pool); the user-turn
    history is append-only (stable chain hashes, the reuse substrate); the
    working block W_t re-draws every turn (the mutation that ends prefix
    matching exactly at the history/working boundary).  A conversation
    stops growing when the next turn's prompt + output would exceed
    ``max_total`` (the scheduler rejects requests longer than a KV
    segment), and its turns are ``turn_gap``-spaced with small structural
    jitter so the previous turn has published before the next arrives.
    """
    arrivals: list[Arrival] = []
    for t in tenants:
        sys_p = content.integers(0, vocab, size=sys_len).astype(np.int32)
        for _ in range(n_convs):
            step = int(struct.integers(0, max(1, n_steps // 3)))
            history = [sys_p]
            hist_len = sys_len
            while step < n_steps:
                ulen = int(struct.integers(*t.prompt_len))
                n_out = int(struct.integers(*t.out_len))
                if hist_len + ulen + work_len + n_out > max_total:
                    break                      # context budget exhausted
                history.append(
                    _zipf_tokens(content, ulen, vocab, zipf_a, 0))
                hist_len += ulen
                work = content.integers(0, vocab, size=work_len
                                        ).astype(np.int32)
                arrivals.append(Arrival(
                    step=step, tenant=t.name,
                    tokens=np.concatenate(history + [work]),
                    max_new=n_out))
                step += turn_gap + int(struct.integers(0, 4))
    arrivals.sort(key=lambda a: (a.step, a.tenant))
    return arrivals


def make_trace(kind: str, *, n_steps: int = 200, vocab: int = 256,
               tenants: tuple[TenantProfile, ...] = DEFAULT_TENANTS,
               seed: int = 0, zipf_a: float = 1.4,
               shift_period: int = 64, arrival: str = "bernoulli",
               turn_gap: int = 24, sys_len: int = 12, n_convs: int = 3,
               work_len: int = 4, max_total: int = 56) -> Trace:
    """Build one seeded, replayable arrival trace (see module docstring).

    The structural draws (the MMPP modulation chain, arrival steps,
    prompt/output lengths) come from a dedicated RNG stream shared by every
    kind; token content comes from a second stream — so for a fixed
    (seed, arrival) pair, traces of different kinds carry the SAME load at
    the same steps and differ only in what they touch.  The ``turn_gap`` /
    ``sys_len`` / ``n_convs`` / ``work_len`` knobs apply to
    ``kind="agentic"`` only (see :func:`_agentic_arrivals`); ``max_total``
    also caps ``kind="prod-mixture"``'s lognormal prompt lengths.
    """
    if kind not in TRACE_KINDS:
        raise KeyError(f"unknown trace kind {kind!r}; known: {TRACE_KINDS}")
    if arrival not in ARRIVAL_KINDS:
        raise KeyError(
            f"unknown arrival process {arrival!r}; known: {ARRIVAL_KINDS}")
    if kind == "prefill-heavy" and tenants is DEFAULT_TENANTS:
        tenants = PREFILL_HEAVY_TENANTS   # the mixture IS the workload
    struct = np.random.default_rng(np.random.SeedSequence([seed, 0xA11]))
    content = np.random.default_rng(np.random.SeedSequence([seed, 0xB22]))
    if kind == "agentic":
        arrivals = _agentic_arrivals(
            struct, content, tenants, n_steps=n_steps, vocab=vocab,
            zipf_a=zipf_a, turn_gap=turn_gap, sys_len=sys_len,
            n_convs=n_convs, work_len=work_len, max_total=max_total)
        return Trace(kind=kind, seed=seed, vocab=vocab, n_steps=n_steps,
                     tenants=tuple(tenants), arrivals=tuple(arrivals),
                     arrival=arrival)
    # The MMPP calm/burst chain is drawn FIRST, from the structural stream:
    # identical modulation (and identical subsequent draws) for every kind.
    rate_scale = np.ones(n_steps)
    if arrival == "mmpp":
        hot = [t.name for t in tenants if t.rate * MMPP_BURST_SCALE > 1.0]
        if hot:
            import warnings
            warnings.warn(
                f"MMPP burst rate saturates at 1 for tenants {hot} "
                f"(rate > {1.0 / MMPP_BURST_SCALE:.3f}): the stationary "
                "mean will fall below the Bernoulli process's",
                stacklevel=2)
        state = 0                               # start calm (stationary mode)
        for step in range(n_steps):
            flip = struct.random()
            state = (1 - state) if flip < (MMPP_P01, MMPP_P10)[state] else state
            rate_scale[step] = (MMPP_CALM_SCALE, MMPP_BURST_SCALE)[state]
    scan_cursor = 0
    arrivals: list[Arrival] = []
    for step in range(n_steps):
        for ti, t in enumerate(tenants):
            if struct.random() >= min(1.0, t.rate * rate_scale[step]):
                continue
            if kind == "prod-mixture":
                # two-component lognormal prompt length (struct stream —
                # this kind is exempt from the identical-load invariant),
                # clipped to what fits a KV segment next to the output
                n_out = int(struct.integers(*t.out_len))
                mu, sig = (PROD_MIX_SHORT
                           if struct.random() < PROD_MIX_SHORT_SHARE
                           else PROD_MIX_LONG)
                plen = int(np.clip(int(round(struct.lognormal(mu, sig))),
                                   1, max(1, max_total - n_out - 1)))
            else:
                plen = int(struct.integers(*t.prompt_len))
                n_out = int(struct.integers(*t.out_len))
            if kind == "scan-antagonist" and ti == 1:
                # the antagonist sweeps the vocab with no reuse
                tokens = ((scan_cursor + np.arange(plen)) % vocab
                          ).astype(np.int32)
                scan_cursor = (scan_cursor + plen) % vocab
            else:
                phase = ((step // shift_period) * (vocab // 3)
                         if kind == "diurnal-shift" else 0)
                tokens = _zipf_tokens(content, plen, vocab, zipf_a, phase)
            arrivals.append(Arrival(step=step, tenant=t.name, tokens=tokens,
                                    max_new=n_out))
    return Trace(kind=kind, seed=seed, vocab=vocab, n_steps=n_steps,
                 tenants=tuple(tenants), arrivals=tuple(arrivals),
                 arrival=arrival)


def play(trace: Trace, sched, *, max_steps: int | None = None,
         on_step=None) -> None:
    """Replay a trace into a Scheduler: submit each step's arrivals, step
    the engine, then drain until every request finished.  ``on_step`` (if
    given) is called with the scheduler after every step — benchmark hooks
    such as the steady-state counter snapshot."""
    due = trace.by_step()
    horizon = max_steps or max(2000, 50 * trace.n_steps)
    # drain through Scheduler.active: queued, pooled (decode AND prefill
    # lanes), and hand-offs in flight all keep the loop going
    while sched.step_count < trace.n_steps or sched.active:
        if sched.step_count >= horizon:
            raise RuntimeError(f"trace undrained after {horizon} steps")
        for a in due.get(sched.step_count, []):
            sched.submit(a.tenant, a.tokens, a.max_new)
        sched.step()
        if on_step is not None:
            on_step(sched)
