"""repro.workloads — trace-driven multi-tenant workload generators.

Seeded, replayable arrival traces (zipf-hot / diurnal-shift /
scan-antagonist / prefill-heavy / agentic / prod-mixture) for the
continuous-batching scheduler; see :mod:`repro.workloads.traces` and
DESIGN.md §9 / §12 / §13.
"""
from repro.workloads.traces import (  # noqa: F401
    ARRIVAL_KINDS, DEFAULT_TENANTS, PREFILL_HEAVY_TENANTS, TRACE_KINDS,
    Arrival, TenantProfile, Trace, make_trace, play,
)
