"""The three built-in tiered resources: KV pages, MoE experts, vocab rows.

Each is a ~30-line stream encoder over :class:`~repro.tiering.resource
.StreamResource` — the adapter surface the old ``core/adapters`` classes
hand-wired three times now reduces to (DESIGN.md §3):

  §3.1 experts ..... router token->expert ids, page = (group, expert)
  §3.2 KV pages .... pages carrying non-trivial attention softmax mass
  §3.3 embeddings .. token ids mapped to vocab row-blocks

Payloads: the serve engine declares each resource's row shape/dtype in its
:class:`ResourceSpec` and binds real model data (embedding rows, expert
weight blocks, flushed KV pages), so daemon epochs move actual bytes
through the migration data plane — see DESIGN.md §8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tiering.resource import ResourceSpec, StreamResource, register_resource

EMBED_ROWS_PER_PAGE = 64


def _subsample(pages: jax.Array, cap: int) -> jax.Array:
    """Deterministic stride subsampling to the NeoProf line-rate block size."""
    if pages.shape[0] > cap:
        pages = pages[:: pages.shape[0] // cap][:cap]
    return pages


@register_resource("kv")
class KVPagesResource(StreamResource):
    """Paged-KV cache (§3.2): a page is hot if it carries attention mass.

    The access stream is the set of page ids whose content contributed
    non-trivial softmax mass at a decode step — the analogue of LLC misses
    to CXL memory: pages the model actually pulled from.  The mass is the
    KERNEL-exported per-page softmax share (`kernels/paged_attn` page
    stats, DESIGN.md §10) — true access intensity measured where the
    access happens, as NeoProf snoops the bus; the serve engine's old
    `page_len` fill proxy survives only as the A/B baseline
    (``ServeConfig.kv_mass_source="fill"``).
    """

    def __init__(self, spec: ResourceSpec, mass_threshold: float = 0.02,
                 migrate_fn=None):
        super().__init__(spec, migrate_fn)
        self.mass_threshold = mass_threshold

    def encode_stream(self, page_mass: jax.Array,
                      page_ids: jax.Array) -> jax.Array:
        """(P,) per-page softmax mass + ids -> ids with cold pages masked -1."""
        total = jnp.maximum(jnp.sum(page_mass), 1e-30)
        keep = page_mass / total >= self.mass_threshold
        return jnp.where(keep, page_ids.astype(jnp.int32), -1).reshape(-1)


@register_resource("experts")
class ExpertStreamResource(StreamResource):
    """MoE expert weights (§3.1): page_id = group * n_experts + expert."""

    def __init__(self, spec: ResourceSpec, n_experts: int, migrate_fn=None):
        super().__init__(spec, migrate_fn)
        self.n_experts = n_experts

    def encode_stream(self, router_streams: jax.Array) -> jax.Array:
        """(G, n_moe, ..., k) router expert indices -> flat page stream.

        Negative router entries are padding (e.g. inactive scheduler lanes
        masked out of the stream) and stay -1 after encoding.
        """
        g = router_streams.shape[0]
        group_ids = jnp.arange(g, dtype=jnp.int32).reshape(
            (g,) + (1,) * (router_streams.ndim - 1))
        router = router_streams.astype(jnp.int32)
        pages = jnp.where(router >= 0, group_ids * self.n_experts + router,
                          -1).reshape(-1)
        return _subsample(pages, self.spec.stream_cap)


@register_resource("embeddings")
class EmbedRowsResource(StreamResource):
    """Vocab tables (§3.3): the access stream is the model's own input."""

    def __init__(self, spec: ResourceSpec,
                 rows_per_page: int = EMBED_ROWS_PER_PAGE, migrate_fn=None):
        super().__init__(spec, migrate_fn)
        self.rows_per_page = rows_per_page

    def encode_stream(self, tokens: jax.Array) -> jax.Array:
        pages = (tokens.reshape(-1) // self.rows_per_page).astype(jnp.int32)
        return _subsample(pages, self.spec.stream_cap)
