"""repro.tiering — the unified NeoMem tiering surface (DESIGN.md §1).

One API for every consumer of slow memory:

  ResourceSpec / TieredResource / registry ... declare a consumer
  TieredMemory / TieredMemoryState ........... pure profiling + placement
  NeoMemDaemon (multiplexed) ................. one loop, N resources
  TierStats .................................. one telemetry schema
  migrate / TierBuffers ...................... the data plane (DESIGN.md §8)
  codec ...................................... slow-store wire formats (§14)

The legacy ``repro.core.adapters`` classes and ``repro.core.daemon`` are
thin deprecation shims over this package.
"""
from repro.tiering.codec import (  # noqa: F401
    CODECS, decode_rows, dequantize_int8, encode_rows, quantize_int8,
    wire_row_bytes,
)
from repro.tiering.daemon import (  # noqa: F401
    NeoMemDaemon, ResourceHandle, split_quota,
)
from repro.tiering.memory import (  # noqa: F401
    DaemonParams, MigrationEvent, TieredMemory, TieredMemoryState, lookup,
    observe,
)
from repro.tiering.migrate import (  # noqa: F401
    TierBuffers, init_buffers, lookup_rows, read_rows, segment_page_ids,
    write_rows,
)
from repro.tiering.resource import (  # noqa: F401
    ResourceSpec, StreamResource, TieredResource, make_resource,
    register_resource, resource_kinds,
)
from repro.tiering.resources import (  # noqa: F401
    EMBED_ROWS_PER_PAGE, EmbedRowsResource, ExpertStreamResource,
    KVPagesResource,
)
from repro.tiering.stats import TierStats, drain_tier_stats, hit_rate  # noqa: F401
