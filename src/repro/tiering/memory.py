"""TieredMemory — profiling + placement for ONE resource, as a pytree facade.

Replaces the mutable ``self.prof`` / ``self.tier`` pattern of the old
adapters: all device-resident state (NeoProf sketch/buffers, TieredStore
placement, Algorithm-1 scalars) lives in a single :class:`TieredMemoryState`
pytree threaded through pure functions, so profiling composes with
jit/pjit/shard_map.  The split mirrors the paper's hardware/software line:

  * :func:`observe` / :func:`lookup` — pure, jittable, run inside the model
    step (the device side: NeoProf snoop + tier hit accounting);
  * :meth:`TieredMemory.tick` — host side, runs the daemon cadences
    (migration << threshold-update <= clear, paper §V) against the state and
    returns promotion batches for the owner to apply.

The host side keeps exactly two non-pytree artifacts: the overflow queue of
hot pages awaiting quota (a numpy FIFO, as in the kernel daemon) and the
:class:`~repro.tiering.stats.TierStats` telemetry accumulator — plus, when
payload data is bound via :meth:`TieredMemory.bind_data`, the
:class:`~repro.tiering.migrate.TierBuffers` pair the migration data plane
copies through (DESIGN.md §8: one fused donated copy per epoch, bytes
metered against the per-epoch quota).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiering
from repro.core.neoprof import (NeoProfCommands, NeoProfParams, NeoProfState,
                                neoprof_init, neoprof_observe)
from repro.core.policy import PolicyParams, PolicyState
from repro.core.policy import update_threshold as _algorithm1
from repro.core.tiering import TierParams, TierState
from repro.tiering import codec as codec_lib
from repro.tiering import migrate as migrate_lib
from repro.tiering.stats import TierStats, drain_tier_stats
from repro.tiering.stats import hit_rate as _hit_rate

MAX_PENDING = 1 << 14        # overflow queue bound (pages awaiting quota)


@dataclasses.dataclass
class DaemonParams:
    """Cadence hierarchy (DESIGN.md §1.3): migration ticks are the base rate.

    ``quota_pages=None`` resolves context-dependently: a single-resource
    TieredMemory uses its TierParams quota; the multiplexed daemon uses the
    sum of its resources' quotas as the shared budget.
    """

    migration_interval: int = 1        # ticks between promotion batches
    threshold_update_period: int = 8   # ticks between Algorithm-1 runs
    clear_interval: int = 64           # ticks between sketch resets
    quota_pages: int | None = None     # promotion budget per interval
    # Asynchronous data plane (DESIGN.md §15): epochs are ISSUED as
    # non-donated async copies and COMMITTED by pointer swap at a later
    # tick, once the copy's readiness token is witnessed — decode keeps
    # reading the previous epoch's committed views in between.
    async_plane: bool = False


class TieredMemoryState(NamedTuple):
    """Everything the tiering layer knows about one resource, as one pytree."""

    prof: NeoProfState   # NeoProf: sketch + hot buffer + state monitor (+ θ)
    tier: TierState      # TieredStore: placement maps + 2Q bits + counters
    p: jax.Array         # () f32 — Algorithm-1 hot-fraction scalar
    tick: jax.Array      # () i32 — daemon tick counter


@dataclasses.dataclass
class MigrationEvent:
    """One promotion batch: copy slow[promoted[i]] into fast victims[i],
    after writing the slot's previous occupant ``evicted[i]`` back down."""

    promoted: jax.Array   # (k,) int32 page ids, -1 = no-op lane
    victims: jax.Array    # (k,) int32 slot ids, -1 = no-op lane
    n_promoted: int
    evicted: jax.Array | None = None   # (k,) int32 demoted page ids, -1 no-op


@dataclasses.dataclass
class InFlightEpoch:
    """One issued-but-uncommitted migration epoch (DESIGN.md §15).

    ``fast`` is the NEXT epoch's fast buffer, produced by a non-donated
    async gather (:func:`migrate.issue_migrate`); ``page_slot`` the
    placement table it was built against (the control state already
    points at it — decode keeps reading the previous committed table
    until the pointer swap).  ``token`` is the cheap device→host
    readiness witness: a () int32 from the same XLA executable as the
    copy, so ``token.is_ready()`` implies the buffer is materialized.
    """

    fast: jax.Array
    page_slot: jax.Array
    token: jax.Array
    bytes: int


@functools.partial(jax.jit, static_argnames=("prof_params",))
def observe(
    state: TieredMemoryState,
    pages: jax.Array,
    prof_params: NeoProfParams,
    touch_pages: jax.Array | None = None,
    rd_bytes=0.0, wr_bytes=0.0, budget_bytes=0.0,
) -> TieredMemoryState:
    """Pure device-side step: NeoProf snoop + tier hit/2Q accounting.

    ``touch_pages`` lets callers profile one stream but account hits on a
    (typically capped) other — defaults to ``pages``.
    """
    prof = neoprof_observe(state.prof, pages, prof_params,
                           rd_bytes=rd_bytes, wr_bytes=wr_bytes,
                           budget_bytes=budget_bytes)
    tier = tiering.touch(state.tier,
                         pages if touch_pages is None else touch_pages)
    return state._replace(prof=prof, tier=tier)


def lookup(state: TieredMemoryState,
           page_ids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pure: (fast-slot or -1, hit mask) for a batch of page ids."""
    return tiering.lookup(state.tier, page_ids)


class TieredMemory:
    """Facade owning the params + host-side daemon verbs for one resource.

    Construct from explicit params or via ``ResourceSpec.memory()`` /
    ``TieredMemory.from_spec`` — either way ONE object sources the prof,
    tier, and quota geometry (no way to hand the daemon a different
    TierParams than the tier was initialized with).
    """

    def __init__(
        self,
        prof_params: NeoProfParams,
        tier_params: TierParams,
        daemon_params: DaemonParams | None = None,
        policy_params: PolicyParams | None = None,
        fixed_theta: int | None = None,
    ):
        self.pp = prof_params
        self.tp = tier_params
        self.dp = daemon_params or DaemonParams()
        self.quota = (self.dp.quota_pages if self.dp.quota_pages is not None
                      else tier_params.quota_pages)
        # policy quota bound: 4x migration capacity per update period
        # (equal-to-capacity degenerates into p starve/flood oscillation)
        self.pol_params = policy_params or PolicyParams(
            m_quota_pages=4 * self.quota * max(
                1, self.dp.threshold_update_period // self.dp.migration_interval))
        self.fixed_theta = fixed_theta
        self.cmd = NeoProfCommands(prof_params)
        self._pending = np.empty((0,), np.int64)
        # migration data plane (DESIGN.md §8) — absent until bind_data
        self.spec = None
        self.buffers: migrate_lib.TierBuffers | None = None
        self.codec = "none"          # slow-store wire format (DESIGN.md §14)
        self.row_bytes = 0           # WIRE bytes per page once data is bound
        self.quota_bytes = 0
        # per-page write witness (None until bind_data): see pages_written
        self.written: np.ndarray | None = None
        # async data plane (DESIGN.md §15): the issued-but-uncommitted
        # epoch, and the placement table decode reads until it commits
        self._inflight: InFlightEpoch | None = None
        self._committed_slot: jax.Array | None = None

    @classmethod
    def from_spec(cls, spec, daemon_params=None, policy_params=None,
                  fixed_theta=None) -> "TieredMemory":
        mem = cls(spec.prof_params(), spec.tier_params(),
                  daemon_params=daemon_params, policy_params=policy_params,
                  fixed_theta=fixed_theta)
        mem.spec = spec
        mem.codec = codec_lib.check_codec(getattr(spec, "slow_codec", "none"))
        return mem

    # -- data plane (DESIGN.md §8) -------------------------------------------
    def bind_data(self, slow_data, initially_valid: bool = True,
                  codec: str | None = None) -> None:
        """Attach payload buffers: ``slow_data`` is (num_pages, *row_shape),
        always in the resource's NATIVE dtype — the slow store is encoded to
        ``codec``'s wire format here (default: the spec's ``slow_codec``;
        DESIGN.md §14), and ``row_bytes`` / ``quota_bytes`` meter WIRE bytes
        from then on.

        After binding, every promotion epoch physically moves rows between
        the fast/slow buffers (:meth:`apply_migration`) and meters the bytes;
        without it the resource stays placement/telemetry-only.

        ``initially_valid=False`` marks every page as not-yet-written: the
        store starts as zero-filled scratch (the KV slow store) and a page
        only becomes *resident* once a write verb lands on it.  The
        :meth:`pages_written` witness backs the disaggregated hand-off's
        segment-residency gate (DESIGN.md §13) — a decode worker must never
        admit a request whose segment the prefill worker has not finished
        flushing.
        """
        slow_data = jnp.asarray(slow_data)
        if slow_data.shape[0] != self.tp.num_pages:
            raise ValueError(
                f"slow_data has {slow_data.shape[0]} pages, tier declares "
                f"{self.tp.num_pages}")
        if self.spec is not None and self.spec.row_shape is not None:
            want = (tuple(self.spec.row_shape), jnp.dtype(self.spec.row_dtype))
            got = (tuple(slow_data.shape[1:]), slow_data.dtype)
            if want != got:
                raise ValueError(
                    f"slow_data rows {got} != ResourceSpec declaration {want}")
        if codec is not None:
            self.codec = codec_lib.check_codec(codec)
        self.buffers = migrate_lib.init_buffers(slow_data, self.tp.num_slots,
                                                codec=self.codec)
        self.row_bytes = migrate_lib.row_bytes(self.buffers)
        self.quota_bytes = 2 * self.quota * self.row_bytes
        self.written = np.full(self.tp.num_pages, bool(initially_valid))

    def apply_migration(self, event: MigrationEvent | None,
                        stats: TierStats) -> int:
        """Execute one epoch's data movement against the bound buffers.

        Returns the WIRE bytes moved (promotions + demotion write-backs, at
        the codec's at-rest row size), metered into ``stats`` against the
        per-epoch byte quota.  A no-op
        (no buffers bound, or an empty event) moves and meters nothing.
        """
        if self.buffers is None or event is None:
            return 0
        evicted = (event.evicted if event.evicted is not None
                   else jnp.full_like(jnp.asarray(event.victims), -1))
        t0 = time.perf_counter()
        self.buffers, n_up, n_down = migrate_lib.migrate(
            self.buffers, event.promoted, event.victims, evicted,
            codec=self.codec)
        # the synchronous arm stops the world: the donated fused copy must
        # land before the next decode step can read the swapped buffers —
        # that wait is exactly the stall the async plane (§15) removes
        jax.block_until_ready(self.buffers.fast)
        stats.stall_s += time.perf_counter() - t0
        moved = (n_up + n_down) * self.row_bytes
        stats.migration_bytes += moved
        stats.last_epoch_bytes = moved
        stats.max_epoch_bytes = max(stats.max_epoch_bytes, moved)
        stats.quota_bytes = self.quota_bytes
        if moved:
            stats.migration_epochs += 1
        return moved

    # -- async data plane (DESIGN.md §15) ------------------------------------
    @property
    def async_on(self) -> bool:
        """Whether this resource runs the double-buffered async plane."""
        return self.dp.async_plane and self.buffers is not None

    @property
    def busy(self) -> bool:
        """An epoch is issued but not yet committed — the daemon must not
        issue N+2 (and excludes this resource from the quota split)."""
        return self._inflight is not None

    def _view_slot(self, state: TieredMemoryState) -> jax.Array:
        """The placement table READS resolve against: the committed epoch's
        snapshot under the async plane, the live control table otherwise."""
        if self.async_on and self._committed_slot is not None:
            return self._committed_slot
        return state.tier.page_slot

    def lookup_slots(self, state: TieredMemoryState, page_ids) -> jax.Array:
        """Placement lookup against the COMMITTED view (== tiering.lookup's
        slots under the synchronous plane)."""
        ps = self._view_slot(state)
        ids = jnp.asarray(page_ids, jnp.int32)
        return jnp.where(ids >= 0, ps[jnp.maximum(ids, 0)], -1)

    def issue_migration(self, state: TieredMemoryState,
                        event: MigrationEvent | None,
                        stats: TierStats) -> int:
        """Issue phase: dispatch the epoch's promotion gather WITHOUT
        blocking and record the in-flight epoch.  ``state`` is the
        post-promote control state (its ``page_slot`` is the table the new
        buffer is built against).  Returns the epoch's wire bytes, metered
        as ``inflight_bytes`` until :meth:`commit_migration` folds them
        into the lifetime counters.

        The demotion write-back is ELIDED here: under the write-both-tiers
        rule every fast row already has a byte-identical slow copy, so the
        write-back would be a rewrite of identical bytes.  Its wire cost is
        still metered — the epoch moves the same bytes either way.
        """
        if self.buffers is None or event is None:
            return 0
        if self._inflight is not None:
            raise RuntimeError(
                "migration epoch already in flight — commit (or drop) epoch "
                "N+1 before issuing N+2")
        # host-side byte accounting off the tiny promote outputs (these are
        # products of tiering.promote's executable, NOT the bulk copy — the
        # np.asarray below never waits on payload movement)
        ok = (np.asarray(event.promoted) >= 0) & (np.asarray(event.victims) >= 0)
        if event.evicted is not None:
            n_down = int(np.sum(ok & (np.asarray(event.evicted) >= 0)))
        else:
            n_down = 0
        new_fast, token = migrate_lib.issue_migrate(
            self.buffers, event.promoted, event.victims)
        moved = (int(np.sum(ok)) + n_down) * self.row_bytes
        self._inflight = InFlightEpoch(fast=new_fast,
                                       page_slot=state.tier.page_slot,
                                       token=token, bytes=moved)
        stats.inflight_bytes = moved
        stats.quota_bytes = self.quota_bytes
        return moved

    def commit_ready(self) -> bool:
        """Non-blocking probe: has the in-flight epoch's copy landed?"""
        return (self._inflight is not None
                and migrate_lib.token_ready(self._inflight.token))

    def commit_migration(self, stats: TierStats, block: bool = False) -> int:
        """Commit phase: pointer-swap the in-flight epoch's buffer + table
        into the committed view and fold its bytes into the lifetime
        counters.  Without ``block`` this is a no-op unless the readiness
        token is already witnessed — the swap NEVER waits; ``block=True``
        forces the commit (checkpoint finalize, sync fallback) and meters
        the wait as ``stall_s``."""
        fl = self._inflight
        if fl is None:
            return 0
        if not migrate_lib.token_ready(fl.token):
            if not block:
                return 0
            t0 = time.perf_counter()
            jax.block_until_ready(fl.fast)
            stats.stall_s += time.perf_counter() - t0
        self.buffers = self.buffers._replace(fast=fl.fast)
        self._committed_slot = fl.page_slot
        self._inflight = None
        moved = fl.bytes
        stats.inflight_bytes = 0
        stats.migration_bytes += moved
        stats.last_epoch_bytes = moved
        stats.max_epoch_bytes = max(stats.max_epoch_bytes, moved)
        stats.quota_bytes = self.quota_bytes
        if moved:
            stats.migration_epochs += 1
        return moved

    def finalize_epoch(self, stats: TierStats) -> int:
        """Force-commit any in-flight epoch (checkpoint save: the persisted
        placement map is the control table, so the payload must match)."""
        return self.commit_migration(stats, block=True)

    def drop_inflight(self, stats: TierStats | None = None) -> None:
        """Abandon the in-flight epoch (checkpoint restore: the issued copy
        belongs to the pre-restore placement stream)."""
        self._inflight = None
        if stats is not None:
            stats.inflight_bytes = 0

    def reset_committed(self, state: TieredMemoryState) -> None:
        """Align the committed view with the control state (restore path):
        no epoch is in flight and decode reads the live table."""
        self._inflight = None
        self._committed_slot = (state.tier.page_slot if self.async_on
                                else None)

    def dispatch_migration(self, state: TieredMemoryState,
                           event: MigrationEvent | None,
                           stats: TierStats) -> int:
        """Route one epoch's data movement: async issue or sync apply."""
        if self.async_on:
            return self.issue_migration(state, event, stats)
        return self.apply_migration(event, stats)

    def _inflight_slots(self, page_ids) -> jax.Array:
        ps = self._inflight.page_slot
        ids = jnp.asarray(page_ids, jnp.int32)
        return jnp.where(ids >= 0, ps[jnp.maximum(ids, 0)], -1)

    def refill_fast(self, state: TieredMemoryState) -> None:
        """Re-gather the fast copy of every resident page from the slow store.

        Used after restoring a checkpointed placement map (DESIGN.md §6):
        the restored ``TierState`` says which pages are resident, but the
        rebuilt fast buffer is cold — without the refill, ``read_rows``
        would serve stale rows for pages the map calls hits.  A no-op when
        no payload is bound.
        """
        if self.buffers is None:
            return
        # a restored store is assumed fully materialized: the write witnesses
        # that produced it did not survive the checkpoint, the payload did
        if self.written is not None:
            self.written[:] = True
        slot_page = np.asarray(state.tier.slot_page)
        occupied = np.flatnonzero(slot_page >= 0)
        if occupied.size == 0:
            return
        pages = slot_page[occupied]
        scale = self.buffers.scale
        rows = codec_lib.decode_rows(
            self.buffers.slow[pages],
            None if scale is None else scale[pages],
            self.buffers.fast.dtype)
        fast = self.buffers.fast.at[occupied].set(rows)
        self.buffers = self.buffers._replace(fast=fast)

    def lookup_rows(self, state: TieredMemoryState, page_ids) -> jax.Array:
        """Pure, jittable read path: placement-table gather over the bound
        buffers with in-trace slow fallback (:func:`migrate.lookup_rows`).
        Safe to call INSIDE a jitted step — the placement map
        (``state.tier.page_slot``) and both buffers are device arrays, so
        the read costs one fused gather and no host round-trip.  For a
        jit-compatible argument pytree, see :meth:`tier_view`."""
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        return migrate_lib.lookup_rows(self.buffers.fast, self.buffers.slow,
                                       self._view_slot(state), page_ids,
                                       scale=self.buffers.scale)

    def tier_view(self, state: TieredMemoryState) -> dict[str, jax.Array]:
        """The device-array pytree an in-jit consumer threads into its step:
        ``{"fast", "slow", "page_slot", "scale"}`` (``scale`` is ``None``
        except under the ``int8`` codec — a valid pytree leaf either way) —
        pass these as jit ARGUMENTS (not closure constants) so daemon epochs
        swap buffers without retracing."""
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        return {"fast": self.buffers.fast, "slow": self.buffers.slow,
                "page_slot": self._view_slot(state),
                "scale": self.buffers.scale}

    def read_rows(self, state: TieredMemoryState, page_ids,
                  slots: jax.Array | None = None) -> jax.Array:
        """Serve page payloads: fast-tier copy on hit, slow-tier fallback.

        The gathers are partitioned host-side by the hit mask, so fast-tier
        hits never touch the slow store — on real hardware a 100% hit batch
        costs zero pinned-host bandwidth.  (:func:`migrate.read_rows` is the
        fused single-gather variant for in-jit consumers.)  ``slots`` lets a
        caller that already looked the ids up (e.g. the daemon handle's read
        metering) skip the second placement lookup.
        """
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        page_ids = jnp.asarray(page_ids, jnp.int32)
        if slots is None:
            slots = self.lookup_slots(state, page_ids)
        slots_np = np.asarray(slots)
        ids_np = np.maximum(np.asarray(page_ids), 0)
        hit = slots_np >= 0

        def _slow(ids):     # slow-store gather + wire-format decode
            scale = self.buffers.scale
            return codec_lib.decode_rows(
                self.buffers.slow[ids],
                None if scale is None else scale[ids],
                self.buffers.fast.dtype)

        if hit.all():
            return self.buffers.fast[slots]
        if not hit.any():
            return _slow(ids_np)
        rows = jnp.empty(page_ids.shape + self.buffers.fast.shape[1:],
                         self.buffers.fast.dtype)
        rows = rows.at[np.flatnonzero(hit)].set(
            self.buffers.fast[slots_np[hit]])
        return rows.at[np.flatnonzero(~hit)].set(_slow(ids_np[~hit]))

    def write_rows(self, state: TieredMemoryState, page_ids, rows) -> int:
        """Refresh page payloads in both tiers (owners with mutating data):
        the slow store always takes the write, fast copies of promoted pages
        are refreshed for coherence.  Returns the rows written."""
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        page_ids = jnp.asarray(page_ids, jnp.int32)
        slots = self.lookup_slots(state, page_ids)
        self.buffers = migrate_lib.write_rows(self.buffers, page_ids, slots,
                                              rows, codec=self.codec)
        if self._inflight is not None:
            # replay onto the in-flight epoch's buffer under ITS table, so a
            # page promoted by the issued-but-uncommitted copy does not keep
            # a stale fast row past the commit (DESIGN.md §15)
            self._inflight.fast = migrate_lib.refresh_rows(
                self._inflight.fast, self._inflight_slots(page_ids), rows)
        return self._mark_written(page_ids)

    def write_pages(self, state: TieredMemoryState, page_ids, k_pages,
                    v_pages) -> int:
        """Bulk KV ring-page flush (:func:`migrate.write_pages`): the [K|V]
        concat, slot-major transpose and dual-tier scatter fuse in one
        donated jit — the chunked-prefill data-plane verb.  ``k_pages`` /
        ``v_pages`` are (G, L, S, T, hkv, d) ring views; ``page_ids`` the
        (L*S,) slot map (-1 = dropped).  Returns the pages written."""
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        page_ids = jnp.asarray(page_ids, jnp.int32)
        slots = self.lookup_slots(state, page_ids)
        self.buffers = migrate_lib.write_pages(self.buffers, page_ids, slots,
                                               k_pages, v_pages,
                                               codec=self.codec)
        if self._inflight is not None:
            self._inflight.fast = migrate_lib.refresh_pages(
                self._inflight.fast, self._inflight_slots(page_ids),
                k_pages, v_pages)
        return self._mark_written(page_ids)

    def copy_rows(self, state: TieredMemoryState, src_ids, dst_ids) -> int:
        """Duplicate page payloads store-to-store (`migrate.copy_rows`):
        the content-addressed publish path copies a finished request's
        segment pages into shared pool pages in one fused donated op.
        Returns the pages copied."""
        if self.buffers is None:
            raise ValueError("no payload bound — call bind_data() first")
        src_ids = jnp.asarray(src_ids, jnp.int32)
        dst_ids = jnp.asarray(dst_ids, jnp.int32)
        dst_slots = self.lookup_slots(state, dst_ids)
        self.buffers = migrate_lib.copy_rows(self.buffers, src_ids, dst_ids,
                                             dst_slots)
        if self._inflight is not None:
            self._inflight.fast = migrate_lib.refresh_copy(
                self._inflight.fast, self.buffers.slow, self.buffers.scale,
                src_ids, self._inflight_slots(dst_ids))
        valid = (np.asarray(src_ids) >= 0) & (np.asarray(dst_ids) >= 0)
        if self.written is not None:
            self.written[np.asarray(dst_ids)[valid]] = True
        return int(np.sum(valid))

    def _mark_written(self, page_ids) -> int:
        """Record the write witnesses for a batch of page ids (-1 dropped)."""
        ids = np.asarray(page_ids)
        ids = ids[ids >= 0]
        if self.written is not None and ids.size:
            self.written[ids] = True
        return int(ids.size)

    def pages_written(self, page_ids) -> np.ndarray:
        """Per-page write witness: True where a write verb has landed since
        binding (or where the payload was valid at bind time).  The
        segment-residency query behind disaggregated decode admission
        (DESIGN.md §13); invalid ids (< 0) report False."""
        if self.written is None:
            raise ValueError("no payload bound — call bind_data() first")
        ids = np.asarray(page_ids, np.int64)
        out = np.zeros(ids.shape, bool)
        valid = (ids >= 0) & (ids < self.written.shape[0])
        out[valid] = self.written[ids[valid]]
        return out

    # -- state ---------------------------------------------------------------
    def init(self, key: jax.Array | None = None) -> TieredMemoryState:
        prof = neoprof_init(self.pp, key)
        theta0 = (self.fixed_theta if self.fixed_theta is not None
                  else self.pol_params.theta_min)
        return TieredMemoryState(
            prof=self.cmd.set_threshold(prof, theta0),
            tier=tiering.tier_init(self.tp),
            p=jnp.float32(self.pol_params.p_init),
            tick=jnp.zeros((), jnp.int32),
        )

    def observe(self, state: TieredMemoryState, pages, *, touch_pages=None,
                rd_bytes=0.0, wr_bytes=0.0, budget_bytes=0.0) -> TieredMemoryState:
        return observe(state, pages, self.pp, touch_pages=touch_pages,
                       rd_bytes=rd_bytes, wr_bytes=wr_bytes,
                       budget_bytes=budget_bytes)

    def profile(self, state: TieredMemoryState, pages, *, rd_bytes=0.0,
                wr_bytes=0.0, budget_bytes=0.0) -> TieredMemoryState:
        """NeoProf snoop only (callers that account tier hits separately)."""
        return state._replace(prof=neoprof_observe(
            state.prof, pages, self.pp, rd_bytes=rd_bytes, wr_bytes=wr_bytes,
            budget_bytes=budget_bytes))

    def touch(self, state: TieredMemoryState, pages) -> TieredMemoryState:
        """Tier hit/2Q accounting only."""
        return state._replace(tier=tiering.touch(state.tier, pages))

    def policy_state(self, state: TieredMemoryState,
                     stats: TierStats | None = None) -> PolicyState:
        """Reconstruct the Algorithm-1 view from the pytree (+ telemetry)."""
        last = lambda tr, d: tr[-1] if stats is not None and tr else d
        return PolicyState(
            p=float(state.p), theta=int(state.prof.theta),
            last_B=last(stats.bw_trace if stats else [], 0.0),
            last_P=last(stats.pp_trace if stats else [], 0.0),
            last_E=int(last(stats.err_trace if stats else [], 0)),
        )

    def hit_rate(self, state: TieredMemoryState, stats: TierStats) -> float:
        return _hit_rate(state.tier, stats)

    # -- daemon verbs (host side) ---------------------------------------------
    def collect(self, state: TieredMemoryState,
                stats: TierStats) -> tuple[TieredMemoryState, int]:
        """Drain NeoProf's hot buffer into the pending FIFO; return demand."""
        prof, hot = self.cmd.drain_hotpages(state.prof)
        self.enqueue(hot)
        stats.pending = len(self._pending)
        return state._replace(prof=prof), len(self._pending)

    def clear_pending(self) -> None:
        """Drop the host-side overflow queue (e.g. on checkpoint restore:
        the backlog belongs to the pre-restore stream, DESIGN.md §6)."""
        self._pending = np.empty((0,), np.int64)

    def enqueue(self, pages) -> None:
        """Queue externally-detected hot pages (baseline profilers, tests)."""
        self._pending = np.concatenate(
            [self._pending, np.asarray(pages, np.int64)])[: 4 * MAX_PENDING]

    def migrate(self, state: TieredMemoryState, stats: TierStats,
                quota: int | None = None,
                ) -> tuple[TieredMemoryState, MigrationEvent | None]:
        """Promote up to ``quota`` pending pages (batch width stays static)."""
        k = self.quota                       # static promote width (no retrace)
        if self.async_on:
            # first promote under the async plane: snapshot the pre-promote
            # table as epoch 0's committed view — from here on the control
            # table runs ahead of what decode reads until each commit
            if self._committed_slot is None:
                self._committed_slot = state.tier.page_slot
        else:
            stats.last_epoch_bytes = 0  # an epoch that moves nothing reports 0
        take = min(quota if quota is not None else k, k, len(self._pending))
        if take <= 0:
            stats.pending = len(self._pending)
            return state, None
        batch = np.full((k,), -1, np.int32)
        batch[:take] = self._pending[:take]
        self._pending = self._pending[take:][:MAX_PENDING]
        old_slot_page = state.tier.slot_page
        tier, promoted, victims = tiering.promote(
            state.tier, jnp.asarray(batch), k)
        # the page each victim slot held BEFORE this batch — the demotion
        # write-back targets for the data plane (apply_migration)
        evicted = jnp.where(victims >= 0,
                            old_slot_page[jnp.maximum(victims, 0)], -1)
        n = int(np.sum(np.asarray(promoted) >= 0))
        stats.migrated_this_period += n
        stats.pending = len(self._pending)
        return state._replace(tier=tier), MigrationEvent(promoted, victims, n,
                                                         evicted=evicted)

    def drain(self, state: TieredMemoryState,
              stats: TierStats) -> TieredMemoryState:
        """Drain tier period counters into stats (the one shared code path)."""
        return state._replace(tier=drain_tier_stats(state.tier, stats))

    def update_threshold(self, state: TieredMemoryState,
                         stats: TierStats) -> TieredMemoryState:
        """One Algorithm-1 period: read NeoProf, drain stats, retune θ."""
        hist = self.cmd.get_hist(state.prof)
        bw = self.cmd.bandwidth_util(state.prof)
        err = self.cmd.get_error_bound(state.prof, hist)
        state = self.drain(state, stats)
        period = stats.last_period
        # Laplace-damped: a single bounce at low volume must not crash p
        pp_ratio = float(period["ping_pong"]) / max(
            int(period["promoted"]), self.quota // 2, 1)
        if self.fixed_theta is None:
            # M = migration DEMAND (migrated + still-queued): Alg.1's quota
            # constraint throttles when demand exceeds capacity, not merely
            # when the migrator runs at capacity.
            demand = stats.migrated_this_period + len(self._pending)
            pol = _algorithm1(
                PolicyState(p=float(state.p), theta=int(state.prof.theta)),
                self.pol_params, hist, bandwidth_util=bw,
                ping_pong_ratio=pp_ratio, migrated_pages=demand,
                error_bound=err)
            state = state._replace(
                prof=self.cmd.set_threshold(state.prof, pol.theta),
                p=jnp.float32(pol.p))
        stats.migrated_this_period = 0
        stats.theta_trace.append(int(state.prof.theta))
        stats.bw_trace.append(float(bw))
        stats.pp_trace.append(pp_ratio)
        stats.err_trace.append(int(err))
        stats.p_trace.append(float(state.p))
        return state

    def clear(self, state: TieredMemoryState) -> TieredMemoryState:
        return state._replace(prof=self.cmd.reset(state.prof))

    def tick(self, state: TieredMemoryState, stats: TierStats,
             ) -> tuple[TieredMemoryState, MigrationEvent | None]:
        """Single-resource cadence driver (the multiplexed daemon drives the
        verbs itself so it can split the quota budget across resources)."""
        state = state._replace(tick=state.tick + 1)
        t, dp, event = int(state.tick), self.dp, None
        if t % dp.migration_interval == 0:
            if self.async_on:
                self.commit_migration(stats)   # commit FIRST, never blocks
            state, _ = self.collect(state, stats)
            if not self.busy:                  # no N+2 issue before N+1 commit
                state, event = self.migrate(state, stats)
                self.dispatch_migration(state, event, stats)
        if t % dp.threshold_update_period == 0:
            state = self.update_threshold(state, stats)
        if t % dp.clear_interval == 0:
            state = self.clear(state)
        return state, event
