"""The migration data plane: real byte movement behind ``apply_migration``.

The placement layer (:mod:`repro.core.tiering`) decides WHICH pages move;
this module moves them.  A resource that binds payload data gets a
:class:`TierBuffers` pair (DESIGN.md §8):

  * ``fast``: ``(num_slots, *row_shape)`` — promoted copies, device memory;
  * ``slow``: ``(num_pages, *row_shape)`` — the full backing store, placed
    in the ``pinned_host`` slow tier when the backend supports memory kinds
    (:mod:`repro.dist.host_offload`), or kept as a logically-separate device
    array on the CPU fallback so the data path runs unchanged in CI.

Each daemon epoch applies ONE fused copy (:func:`migrate`): victims are
written back to their old slow-tier pages (demotion), then the promoted
pages are gathered into the freed fast slots.  Both buffers are donated on
accelerators, so the epoch costs exactly the moved bytes — which the caller
meters against the per-epoch byte quota in
:class:`~repro.tiering.stats.TierStats`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import host_offload as ho


class TierBuffers(NamedTuple):
    """Payload buffers for one resource: fast copies over a slow store."""

    fast: jax.Array   # (num_slots, *row_shape)
    slow: jax.Array   # (num_pages, *row_shape) — full backing store


def row_bytes(buffers: TierBuffers) -> int:
    """Payload bytes of one page row (the migration byte unit)."""
    return int(np.prod(buffers.slow.shape[1:], dtype=np.int64)
               * buffers.slow.dtype.itemsize)


def place_slow(x: jax.Array) -> jax.Array:
    """Place the backing store in the slow tier (pinned host when available).

    On TPU/GPU this carries a ``pinned_host`` memory-kind sharding and XLA
    emits real H2D/D2H copies for every gather/scatter against it; on CPU
    the tiers degrade to logical separation (DESIGN.md §7) and the data
    path is exercised bit-for-bit without the placement.
    """
    x = jnp.asarray(x)
    if not ho.supports_memory_kinds():
        return x
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("_tier",))
    return ho.to_slow_tier(x, mesh, P())


def init_buffers(slow_data: jax.Array, num_slots: int) -> TierBuffers:
    """Build the buffer pair around an existing slow-tier payload array."""
    slow = place_slow(slow_data)
    fast = jnp.zeros((num_slots,) + slow.shape[1:], slow.dtype)
    return TierBuffers(fast=fast, slow=slow)


def segment_page_ids(segment: int, n_tokens: int, page_t: int,
                     pages_per_seq: int,
                     table: np.ndarray | None = None) -> np.ndarray:
    """Global page ids of a request's first ``n_tokens`` worth of KV pages.

    A lane-mode KV segment is ``pages_per_seq`` consecutive pages starting
    at ``segment * pages_per_seq``; a request that has consumed ``n_tokens``
    occupies the first ``ceil(n_tokens / page_t)`` of them (the final,
    possibly partial, page included — a hand-off force-flush writes it too).
    ``table`` is the lane's copy-on-write page-table row (local idx -> pool
    gid, -1 = private): shared pool pages resolve through it, exactly as the
    read path does (DESIGN.md §12/§13).  This is the id set the
    segment-residency gate checks against ``TieredMemory.pages_written``.
    """
    n_pages = -(-max(n_tokens, 0) // page_t)
    local = np.arange(min(n_pages, pages_per_seq), dtype=np.int64)
    gids = segment * pages_per_seq + local
    if table is not None:
        tabled = np.asarray(table, np.int64)[local]
        gids = np.where(tabled >= 0, tabled, gids)
    return gids


def _migrate_impl(fast, slow, promoted, victims, evicted):
    ok = (promoted >= 0) & (victims >= 0)
    ev_ok = ok & (evicted >= 0)
    n_pages, n_slots = slow.shape[0], fast.shape[0]
    # gather promoted rows BEFORE the write-back scatter (a page promoted in
    # this batch is never also evicted in it, but order still documents it)
    gathered = slow[jnp.where(ok, promoted, 0)]
    # no-op lanes scatter out of bounds and are dropped — routing them to
    # index 0 would race with a legitimate write to page/slot 0
    ev_idx = jnp.where(ev_ok, evicted, n_pages)
    sl_idx = jnp.where(ok, victims, n_slots)
    # demotion write-back: the victim slot's current row returns to its page
    slow = slow.at[ev_idx].set(fast[jnp.where(ev_ok, victims, 0)], mode="drop")
    # promotion: hot rows land in the freed slots
    fast = fast.at[sl_idx].set(gathered, mode="drop")
    return (fast, slow, jnp.sum(ok, dtype=jnp.int32),
            jnp.sum(ev_ok, dtype=jnp.int32))


@functools.lru_cache(maxsize=None)
def _migrate_jit():
    # donation frees the pre-copy buffers on accelerators; the CPU backend
    # ignores donation with a warning, so only request it where it works
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(_migrate_impl, donate_argnums=donate)


def migrate(buffers: TierBuffers, promoted: jax.Array, victims: jax.Array,
            evicted: jax.Array) -> tuple[TierBuffers, int, int]:
    """Apply one promotion batch as ONE fused copy (the epoch's data plane).

    ``promoted[i]`` is copied into fast slot ``victims[i]`` after the slot's
    previous occupant ``evicted[i]`` is written back to the slow store
    (-1 = no-op lane everywhere).  Returns the new buffers plus the promoted
    / demoted row counts actually moved (multiply by :func:`row_bytes` for
    the metered traffic).
    """
    fast, slow, n_up, n_down = _migrate_jit()(
        buffers.fast, buffers.slow, jnp.asarray(promoted, jnp.int32),
        jnp.asarray(victims, jnp.int32), jnp.asarray(evicted, jnp.int32))
    return TierBuffers(fast=fast, slow=slow), int(n_up), int(n_down)


@jax.jit
def read_rows(fast: jax.Array, slow: jax.Array, slots: jax.Array,
              page_ids: jax.Array) -> jax.Array:
    """Serve a batch of page reads: fast copy when resident, slow fallback.

    ``slots`` is the placement lookup result (-1 = not resident).  Rows for
    invalid page ids (< 0) read slow page 0 — callers mask them.
    """
    hit = slots >= 0
    safe_page = jnp.where(page_ids >= 0, page_ids, 0)
    mask = hit.reshape(hit.shape + (1,) * (fast.ndim - 1))
    return jnp.where(mask, fast[jnp.where(hit, slots, 0)], slow[safe_page])


def lookup_rows(fast: jax.Array, slow: jax.Array, page_slot: jax.Array,
                page_ids: jax.Array) -> jax.Array:
    """The in-jit tiered read fast path (DESIGN.md §10): placement lookup +
    fused dual-tier gather, entirely inside the caller's jit.

    ``page_slot`` is the device-resident placement table
    (``TierState.page_slot``); ``page_ids`` may have ANY leading shape —
    the result has ``page_ids.shape + row_shape``.  Fast-buffer rows are
    gathered for resident pages, with the slow store as the in-trace
    fallback (bit-exact either way; tiers are inclusive).  This is what the
    jitted decode step binds embedding/expert reads to — no host verb, no
    per-step round-trip; ``TieredMemory.read_rows`` remains the host-side
    verb whose hit-partitioned gather spares pinned-host bandwidth.
    Rows for invalid page ids (< 0) read slow page 0 — callers mask them.
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)
    slots = jnp.where(page_ids >= 0,
                      page_slot[jnp.maximum(page_ids, 0)], -1)
    return read_rows(fast, slow, slots, page_ids)


def _write_rows_impl(fast, slow, page_ids, slots, rows):
    rows = rows.astype(slow.dtype)
    slow_idx = jnp.where(page_ids >= 0, page_ids, slow.shape[0])
    slow = slow.at[slow_idx].set(rows, mode="drop")
    # keep promoted copies coherent: a page resident in the fast tier gets
    # its fast row refreshed too, so later reads/write-backs never serve or
    # demote a stale snapshot
    fast_idx = jnp.where((page_ids >= 0) & (slots >= 0), slots,
                         fast.shape[0])
    fast = fast.at[fast_idx].set(rows, mode="drop")
    return fast, slow


@functools.lru_cache(maxsize=None)
def _write_rows_jit():
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(_write_rows_impl, donate_argnums=donate)


def write_rows(buffers: TierBuffers, page_ids: jax.Array, slots: jax.Array,
               rows: jax.Array) -> TierBuffers:
    """Refresh page payloads in BOTH tiers (owners with mutating payloads,
    e.g. the serve engine flushing freshly-filled KV pages).

    The slow store always takes the write; pages currently promoted
    (``slots[i] >= 0``) also get their fast copy refreshed so the tiers
    stay coherent.  -1 page ids are dropped lanes.
    """
    fast, slow = _write_rows_jit()(
        buffers.fast, buffers.slow, jnp.asarray(page_ids, jnp.int32),
        jnp.asarray(slots, jnp.int32), rows)
    return TierBuffers(fast=fast, slow=slow)


def _write_pages_impl(fast, slow, page_ids, slots, k_pages, v_pages):
    # ring layout (G, L, S, T, hkv, d) -> page-row layout (L*S, G, T, hkv, d)
    rows = jnp.concatenate([k_pages, v_pages], axis=-1)
    rows = jnp.moveaxis(rows, 0, 2)
    rows = rows.reshape((-1,) + rows.shape[2:])
    return _write_rows_impl(fast, slow, page_ids, slots, rows)


@functools.lru_cache(maxsize=None)
def _write_pages_jit():
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(_write_pages_impl, donate_argnums=donate)


def write_pages(buffers: TierBuffers, page_ids: jax.Array, slots: jax.Array,
                k_pages: jax.Array, v_pages: jax.Array) -> TierBuffers:
    """Bulk KV-page write: flush paged-ring slots into the tier store as ONE
    donated fused op (the chunked-prefill / lane-flush data-plane verb).

    ``k_pages`` / ``v_pages`` are ring views shaped (G, L, S, T, hkv, dk|dv)
    — layer groups x lanes x ring slots; ``page_ids`` is the (L*S,) slot ->
    logical-page map (-1 = unchanged/dropped slot) and ``slots`` its
    placement lookup.  The [K | V] concat, slot-major transpose and
    dual-tier scatter all fuse inside one jit, so a chunk flush costs one
    dispatch instead of the host-side reshape pipeline + scatter.
    """
    fast, slow = _write_pages_jit()(
        buffers.fast, buffers.slow, jnp.asarray(page_ids, jnp.int32),
        jnp.asarray(slots, jnp.int32), k_pages, v_pages)
    return TierBuffers(fast=fast, slow=slow)


def _copy_rows_impl(fast, slow, src_ids, dst_ids, dst_slots):
    # the slow store is coherent by construction (every write verb and the
    # demotion write-back lands there), so the gather reads slow only
    rows = slow[jnp.maximum(src_ids, 0)]
    valid = (src_ids >= 0) & (dst_ids >= 0)
    slow_idx = jnp.where(valid, dst_ids, slow.shape[0])
    slow = slow.at[slow_idx].set(rows, mode="drop")
    fast_idx = jnp.where(valid & (dst_slots >= 0), dst_slots, fast.shape[0])
    fast = fast.at[fast_idx].set(rows, mode="drop")
    return fast, slow


@functools.lru_cache(maxsize=None)
def _copy_rows_jit():
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(_copy_rows_impl, donate_argnums=donate)


def copy_rows(buffers: TierBuffers, src_ids: jax.Array, dst_ids: jax.Array,
              dst_slots: jax.Array) -> TierBuffers:
    """Duplicate page payloads store-to-store as ONE donated fused op —
    the content-addressed publish verb (DESIGN.md §12): a finished
    request's private segment pages are copied into shared pool pages
    without a host round-trip.  Destinations currently promoted
    (``dst_slots[i] >= 0``) get their fast copy refreshed for coherence;
    -1 in either id array drops that pair.
    """
    fast, slow = _copy_rows_jit()(
        buffers.fast, buffers.slow, jnp.asarray(src_ids, jnp.int32),
        jnp.asarray(dst_ids, jnp.int32), jnp.asarray(dst_slots, jnp.int32))
    return TierBuffers(fast=fast, slow=slow)
