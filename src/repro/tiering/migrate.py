"""The migration data plane: real byte movement behind ``apply_migration``.

The placement layer (:mod:`repro.core.tiering`) decides WHICH pages move;
this module moves them.  A resource that binds payload data gets a
:class:`TierBuffers` set (DESIGN.md §8):

  * ``fast``: ``(num_slots, *row_shape)`` — promoted copies, device memory,
    always in the resource's NATIVE row dtype;
  * ``slow``: ``(num_pages, *row_shape)`` — the full backing store in the
    resource's wire format (:mod:`repro.tiering.codec`, DESIGN.md §14):
    native dtype under the ``none`` codec, fp32 under ``fp32``, int8 under
    ``int8``.  Placed in the ``pinned_host`` slow tier when the backend
    supports memory kinds (:mod:`repro.dist.host_offload`), or kept as a
    logically-separate device array on the CPU fallback so the data path
    runs unchanged in CI;
  * ``scale``: ``(num_pages,)`` fp32 per-row quantization scales — present
    only under the ``int8`` codec (``None`` otherwise).

Each daemon epoch applies ONE fused copy (:func:`migrate`): victims are
written back to their old slow-tier pages (demotion — re-ENCODED to the
wire format), then the promoted pages are gathered into the freed fast
slots (DECODED back to native dtype inside the same jit).  Both buffers
are donated on accelerators, so the epoch costs exactly the moved WIRE
bytes — which the caller meters against the per-epoch byte quota in
:class:`~repro.tiering.stats.TierStats`.

The read verbs (:func:`read_rows` / :func:`lookup_rows`) never take a
codec name: decode dispatches on the payload dtype and scale presence
(both trace-time static, see :func:`repro.tiering.codec.decode_rows`), so
the jitted decode step's tier view stays a plain array pytree.  The write
verbs encode, so they take ``codec`` as a static argument and key their
cached jit builders on it.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import host_offload as ho
from repro.tiering import codec as codec_lib


class TierBuffers(NamedTuple):
    """Payload buffers for one resource: fast copies over a slow store."""

    fast: jax.Array   # (num_slots, *row_shape) — native dtype
    slow: jax.Array   # (num_pages, *row_shape) — full store, wire format
    scale: jax.Array | None = None   # (num_pages,) fp32 — int8 codec only


def row_bytes(buffers: TierBuffers) -> int:
    """WIRE bytes of one page row (the migration byte unit): what the slow
    store actually holds per page — int8 payload plus its fp32 scale under
    the ``int8`` codec, the stored dtype otherwise."""
    n = int(np.prod(buffers.slow.shape[1:], dtype=np.int64)
            * buffers.slow.dtype.itemsize)
    if buffers.scale is not None:
        n += int(buffers.scale.dtype.itemsize)
    return n


def place_slow(x: jax.Array) -> jax.Array:
    """Place the backing store in the slow tier (pinned host when available).

    On TPU/GPU this carries a ``pinned_host`` memory-kind sharding and XLA
    emits real H2D/D2H copies for every gather/scatter against it; on CPU
    the tiers degrade to logical separation (DESIGN.md §7) and the data
    path is exercised bit-for-bit without the placement.
    """
    x = jnp.asarray(x)
    if not ho.supports_memory_kinds():
        return x
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("_tier",))
    return ho.to_slow_tier(x, mesh, P())


def init_buffers(slow_data: jax.Array, num_slots: int,
                 codec: str = "none") -> TierBuffers:
    """Build the buffer set around an existing payload array.

    ``slow_data`` arrives in the resource's native dtype; the store is
    encoded to the codec's wire format at bind time (the per-row scales
    ride in the slow tier next to the payload).  The fast buffer keeps the
    NATIVE dtype — promoted rows are decoded once, on promotion, so every
    fast-tier hit serves full-precision rows with zero decode cost.
    """
    slow_data = jnp.asarray(slow_data)
    payload, scale = codec_lib.encode_store(codec, slow_data)
    slow = place_slow(payload)
    if scale is not None:
        scale = place_slow(scale)
    fast = jnp.zeros((num_slots,) + slow.shape[1:], slow_data.dtype)
    return TierBuffers(fast=fast, slow=slow, scale=scale)


def segment_page_ids(segment: int, n_tokens: int, page_t: int,
                     pages_per_seq: int,
                     table: np.ndarray | None = None) -> np.ndarray:
    """Global page ids of a request's first ``n_tokens`` worth of KV pages.

    A lane-mode KV segment is ``pages_per_seq`` consecutive pages starting
    at ``segment * pages_per_seq``; a request that has consumed ``n_tokens``
    occupies the first ``ceil(n_tokens / page_t)`` of them (the final,
    possibly partial, page included — a hand-off force-flush writes it too).
    ``table`` is the lane's copy-on-write page-table row (local idx -> pool
    gid, -1 = private): shared pool pages resolve through it, exactly as the
    read path does (DESIGN.md §12/§13).  This is the id set the
    segment-residency gate checks against ``TieredMemory.pages_written``.
    """
    n_pages = -(-max(n_tokens, 0) // page_t)
    local = np.arange(min(n_pages, pages_per_seq), dtype=np.int64)
    gids = segment * pages_per_seq + local
    if table is not None:
        tabled = np.asarray(table, np.int64)[local]
        gids = np.where(tabled >= 0, tabled, gids)
    return gids


def _donate(n_buffers: int):
    # donation frees the pre-copy buffers on accelerators; the CPU backend
    # ignores donation with a warning, so only request it where it works
    return tuple(range(n_buffers)) if jax.default_backend() != "cpu" else ()


def _scale_at(scale, idx):
    """Per-row scales for a gathered id batch (None under scale-less codecs)."""
    return None if scale is None else scale[idx]


def _migrate_impl(codec, fast, slow, scale, promoted, victims, evicted):
    ok = (promoted >= 0) & (victims >= 0)
    ev_ok = ok & (evicted >= 0)
    n_pages, n_slots = slow.shape[0], fast.shape[0]
    # gather promoted rows BEFORE the write-back scatter (a page promoted in
    # this batch is never also evicted in it, but order still documents it);
    # promotion is the decode point — fast rows are native dtype
    up_idx = jnp.where(ok, promoted, 0)
    gathered = codec_lib.decode_rows(slow[up_idx], _scale_at(scale, up_idx),
                                     fast.dtype)
    # no-op lanes scatter out of bounds and are dropped — routing them to
    # index 0 would race with a legitimate write to page/slot 0
    ev_idx = jnp.where(ev_ok, evicted, n_pages)
    sl_idx = jnp.where(ok, victims, n_slots)
    # demotion write-back: the victim slot's current row returns to its page,
    # re-encoded to the wire format (the codec's quantize point)
    down, down_scale = codec_lib.encode_rows(
        codec, fast[jnp.where(ev_ok, victims, 0)])
    slow = slow.at[ev_idx].set(down.astype(slow.dtype), mode="drop")
    if scale is not None:
        scale = scale.at[ev_idx].set(down_scale, mode="drop")
    # promotion: hot rows land in the freed slots
    fast = fast.at[sl_idx].set(gathered, mode="drop")
    return (fast, slow, scale, jnp.sum(ok, dtype=jnp.int32),
            jnp.sum(ev_ok, dtype=jnp.int32))


@functools.lru_cache(maxsize=None)
def _migrate_jit(codec: str):
    return jax.jit(functools.partial(_migrate_impl, codec),
                   donate_argnums=_donate(3))


def migrate(buffers: TierBuffers, promoted: jax.Array, victims: jax.Array,
            evicted: jax.Array, codec: str = "none"
            ) -> tuple[TierBuffers, int, int]:
    """Apply one promotion batch as ONE fused copy (the epoch's data plane).

    ``promoted[i]`` is copied into fast slot ``victims[i]`` after the slot's
    previous occupant ``evicted[i]`` is written back to the slow store
    (-1 = no-op lane everywhere).  Decode-on-promote / encode-on-demote
    happen inside the same jit under the resource's codec.  Returns the new
    buffers plus the promoted / demoted row counts actually moved (multiply
    by :func:`row_bytes` for the metered wire traffic).
    """
    fast, slow, scale, n_up, n_down = _migrate_jit(codec)(
        buffers.fast, buffers.slow, buffers.scale,
        jnp.asarray(promoted, jnp.int32), jnp.asarray(victims, jnp.int32),
        jnp.asarray(evicted, jnp.int32))
    return TierBuffers(fast=fast, slow=slow, scale=scale), int(n_up), \
        int(n_down)


def read_rows(fast: jax.Array, slow: jax.Array, slots: jax.Array,
              page_ids: jax.Array, scale: jax.Array | None = None
              ) -> jax.Array:
    """Serve a batch of page reads: fast copy when resident, slow fallback.

    ``slots`` is the placement lookup result (-1 = not resident).  The slow
    fallback decodes in the same fused gather (per-row ``scale`` under the
    int8 codec — dtype-dispatched, see :func:`codec.decode_rows`), so the
    result is always native-dtype rows.  Pure jnp — runs inside the
    caller's jit (the decode step) or eagerly from host verbs.  Rows for
    invalid page ids (< 0) read slow page 0 — callers mask them.
    """
    hit = slots >= 0
    safe_page = jnp.where(page_ids >= 0, page_ids, 0)
    slow_rows = codec_lib.decode_rows(
        slow[safe_page], _scale_at(scale, safe_page), fast.dtype)
    mask = hit.reshape(hit.shape + (1,) * (fast.ndim - 1))
    return jnp.where(mask, fast[jnp.where(hit, slots, 0)], slow_rows)


def lookup_rows(fast: jax.Array, slow: jax.Array, page_slot: jax.Array,
                page_ids: jax.Array, scale: jax.Array | None = None
                ) -> jax.Array:
    """The in-jit tiered read fast path (DESIGN.md §10): placement lookup +
    fused dual-tier gather, entirely inside the caller's jit.

    ``page_slot`` is the device-resident placement table
    (``TierState.page_slot``); ``page_ids`` may have ANY leading shape —
    the result has ``page_ids.shape + row_shape``.  Fast-buffer rows are
    gathered for resident pages, with the slow store as the in-trace
    fallback — decoded from the wire format where the codec quantizes
    (DESIGN.md §14), bit-exact under the ``none`` codec (tiers are
    inclusive).  This is what the jitted decode step binds embedding/expert
    reads to — no host verb, no per-step round-trip;
    ``TieredMemory.read_rows`` remains the host-side verb whose
    hit-partitioned gather spares pinned-host bandwidth.
    Rows for invalid page ids (< 0) read slow page 0 — callers mask them.
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)
    slots = jnp.where(page_ids >= 0,
                      page_slot[jnp.maximum(page_ids, 0)], -1)
    return read_rows(fast, slow, slots, page_ids, scale=scale)


def _write_rows_impl(codec, fast, slow, scale, page_ids, slots, rows):
    payload, row_scale = codec_lib.encode_rows(codec, rows)
    slow_idx = jnp.where(page_ids >= 0, page_ids, slow.shape[0])
    slow = slow.at[slow_idx].set(payload.astype(slow.dtype), mode="drop")
    if scale is not None:
        scale = scale.at[slow_idx].set(row_scale, mode="drop")
    # keep promoted copies coherent: a page resident in the fast tier gets
    # its fast row refreshed too (native dtype — the fast tier never holds
    # wire format), so later reads/write-backs never serve a stale snapshot
    fast_idx = jnp.where((page_ids >= 0) & (slots >= 0), slots,
                         fast.shape[0])
    fast = fast.at[fast_idx].set(rows.astype(fast.dtype), mode="drop")
    return fast, slow, scale


@functools.lru_cache(maxsize=None)
def _write_rows_jit(codec: str):
    return jax.jit(functools.partial(_write_rows_impl, codec),
                   donate_argnums=_donate(3))


def write_rows(buffers: TierBuffers, page_ids: jax.Array, slots: jax.Array,
               rows: jax.Array, codec: str = "none") -> TierBuffers:
    """Refresh page payloads in BOTH tiers (owners with mutating payloads,
    e.g. the serve engine flushing freshly-filled KV pages).

    The slow store always takes the write — encoded to the wire format —
    and pages currently promoted (``slots[i] >= 0``) also get their fast
    copy refreshed so the tiers stay coherent.  -1 page ids are dropped
    lanes.
    """
    fast, slow, scale = _write_rows_jit(codec)(
        buffers.fast, buffers.slow, buffers.scale,
        jnp.asarray(page_ids, jnp.int32), jnp.asarray(slots, jnp.int32),
        rows)
    return TierBuffers(fast=fast, slow=slow, scale=scale)


def _pages_to_rows(k_pages, v_pages):
    # ring layout (G, L, S, T, hkv, d) -> page-row layout (L*S, G, T, hkv, d)
    rows = jnp.concatenate([k_pages, v_pages], axis=-1)
    rows = jnp.moveaxis(rows, 0, 2)
    return rows.reshape((-1,) + rows.shape[2:])


def _write_pages_impl(codec, fast, slow, scale, page_ids, slots,
                      k_pages, v_pages):
    rows = _pages_to_rows(k_pages, v_pages)
    return _write_rows_impl(codec, fast, slow, scale, page_ids, slots, rows)


@functools.lru_cache(maxsize=None)
def _write_pages_jit(codec: str):
    return jax.jit(functools.partial(_write_pages_impl, codec),
                   donate_argnums=_donate(3))


def write_pages(buffers: TierBuffers, page_ids: jax.Array, slots: jax.Array,
                k_pages: jax.Array, v_pages: jax.Array,
                codec: str = "none") -> TierBuffers:
    """Bulk KV-page write: flush paged-ring slots into the tier store as ONE
    donated fused op (the chunked-prefill / lane-flush data-plane verb).

    ``k_pages`` / ``v_pages`` are ring views shaped (G, L, S, T, hkv, dk|dv)
    — layer groups x lanes x ring slots; ``page_ids`` is the (L*S,) slot ->
    logical-page map (-1 = unchanged/dropped slot) and ``slots`` its
    placement lookup.  The [K | V] concat, slot-major transpose, codec
    encode and dual-tier scatter all fuse inside one jit, so a chunk flush
    costs one dispatch instead of the host-side reshape pipeline + scatter.
    """
    fast, slow, scale = _write_pages_jit(codec)(
        buffers.fast, buffers.slow, buffers.scale,
        jnp.asarray(page_ids, jnp.int32), jnp.asarray(slots, jnp.int32),
        k_pages, v_pages)
    return TierBuffers(fast=fast, slow=slow, scale=scale)


# -- async data plane (DESIGN.md §15) ---------------------------------------
#
# The asynchronous epoch is the promotion gather ONLY, dispatched without
# donation: the committed fast buffer stays alive (decode keeps reading the
# stale epoch bit-exactly) while XLA produces the NEXT epoch's fast buffer —
# the "double buffer".  The demotion write-back is elided: under the
# write-both-tiers rule every resident fast row equals decode(slow row), so
# the write-back would re-write identical wire bytes; its traffic is still
# metered by the caller (the bytes are real on a CXL port).  Writes landing
# while an epoch is in flight are replayed onto the in-flight buffer by the
# ``refresh_*`` verbs below, so commit never serves a pre-write snapshot.


@jax.jit
def _issue_migrate_jit(fast, slow, scale, promoted, victims):
    ok = (promoted >= 0) & (victims >= 0)
    up_idx = jnp.where(ok, promoted, 0)
    gathered = codec_lib.decode_rows(slow[up_idx], _scale_at(scale, up_idx),
                                     fast.dtype)
    sl_idx = jnp.where(ok, victims, fast.shape[0])
    new_fast = fast.at[sl_idx].set(gathered, mode="drop")
    return new_fast, jnp.sum(ok, dtype=jnp.int32)


def issue_migrate(buffers: TierBuffers, promoted: jax.Array,
                  victims: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dispatch one epoch's promotion copy asynchronously (no donation, no
    host block): returns ``(new_fast, token)`` where ``new_fast`` is the
    NEXT epoch's fast buffer and ``token`` a cheap () int32 readiness
    witness (the promoted-row count — an output of the same executable, so
    it completes exactly when the copy does).  The caller commits by
    pointer swap once :func:`token_ready` says so."""
    return _issue_migrate_jit(
        buffers.fast, buffers.slow, buffers.scale,
        jnp.asarray(promoted, jnp.int32), jnp.asarray(victims, jnp.int32))


def token_ready(token: jax.Array) -> bool:
    """Non-blocking readiness probe of an issued epoch's witness token."""
    try:
        return bool(token.is_ready())
    except AttributeError:      # no probe on this runtime: degrade to sync
        token.block_until_ready()
        return True


def _refresh_rows_impl(fast, slots, rows):
    idx = jnp.where(slots >= 0, slots, fast.shape[0])
    return fast.at[idx].set(rows.astype(fast.dtype), mode="drop")


@functools.lru_cache(maxsize=None)
def _refresh_rows_jit():
    return jax.jit(_refresh_rows_impl, donate_argnums=_donate(1))


def refresh_rows(fast: jax.Array, slots: jax.Array, rows: jax.Array
                 ) -> jax.Array:
    """Replay an owner write onto the IN-FLIGHT fast buffer (native dtype,
    no slow-store touch — the committed write verb already encoded there):
    keeps a write that lands mid-epoch coherent with the epoch about to
    commit.  ``slots`` is the lookup under the in-flight placement table."""
    return _refresh_rows_jit()(fast, jnp.asarray(slots, jnp.int32), rows)


def _refresh_pages_impl(fast, slots, k_pages, v_pages):
    return _refresh_rows_impl(fast, slots, _pages_to_rows(k_pages, v_pages))


@functools.lru_cache(maxsize=None)
def _refresh_pages_jit():
    return jax.jit(_refresh_pages_impl, donate_argnums=_donate(1))


def refresh_pages(fast: jax.Array, slots: jax.Array, k_pages: jax.Array,
                  v_pages: jax.Array) -> jax.Array:
    """Bulk-flush analogue of :func:`refresh_rows` for KV ring views."""
    return _refresh_pages_jit()(fast, jnp.asarray(slots, jnp.int32),
                                k_pages, v_pages)


def _refresh_copy_impl(fast, slow, scale, src_ids, dst_slots):
    src_safe = jnp.maximum(src_ids, 0)
    rows = codec_lib.decode_rows(slow[src_safe], _scale_at(scale, src_safe),
                                 fast.dtype)
    idx = jnp.where((src_ids >= 0) & (dst_slots >= 0), dst_slots,
                    fast.shape[0])
    return fast.at[idx].set(rows, mode="drop")


@functools.lru_cache(maxsize=None)
def _refresh_copy_jit():
    return jax.jit(_refresh_copy_impl, donate_argnums=_donate(1))


def refresh_copy(fast: jax.Array, slow: jax.Array, scale: jax.Array | None,
                 src_ids: jax.Array, dst_slots: jax.Array) -> jax.Array:
    """:func:`copy_rows` replay onto the in-flight fast buffer: re-decode
    the (already copied) destination rows from the slow store into the
    destinations' in-flight slots."""
    return _refresh_copy_jit()(fast, slow, scale,
                               jnp.asarray(src_ids, jnp.int32),
                               jnp.asarray(dst_slots, jnp.int32))


def _copy_rows_impl(fast, slow, scale, src_ids, dst_ids, dst_slots):
    # the slow store is coherent by construction (every write verb and the
    # demotion write-back lands there), so the gather reads slow only —
    # and copies the WIRE format verbatim (payload + scale): a quantized
    # page publishes without a decode/re-encode round trip
    src_safe = jnp.maximum(src_ids, 0)
    rows = slow[src_safe]
    src_scale = _scale_at(scale, src_safe)   # gather BEFORE the scatter below
    valid = (src_ids >= 0) & (dst_ids >= 0)
    slow_idx = jnp.where(valid, dst_ids, slow.shape[0])
    slow = slow.at[slow_idx].set(rows, mode="drop")
    if scale is not None:
        scale = scale.at[slow_idx].set(src_scale, mode="drop")
    fast_idx = jnp.where(valid & (dst_slots >= 0), dst_slots, fast.shape[0])
    fast = fast.at[fast_idx].set(
        codec_lib.decode_rows(rows, src_scale, fast.dtype), mode="drop")
    return fast, slow, scale


@functools.lru_cache(maxsize=None)
def _copy_rows_jit():
    return jax.jit(_copy_rows_impl, donate_argnums=_donate(3))


def copy_rows(buffers: TierBuffers, src_ids: jax.Array, dst_ids: jax.Array,
              dst_slots: jax.Array) -> TierBuffers:
    """Duplicate page payloads store-to-store as ONE donated fused op —
    the content-addressed publish verb (DESIGN.md §12): a finished
    request's private segment pages are copied into shared pool pages
    without a host round-trip.  Wire format travels verbatim (no codec
    transcode — the scales ride along), so the publish costs exactly the
    compressed bytes.  Destinations currently promoted
    (``dst_slots[i] >= 0``) get their fast copy refreshed for coherence;
    -1 in either id array drops that pair.
    """
    fast, slow, scale = _copy_rows_jit()(
        buffers.fast, buffers.slow, buffers.scale,
        jnp.asarray(src_ids, jnp.int32), jnp.asarray(dst_ids, jnp.int32),
        jnp.asarray(dst_slots, jnp.int32))
    return TierBuffers(fast=fast, slow=slow, scale=scale)
