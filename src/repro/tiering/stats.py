"""Unified per-resource tiering telemetry (DESIGN.md §1.4).

Every consumer of the tiering layer — the multiplexed daemon, the legacy
adapter shims, the paper-evaluation simulator, and the serving benchmarks —
drains the TieredStore's period counters through the single code path in
:func:`drain_tier_stats`, so hit-rate / promotion / ping-pong arithmetic is
written exactly once.  A :class:`TierStats` accumulates the drained totals
plus the Fig. 14-style policy traces (θ / bandwidth / ping-pong / p).
"""
from __future__ import annotations

import dataclasses

from repro.core import tiering
from repro.core.tiering import TierState


@dataclasses.dataclass
class TierStats:
    """Cumulative telemetry for one tiered resource.

    ``fast_reads``/``slow_reads``/... are lifetime totals of the *drained*
    period counters; counts since the last drain still live on the device in
    ``TierState`` (use :func:`hit_rate` to merge both views).
    """

    name: str = ""
    fast_reads: int = 0
    slow_reads: int = 0
    promoted: int = 0
    demoted: int = 0
    ping_pong: int = 0
    # Migration bookkeeping within the current Algorithm-1 period.
    migrated_this_period: int = 0
    pending: int = 0               # overflow queue depth (latest snapshot)
    # Data-plane byte metering (DESIGN.md §8; zero when no buffers bound).
    migration_bytes: int = 0       # lifetime payload bytes moved (both ways)
    last_epoch_bytes: int = 0      # bytes moved by the most recent epoch
    max_epoch_bytes: int = 0       # bytes moved by the LARGEST epoch so far —
    #                                the per-epoch quota must hold across
    #                                EVERY epoch, not just the last one
    quota_bytes: int = 0           # per-epoch byte budget (2 * quota * row)
    migration_epochs: int = 0      # epochs that actually moved payload
    flush_bytes: int = 0           # owner write_rows traffic (e.g. KV flush)
    # Async data plane (DESIGN.md §15; zero in the synchronous mode).
    inflight_bytes: int = 0        # bytes of the issued-but-uncommitted epoch
    # Achieved-overlap metering (DESIGN.md §15).
    stall_s: float = 0.0           # wall time decode spent BLOCKED on a
    #                                migration copy (sync: every epoch's
    #                                fused copy; async: forced commits only)
    decode_s: float = 0.0          # decode wall time (set by the owner —
    #                                the serve engine's step-loop clock)
    # Fig. 14-style traces, appended once per threshold-update period.
    theta_trace: list = dataclasses.field(default_factory=list)
    bw_trace: list = dataclasses.field(default_factory=list)
    pp_trace: list = dataclasses.field(default_factory=list)
    err_trace: list = dataclasses.field(default_factory=list)
    p_trace: list = dataclasses.field(default_factory=list)
    # Raw period counters from the most recent drain (policy inputs).
    last_period: dict = dataclasses.field(default_factory=dict)

    @property
    def total_reads(self) -> int:
        return self.fast_reads + self.slow_reads

    @property
    def drained_hit_rate(self) -> float:
        return self.fast_reads / max(self.total_reads, 1)

    @property
    def overlap_bytes_per_decode_s(self) -> float:
        """Achieved overlap: migration bytes moved per second of decode wall
        time (DESIGN.md §15).  Zero until the owner meters ``decode_s``."""
        if self.decode_s <= 0:
            return 0.0
        return self.migration_bytes / self.decode_s

    def as_row(self) -> dict:
        """Flat schema for benchmark emission (BENCH_serve.json rows —
        documented key-by-key in benchmarks/README.md)."""
        return {
            "name": self.name,
            "fast_reads": self.fast_reads,
            "slow_reads": self.slow_reads,
            "hit_rate": self.drained_hit_rate,
            "promoted": self.promoted,
            "demoted": self.demoted,
            "ping_pong": self.ping_pong,
            "migration_bytes": self.migration_bytes,
            "last_epoch_bytes": self.last_epoch_bytes,
            "max_epoch_bytes": self.max_epoch_bytes,
            "quota_bytes": self.quota_bytes,
            "migration_epochs": self.migration_epochs,
            "flush_bytes": self.flush_bytes,
            "inflight_bytes": self.inflight_bytes,
            "stall_s": self.stall_s,
            "overlap_bytes_per_decode_s": self.overlap_bytes_per_decode_s,
        }


def drain_tier_stats(tier: TierState, stats: TierStats) -> TierState:
    """Drain the TieredStore period counters into ``stats`` (THE code path).

    Returns the tier state with period counters cleared (and reference bits
    aged, per 2Q CLOCK second-chance — see tiering.drain_period_stats).
    """
    tier, period = tiering.drain_period_stats(tier)
    stats.fast_reads += int(period["fast_reads"])
    stats.slow_reads += int(period["slow_reads"])
    stats.promoted += int(period["promoted"])
    stats.demoted += int(period["demoted"])
    stats.ping_pong += int(period["ping_pong"])
    # stash the raw period view for the caller's policy step
    stats.last_period = {k: int(v) for k, v in period.items()}
    return tier


def hit_rate(tier: TierState, stats: TierStats) -> float:
    """Lifetime fast-tier hit rate = drained totals + not-yet-drained counts."""
    f = stats.fast_reads + int(tier.fast_reads)
    s = stats.slow_reads + int(tier.slow_reads)
    return f / max(f + s, 1)


class LegacyDaemonStateView:
    """The old ``DaemonState`` attribute surface, read from a TierStats.

    Shared by the deprecation shims (``core/daemon.py``,
    ``core/adapters/base.py``) so the legacy-compat field mapping exists
    exactly once.
    """

    def __init__(self, stats: TierStats, tick_fn=None):
        self._stats = stats
        self._tick_fn = tick_fn

    @property
    def tick(self) -> int:
        return self._tick_fn() if self._tick_fn is not None else 0

    total_fast = property(lambda self: self._stats.fast_reads)
    total_slow = property(lambda self: self._stats.slow_reads)
    total_promoted = property(lambda self: self._stats.promoted)
    total_ping_pong = property(lambda self: self._stats.ping_pong)
    migrated_this_period = property(
        lambda self: self._stats.migrated_this_period)
    theta_trace = property(lambda self: self._stats.theta_trace)
    bw_trace = property(lambda self: self._stats.bw_trace)
    pp_trace = property(lambda self: self._stats.pp_trace)
