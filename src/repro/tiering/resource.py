"""TieredResource — the one API every consumer of slow memory speaks.

NeoMem's core claim is that one device-side profiler plus one OS policy loop
serves *every* consumer of CXL memory.  The software analogue (DESIGN.md §1):
a resource adapts itself to the tiering layer by implementing two methods —

  * ``encode_stream(*observation) -> page-id stream`` — a PURE function
    mapping whatever the model already computes (router indices, attention
    page masses, token ids) onto the flat page-id address space NeoProf
    profiles.  Jittable; -1 entries are padding.
  * ``apply_migration(promoted_pages, victim_slots)`` — the host-side data
    movement hook for a promotion batch (expert weights, KV pages,
    embedding rows).  Resources that declare ``row_shape``/``row_dtype`` in
    their spec and bind payload data get the movement done for them by the
    migration data plane (:mod:`repro.tiering.migrate`, DESIGN.md §8); the
    hook remains for custom owners with their own layouts.

Everything else — sketch profiling, Algorithm 1, 2Q placement, stats — is
shared machinery in :mod:`repro.tiering.memory` / :mod:`repro.tiering.daemon`.

A :class:`ResourceSpec` is the SINGLE source of sizing truth: prof params,
tier params, and the daemon's quota all derive from one spec object, so a
resource cannot accidentally hand different geometries to the tier and the
daemon (the bug the old ExpertCache had).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.neoprof import NeoProfParams
from repro.core.sketch import SketchParams
from repro.core.tiering import TierParams


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """Sizing for one tiered resource — the only place geometry is declared.

    ``row_shape``/``row_dtype`` declare the PAYLOAD of one page for the
    migration data plane (DESIGN.md §8): ``row_shape=None`` means the
    resource is placement/telemetry-only (no data buffers bound).
    """

    name: str
    n_pages: int                  # logical pages in the slow tier
    hot_slots: int                # fast-tier capacity (pages)
    quota_pages: int = 64         # promotions per migration interval
    sketch_width: int = 1 << 14
    sketch_depth: int = 2
    stream_cap: int = 1 << 14     # max page ids fed to NeoProf per step
    touch_cap: int = 4096         # max page ids fed to tier accounting per step
    row_shape: tuple | None = None   # payload shape of ONE page (data plane)
    row_dtype: str = "bfloat16"      # payload dtype name
    slow_codec: str = "none"         # slow-store wire format (tiering.codec)

    def prof_params(self) -> NeoProfParams:
        return NeoProfParams(sketch=SketchParams(
            width=self.sketch_width, depth=self.sketch_depth))

    def tier_params(self) -> TierParams:
        return TierParams(num_pages=self.n_pages, num_slots=self.hot_slots,
                          quota_pages=self.quota_pages)

    @property
    def row_bytes(self) -> int:
        """NATIVE payload bytes per page (0 when no data plane is declared)."""
        if self.row_shape is None:
            return 0
        return math.prod(self.row_shape) * jnp.dtype(self.row_dtype).itemsize

    @property
    def wire_row_bytes(self) -> int:
        """Bytes one page costs on the migration wire under ``slow_codec``
        (== ``row_bytes`` for the ``none`` codec; DESIGN.md §14)."""
        if self.row_shape is None:
            return 0
        from repro.tiering import codec as codec_lib
        return codec_lib.wire_row_bytes(self.slow_codec, self.row_shape,
                                        self.row_dtype)

    @property
    def quota_bytes(self) -> int:
        """Per-epoch migration byte budget: each of ``quota_pages``
        promotions moves at most one row up AND one written-back row down.
        Metered in WIRE bytes — the same page-count quota costs ~4x fewer
        bytes (holds ~4x more rows per byte) under the ``int8`` codec."""
        return 2 * self.quota_pages * self.wire_row_bytes


@runtime_checkable
class TieredResource(Protocol):
    """What a consumer of tiered memory must provide (see module docstring)."""

    spec: ResourceSpec

    def encode_stream(self, *observation) -> jax.Array:
        """Pure: model-side observation -> (N,) int32 page-id stream, -1 pad."""
        ...

    def apply_migration(self, promoted_pages, victim_slots) -> None:
        """Host-side data movement for one promotion batch (may be a no-op)."""
        ...


class StreamResource:
    """Convenience base: spec + optional ``migrate_fn`` data-movement hook."""

    def __init__(self, spec: ResourceSpec,
                 migrate_fn: Callable[[jax.Array, jax.Array], None] | None = None):
        self.spec = spec
        self.migrate_fn = migrate_fn

    def apply_migration(self, promoted_pages, victim_slots) -> None:
        if self.migrate_fn is not None:
            self.migrate_fn(promoted_pages, victim_slots)


# ---------------------------------------------------------------------------
# Registry: resource kind -> class.  The serve engine / examples look tiered
# resources up by name ("kv", "experts", "embeddings") so new consumers can
# be plugged in without touching the engine.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register_resource(kind: str):
    """Class decorator: register a TieredResource implementation by name."""

    def deco(cls):
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls

    return deco


def resource_kinds() -> list[str]:
    return sorted(_REGISTRY)


def make_resource(kind: str, *args, **kwargs) -> TieredResource:
    if kind not in _REGISTRY:
        raise KeyError(
            f"unknown tiered resource {kind!r}; known: {resource_kinds()}")
    return _REGISTRY[kind](*args, **kwargs)
