"""Row codecs: the slow tier's wire format (DESIGN.md §14).

NeoMem's premise is that slow-tier bytes are the system's currency — the
CXL link is bandwidth-bound, so what a page COSTS is what it serializes
to, not what it dequantizes to.  This module makes that explicit: a codec
decides how a resource's slow store is encoded at rest, and therefore how
many bytes every migration epoch, flush, and reuse-store install meters.

Three codecs:

  * ``none`` — identity: the slow store holds rows in their native dtype.
    The default; byte-for-byte the pre-codec data path.
  * ``fp32`` — full-precision store: rows upcast to fp32 at rest.  For
    bf16-native rows this is numerically the identity (bf16 -> fp32 is
    exact), so it is the "fp arm" of the compression A/B: same values,
    4 bytes/element on the wire.
  * ``int8`` — per-row symmetric quantization: ``scale = max|row| / 127``
    (fp32, one scalar per page row), ``q = round(row / scale)`` as int8.
    ~4x fewer wire bytes than ``fp32`` and the same byte quota holds ~4x
    more slow rows; reads dequantize in the fused dual-tier gather, so
    the jitted decode path stays host-verb-free.

The quantize/dequantize core here is shared with the gradient-compression
link (:mod:`repro.dist.compression` imports :func:`quantize_int8` /
:func:`dequantize_int8` with a per-TENSOR scale) — one implementation of
the symmetric-int8 math serves both consumers, as one NeoProf serves every
resource.

Design rule for the jitted read path: DECODE dispatches on the payload's
dtype and the presence of a scale array — both trace-time static — so
``migrate.read_rows`` / ``lookup_rows`` need no codec name threaded
through the tier-view pytree.  ENCODE (writes, demotions, installs) takes
the codec name as a static argument; :mod:`repro.tiering.migrate` keys its
cached jit builders on it.

This module is a LEAF: it imports only jax/numpy, never the rest of
``repro.tiering`` or ``repro.dist`` — both packages import it, so any
repro import here would cycle through the package ``__init__``s.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

CODECS = ("none", "fp32", "int8")

_SCALE_BYTES = 4        # one fp32 scale per int8 page row


def check_codec(codec: str) -> str:
    if codec not in CODECS:
        raise KeyError(f"unknown slow-tier codec {codec!r}; known: {CODECS}")
    return codec


# ---------------------------------------------------------------------------
# the shared symmetric-int8 core (repro.dist.compression uses axes=None)
# ---------------------------------------------------------------------------

def symmetric_scale(x: jax.Array, axes=None) -> jax.Array:
    """``max|x| / 127`` over ``axes`` (None = the whole tensor), guarded so
    an all-zero slice quantizes to q == 0 with scale 1 instead of 0/0."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes) / 127.0
    return jnp.where(scale > 0.0, scale, 1.0)


def quantize_int8(x: jax.Array, axes=None) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8: -> (q int8, scale fp32 reduced over ``axes``).

    ``|x| <= 127 * scale`` by construction, so the round never clips; the
    worst-case per-element reconstruction error is ``scale / 2``.
    """
    x = x.astype(jnp.float32)
    scale = symmetric_scale(x, axes)
    s = scale.reshape(scale.shape + (1,) * (x.ndim - scale.ndim))
    q = jnp.round(x / s).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, out_dtype) -> jax.Array:
    """``q * scale`` broadcast back over the quantized trailing axes."""
    x = q.astype(jnp.float32)
    s = scale.reshape(scale.shape + (1,) * (x.ndim - scale.ndim))
    return (x * s).astype(out_dtype)


# ---------------------------------------------------------------------------
# row codecs (the slow store's at-rest format)
# ---------------------------------------------------------------------------

def encode_rows(codec: str, rows: jax.Array
                ) -> tuple[jax.Array, jax.Array | None]:
    """Encode ``(K, *row_shape)`` native rows for the slow store.

    -> ``(payload, scale)``: ``int8`` yields an int8 payload plus a (K,)
    fp32 per-row scale; ``none``/``fp32`` yield a dtype-cast payload and
    ``scale=None``.  Pure jnp — safe inside the write verbs' jits.
    """
    check_codec(codec)
    if codec == "int8":
        return quantize_int8(rows, axes=tuple(range(1, rows.ndim)))
    if codec == "fp32":
        return rows.astype(jnp.float32), None
    return rows, None


def decode_rows(payload: jax.Array, scale: jax.Array | None,
                out_dtype) -> jax.Array:
    """Decode slow-store rows back to ``out_dtype`` (the fast tier's dtype).

    Dispatch is trace-time static — payload dtype and scale presence — so
    this inlines into the fused dual-tier gather with no host verb: an
    int8 payload dequantizes against its per-row scales, anything else is
    a plain cast (identity for ``none``; exact bf16<->fp32 for ``fp32``).
    """
    if payload.dtype == jnp.int8:
        if scale is None:
            raise ValueError("int8 slow store decoded without its scales")
        return dequantize_int8(payload, scale, out_dtype)
    return payload.astype(out_dtype)


def encode_store(codec: str, slow_data: jax.Array
                 ) -> tuple[jax.Array, jax.Array | None]:
    """Encode a whole ``(num_pages, *row_shape)`` backing store at bind
    time (same layout contract as :func:`encode_rows`)."""
    return encode_rows(codec, jnp.asarray(slow_data))


def wire_row_bytes(codec: str, row_shape: tuple, row_dtype) -> int:
    """Bytes ONE page row costs on the migration wire / at rest.

    This is the byte unit every quota and telemetry counter meters
    (DESIGN.md §14): ``int8`` pays 1 byte/element + its fp32 scale,
    ``fp32`` pays 4 bytes/element, ``none`` pays the native dtype.
    """
    check_codec(codec)
    n = math.prod(row_shape)
    if codec == "int8":
        return n + _SCALE_BYTES
    if codec == "fp32":
        return n * 4
    return n * jnp.dtype(row_dtype).itemsize
