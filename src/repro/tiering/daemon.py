"""Multiplexed NeoMem daemon: one cadence, N tiered resources, one budget.

The software analogue of one NeoProf device serving every consumer of slow
memory (paper §III): the serve engine / trainer registers each resource
(KV pages, MoE experts, embedding rows, ...) once, and a single host-side
loop drives all of them on the shared cadence hierarchy

    migration  <<  threshold-update  <=  sketch-clear

with ONE migration-quota budget per interval, split across resources in
proportion to their *servable* queued demand (each share capped by that
resource's own promotion-batch quota) — the multiplexed form of
Algorithm 1's quota constraint: a bursty resource is throttled toward its
fair share instead of starving the others, and demand it could not promote
anyway never draws budget away from resources that can.

Resources with bound payload buffers get each epoch's promotion batch
applied as one fused copy through the migration data plane, with the moved
bytes metered per resource (DESIGN.md §8).
"""
from __future__ import annotations

import jax

from repro.tiering.memory import (DaemonParams, MigrationEvent, TieredMemory,
                                  TieredMemoryState, lookup)
from repro.tiering.resource import TieredResource
from repro.tiering.stats import TierStats


def split_quota(budget: int, demands: dict[str, int],
                caps: dict[str, int] | None = None) -> dict[str, int]:
    """Largest-remainder proportional split of the shared migration budget.

    ``caps`` bounds each share by what that resource can actually promote in
    one batch (its static quota width) — un-servable backlog must not draw
    budget away from resources that could use it.
    """
    eff = {n: min(d, caps[n]) if caps else d for n, d in demands.items()}
    total = sum(eff.values())
    if total <= budget:
        return eff
    exact = {n: budget * d / total for n, d in eff.items()}
    shares = {n: int(e) for n, e in exact.items()}
    leftover = budget - sum(shares.values())
    for n in sorted(eff, key=lambda n: exact[n] - shares[n], reverse=True):
        if leftover <= 0:
            break
        shares[n] += 1    # stays <= eff[n]: exact < eff and eff is integral
        leftover -= 1
    return shares


class ResourceHandle:
    """A registered resource's live view: state pytree + stats + encoder."""

    def __init__(self, name: str, resource: TieredResource, mem: TieredMemory):
        self.name = name
        self.resource = resource
        self.mem = mem
        self.state: TieredMemoryState = mem.init()
        self.stats = TierStats(name=name)

    def observe(self, *observation, **kw) -> None:
        """Encode a model-side observation and feed profiler + tier."""
        stream = self.resource.encode_stream(*observation)
        cap = self.resource.spec.touch_cap
        self.state = self.mem.observe(self.state, stream,
                                      touch_pages=stream[:cap], **kw)

    def observe_pages(self, pages, *, touch_pages=None, **kw) -> None:
        """Feed an already-encoded page-id stream (bypasses the encoder)."""
        self.state = self.mem.observe(self.state, pages,
                                      touch_pages=touch_pages, **kw)

    def lookup(self, page_ids) -> tuple[jax.Array, jax.Array]:
        return lookup(self.state, page_ids)

    # -- data plane (DESIGN.md §8) -------------------------------------------
    def bind_data(self, slow_data) -> None:
        """Attach the resource's payload; promotions then move real bytes."""
        self.mem.bind_data(slow_data)
        self.stats.quota_bytes = self.mem.quota_bytes

    def read_rows(self, page_ids) -> jax.Array:
        """Serve payload rows: fast-buffer copy on hit, slow-tier fallback."""
        return self.mem.read_rows(self.state, page_ids)

    def write_rows(self, page_ids, rows) -> None:
        """Owner payload refresh, both tiers kept coherent; bytes metered."""
        n = self.mem.write_rows(self.state, page_ids, rows)
        self.stats.flush_bytes += n * self.mem.row_bytes

    def hit_rate(self) -> float:
        return self.mem.hit_rate(self.state, self.stats)

    def snapshot(self) -> dict:
        row = self.stats.as_row()
        row["hit_rate"] = self.hit_rate()
        return row


class NeoMemDaemon:
    """One daemon loop multiplexed across every registered tiered resource."""

    def __init__(self, params: DaemonParams | None = None):
        self.dp = params or DaemonParams()
        self.resources: dict[str, ResourceHandle] = {}
        self._tick = 0

    # -- registration --------------------------------------------------------
    def register(self, resource: TieredResource, *,
                 policy_params=None, fixed_theta=None) -> ResourceHandle:
        """Register a resource; its ResourceSpec is the single sizing source."""
        spec = resource.spec
        if spec.name in self.resources:
            raise ValueError(f"resource {spec.name!r} already registered")
        mem = TieredMemory.from_spec(
            spec, daemon_params=DaemonParams(
                migration_interval=self.dp.migration_interval,
                threshold_update_period=self.dp.threshold_update_period,
                clear_interval=self.dp.clear_interval,
                quota_pages=spec.quota_pages),
            policy_params=policy_params, fixed_theta=fixed_theta)
        handle = ResourceHandle(spec.name, resource, mem)
        self.resources[spec.name] = handle
        return handle

    def __getitem__(self, name: str) -> ResourceHandle:
        return self.resources[name]

    def __contains__(self, name: str) -> bool:
        return name in self.resources

    def observe(self, name: str, *observation, **kw) -> None:
        self.resources[name].observe(*observation, **kw)

    # -- the multiplexed loop ------------------------------------------------
    @property
    def budget(self) -> int:
        """Shared promotion budget per migration interval."""
        if self.dp.quota_pages is not None:
            return self.dp.quota_pages
        return sum(h.mem.quota for h in self.resources.values())

    def tick(self) -> dict[str, MigrationEvent]:
        """One daemon tick: run whatever cadences are due, for ALL resources."""
        self._tick += 1
        t, dp = self._tick, self.dp
        events: dict[str, MigrationEvent] = {}

        if t % dp.migration_interval == 0:
            demands: dict[str, int] = {}
            for name, h in self.resources.items():
                h.state, demands[name] = h.mem.collect(h.state, h.stats)
            caps = {n: h.mem.quota for n, h in self.resources.items()}
            shares = split_quota(self.budget, demands, caps)
            for name, h in self.resources.items():
                h.state, event = h.mem.migrate(h.state, h.stats,
                                               quota=shares.get(name, 0))
                if event is not None:
                    # data plane first (one fused copy against the bound
                    # buffers, bytes metered), then the resource's own hook
                    h.mem.apply_migration(event, h.stats)
                    h.resource.apply_migration(event.promoted, event.victims)
                    events[name] = event

        if t % dp.threshold_update_period == 0:
            for h in self.resources.values():
                h.state = h.mem.update_threshold(h.state, h.stats)

        if t % dp.clear_interval == 0:
            for h in self.resources.values():
                h.state = h.mem.clear(h.state)
        return events

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict[str, TierStats]:
        return {n: h.stats for n, h in self.resources.items()}

    def hit_rates(self) -> dict[str, float]:
        return {n: h.hit_rate() for n, h in self.resources.items()}

    def snapshot(self) -> dict[str, dict]:
        """Per-resource flat telemetry rows (benchmark / logging schema)."""
        return {n: h.snapshot() for n, h in self.resources.items()}
