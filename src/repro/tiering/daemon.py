"""Multiplexed NeoMem daemon: one cadence, N tiered resources, one budget.

The software analogue of one NeoProf device serving every consumer of slow
memory (paper §III): the serve engine / trainer registers each resource
(KV pages, MoE experts, embedding rows, ...) once, and a single host-side
loop drives all of them on the shared cadence hierarchy

    migration  <<  threshold-update  <=  sketch-clear

with ONE migration-quota budget per interval, split across resources in
proportion to their *servable* queued demand (each share capped by that
resource's own promotion-batch quota) — the multiplexed form of
Algorithm 1's quota constraint: a bursty resource is throttled toward its
fair share instead of starving the others, and demand it could not promote
anyway never draws budget away from resources that can.

Resources with bound payload buffers get each epoch's promotion batch
applied as one fused copy through the migration data plane, with the moved
bytes metered per resource (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.tiering.memory import (DaemonParams, MigrationEvent, TieredMemory,
                                  TieredMemoryState, lookup)
from repro.tiering.resource import TieredResource
from repro.tiering.stats import TierStats


def split_quota(budget: int, demands: dict[str, int],
                caps: dict[str, int] | None = None,
                weights: dict[str, float] | None = None) -> dict[str, int]:
    """Largest-remainder proportional split of the shared migration budget.

    ``caps`` bounds each share by what that resource can actually promote in
    one batch (its static quota width) — un-servable backlog must not draw
    budget away from resources that could use it.

    ``weights`` are isolation weights (default 1.0 each, DESIGN.md §9): when
    the budget binds, shares are proportional to ``weight x servable demand``
    and any share that would exceed its own demand is clamped there, with the
    freed budget redistributed among the rest (weighted max-min).  An entry
    with weight <= 0 is isolated out entirely under contention — it only
    receives budget when the total demand fits.  The same split serves two
    layers: the daemon's per-resource migration budget and the request
    scheduler's per-tenant decode-lane allocation (serve/sched.py).
    """
    eff = {n: min(d, caps[n]) if caps else d for n, d in demands.items()}
    total = sum(eff.values())
    if total <= budget:
        return eff
    w = {n: 1.0 if weights is None else float(weights.get(n, 1.0))
         for n in eff}
    shares = {n: 0 for n in eff}
    open_ = [n for n in eff if eff[n] > 0 and w[n] > 0]
    remaining = budget
    while open_ and remaining > 0:
        tot = sum(w[n] * eff[n] for n in open_)
        exact = {n: remaining * w[n] * eff[n] / tot for n in open_}
        clamped = [n for n in open_ if exact[n] >= eff[n]]
        if not clamped:
            for n in open_:
                shares[n] = int(exact[n])
            leftover = remaining - sum(shares[n] for n in open_)
            for n in sorted(open_, key=lambda n: exact[n] - shares[n],
                            reverse=True):
                if leftover <= 0:
                    break
                shares[n] += 1   # stays <= eff[n]: exact < eff, eff integral
                leftover -= 1
            break
        for n in clamped:            # demand-bound: give it all, redistribute
            shares[n] = eff[n]
            remaining -= eff[n]
        open_ = [n for n in open_ if n not in clamped]
    return shares


class ResourceHandle:
    """A registered resource's live view: state pytree + stats + encoder."""

    def __init__(self, name: str, resource: TieredResource, mem: TieredMemory,
                 weight: float = 1.0):
        self.name = name
        self.resource = resource
        self.mem = mem
        self.weight = weight          # isolation weight in the quota split
        self.state: TieredMemoryState = mem.init()
        self.stats = TierStats(name=name)

    def observe(self, *observation, **kw) -> None:
        """Encode a model-side observation and feed profiler + tier."""
        stream = self.resource.encode_stream(*observation)
        cap = self.resource.spec.touch_cap
        self.state = self.mem.observe(self.state, stream,
                                      touch_pages=stream[:cap], **kw)

    def observe_pages(self, pages, *, touch_pages=None, **kw) -> None:
        """Feed an already-encoded page-id stream (bypasses the encoder)."""
        self.state = self.mem.observe(self.state, pages,
                                      touch_pages=touch_pages, **kw)

    def lookup(self, page_ids) -> tuple[jax.Array, jax.Array]:
        return lookup(self.state, page_ids)

    # -- data plane (DESIGN.md §8) -------------------------------------------
    def bind_data(self, slow_data, initially_valid: bool = True) -> None:
        """Attach the resource's payload; promotions then move real bytes.
        ``initially_valid=False`` starts every page un-witnessed (the KV
        scratch store) — see :meth:`TieredMemory.pages_written`."""
        self.mem.bind_data(slow_data, initially_valid=initially_valid)
        self.stats.quota_bytes = self.mem.quota_bytes

    def pages_written(self, page_ids) -> np.ndarray:
        """Per-page write-witness query (the segment-residency gate)."""
        return self.mem.pages_written(page_ids)

    def tier_view(self) -> dict[str, jax.Array]:
        """Device-array view for in-jit reads: ``{"fast", "slow",
        "page_slot", "scale"}`` (``scale`` is the int8 codec's per-row
        scales, ``None`` otherwise), to be threaded as jit arguments into a
        step that calls :func:`repro.tiering.migrate.lookup_rows`
        (DESIGN.md §10, §14).
        Reads served this way are metered by the observation stream's touch
        accounting, not the host ``read_rows`` counters."""
        return self.mem.tier_view(self.state)

    def lookup_rows(self, page_ids) -> jax.Array:
        """Pure jittable read (no host metering): see ``TieredMemory.lookup_rows``."""
        return self.mem.lookup_rows(self.state, page_ids)

    def read_rows(self, page_ids) -> jax.Array:
        """Serve payload rows: fast-buffer copy on hit, slow-tier fallback.

        Served reads are metered into ``stats.fast_reads``/``slow_reads`` —
        they are real tier accesses, exactly like the observation stream's
        touch accounting (invalid ids < 0 are padding and not counted).
        """
        ids = jnp.asarray(page_ids, jnp.int32)
        # the ONE placement lookup — against the COMMITTED view, so reads
        # issued mid-epoch resolve exactly like the payload gather below
        slots = self.mem.lookup_slots(self.state, ids)
        hits = int(np.sum(np.asarray(slots) >= 0))
        self.stats.fast_reads += hits
        self.stats.slow_reads += int(np.sum(np.asarray(ids) >= 0)) - hits
        return self.mem.read_rows(self.state, ids, slots=slots)

    def write_rows(self, page_ids, rows) -> None:
        """Owner payload refresh, both tiers kept coherent; bytes metered."""
        n = self.mem.write_rows(self.state, page_ids, rows)
        self.stats.flush_bytes += n * self.mem.row_bytes

    def write_pages(self, page_ids, k_pages, v_pages) -> None:
        """Bulk KV ring-page flush (one donated fused op); bytes metered."""
        n = self.mem.write_pages(self.state, page_ids, k_pages, v_pages)
        self.stats.flush_bytes += n * self.mem.row_bytes

    def copy_rows(self, src_ids, dst_ids) -> None:
        """Store-to-store page duplication (the content-addressed publish
        verb, one donated fused op); bytes metered as flush traffic."""
        n = self.mem.copy_rows(self.state, src_ids, dst_ids)
        self.stats.flush_bytes += n * self.mem.row_bytes

    def hit_rate(self) -> float:
        return self.mem.hit_rate(self.state, self.stats)

    def snapshot(self) -> dict:
        row = self.stats.as_row()
        # merge the not-yet-drained device-side period counters so the read
        # counts are consistent with hit_rate() (which always merged them) —
        # a row must never report 0 reads next to a nonzero hit rate
        row["fast_reads"] += int(self.state.tier.fast_reads)
        row["slow_reads"] += int(self.state.tier.slow_reads)
        row["hit_rate"] = self.hit_rate()
        # fold the in-flight epoch the same way: a snapshot taken mid-epoch
        # must still satisfy last_epoch <= max_epoch <= quota row-level
        # conservation — the issued bytes count against the epoch quota the
        # moment they are in flight, not only once committed
        if self.stats.inflight_bytes:
            row["max_epoch_bytes"] = max(row["max_epoch_bytes"],
                                         self.stats.inflight_bytes)
        return row


class NeoMemDaemon:
    """One daemon loop multiplexed across every registered tiered resource."""

    def __init__(self, params: DaemonParams | None = None):
        self.dp = params or DaemonParams()
        self.resources: dict[str, ResourceHandle] = {}
        self._tick = 0

    # -- registration --------------------------------------------------------
    def register(self, resource: TieredResource, *,
                 policy_params=None, fixed_theta=None,
                 weight: float = 1.0) -> ResourceHandle:
        """Register a resource; its ResourceSpec is the single sizing source.

        ``weight`` is the resource's isolation weight in the shared-budget
        split (``split_quota``): under contention a resource's share is
        proportional to ``weight x servable demand``.
        """
        spec = resource.spec
        if spec.name in self.resources:
            raise ValueError(f"resource {spec.name!r} already registered")
        mem = TieredMemory.from_spec(
            spec, daemon_params=DaemonParams(
                migration_interval=self.dp.migration_interval,
                threshold_update_period=self.dp.threshold_update_period,
                clear_interval=self.dp.clear_interval,
                quota_pages=spec.quota_pages,
                async_plane=self.dp.async_plane),
            policy_params=policy_params, fixed_theta=fixed_theta)
        handle = ResourceHandle(spec.name, resource, mem, weight=weight)
        self.resources[spec.name] = handle
        return handle

    def __getitem__(self, name: str) -> ResourceHandle:
        return self.resources[name]

    def __contains__(self, name: str) -> bool:
        return name in self.resources

    def observe(self, name: str, *observation, **kw) -> None:
        self.resources[name].observe(*observation, **kw)

    # -- the multiplexed loop ------------------------------------------------
    @property
    def budget(self) -> int:
        """Shared promotion budget per migration interval."""
        if self.dp.quota_pages is not None:
            return self.dp.quota_pages
        return sum(h.mem.quota for h in self.resources.values())

    def tick(self) -> dict[str, MigrationEvent]:
        """One daemon tick: run whatever cadences are due, for ALL resources."""
        self._tick += 1
        t, dp = self._tick, self.dp
        events: dict[str, MigrationEvent] = {}

        if t % dp.migration_interval == 0:
            # COMMIT phase first (async plane, DESIGN.md §15): witness each
            # in-flight epoch's readiness token and pointer-swap — never
            # blocks; an epoch whose copy has not landed stays in flight
            for h in self.resources.values():
                if h.mem.async_on:
                    h.mem.commit_migration(h.stats)
            # PLAN phase (unchanged policy): drain hot pages, split the
            # shared budget.  A busy resource (epoch still uncommitted) is
            # capped at 0 — no N+2 issue before N+1 commits, and its share
            # flows to the others via the weighted max-min redistribution.
            demands: dict[str, int] = {}
            for name, h in self.resources.items():
                h.state, demands[name] = h.mem.collect(h.state, h.stats)
            caps = {n: (0 if h.mem.busy else h.mem.quota)
                    for n, h in self.resources.items()}
            weights = {n: h.weight for n, h in self.resources.items()}
            shares = split_quota(self.budget, demands, caps, weights)
            # ISSUE phase: promote + dispatch the epoch's data movement
            # (async: non-blocking issue; sync: fused donated copy, with
            # the blocking wait metered as stall_s)
            for name, h in self.resources.items():
                if h.mem.busy:
                    continue
                h.state, event = h.mem.migrate(h.state, h.stats,
                                               quota=shares.get(name, 0))
                if event is not None:
                    # data plane first (bytes metered), then the
                    # resource's own hook
                    h.mem.dispatch_migration(h.state, event, h.stats)
                    h.resource.apply_migration(event.promoted, event.victims)
                    events[name] = event

        if t % dp.threshold_update_period == 0:
            for h in self.resources.values():
                h.state = h.mem.update_threshold(h.state, h.stats)

        if t % dp.clear_interval == 0:
            for h in self.resources.values():
                h.state = h.mem.clear(h.state)
        return events

    # -- checkpointing (DESIGN.md §6) ----------------------------------------
    def state_dict(self) -> dict[str, TieredMemoryState]:
        """Every resource's TieredMemoryState, as ONE pure pytree.

        The returned tree checkpoints directly through ``ckpt/manager.py``;
        a restored server resumes with a warm placement map.  The host-side
        pending FIFOs are best-effort (DESIGN.md §6) and not included — they
        are re-derived from the next sketch epoch after restore.

        Any in-flight async epoch is FINALIZED (force-committed) first: the
        persisted placement map is the control table, so the payload the
        checkpoint implies must match it deterministically (DESIGN.md §15).
        """
        self.finalize()
        return {n: h.state for n, h in self.resources.items()}

    def finalize(self) -> None:
        """Force-commit every in-flight async epoch (accounting barrier:
        checkpoint save, benchmark end-of-run byte parity, shutdown)."""
        for h in self.resources.values():
            h.mem.finalize_epoch(h.stats)

    def load_state(self, states: dict[str, TieredMemoryState]) -> None:
        """Restore a ``state_dict()`` pytree into the registered resources.

        Structure and leaf shapes must match the registered geometry.  For
        resources with bound payload buffers, the fast copies of every
        resident page are re-gathered from the slow store, so the restored
        placement map never serves a cold fast row.
        """
        for name, st in states.items():
            if name not in self.resources:
                raise KeyError(f"state for unregistered resource {name!r}")
            h = self.resources[name]
            if jax.tree.structure(st) != jax.tree.structure(h.state):
                raise ValueError(
                    f"{name}: checkpointed state structure does not match")
            for cur, new in zip(jax.tree.leaves(h.state),
                                jax.tree.leaves(st)):
                if jnp.shape(cur) != jnp.shape(new):
                    raise ValueError(
                        f"{name}: leaf shape {jnp.shape(new)} != registered "
                        f"geometry {jnp.shape(cur)}")
            h.state = jax.tree.map(
                lambda cur, new: jnp.asarray(new, jnp.asarray(cur).dtype), h.state, st)
            # the pending backlog belongs to the PRE-restore stream — keeping
            # it would promote stale pages into the restored placement map,
            # and so does any issued-but-uncommitted epoch: DROP it (the
            # deterministic half of commit-or-drop, DESIGN.md §15)
            h.mem.clear_pending()
            h.stats.pending = 0
            h.mem.drop_inflight(h.stats)
            h.mem.refill_fast(h.state)
            h.mem.reset_committed(h.state)

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict[str, TierStats]:
        return {n: h.stats for n, h in self.resources.items()}

    def hit_rates(self) -> dict[str, float]:
        return {n: h.hit_rate() for n, h in self.resources.items()}

    def snapshot(self) -> dict[str, dict]:
        """Per-resource flat telemetry rows (benchmark / logging schema)."""
        return {n: h.snapshot() for n, h in self.resources.items()}
