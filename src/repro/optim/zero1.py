"""ZeRO-1: flat-sharded optimizer state over the full device mesh.

Adam's m/v are elementwise, so they need no tensor structure: flatten every
param into one padded 1-D vector sharded evenly across ALL mesh axes.  The
update runs in flat space (embarrassingly parallel); the delta is gathered
back to each param's own sharding by XLA when applied (one all-gather worth
of bytes per step — the classic ZeRO-1 trade of memory for collective).

For a 27B dense model on 256 chips this turns 216 GB of fp32 m+v into
0.84 GB/chip.  Used by the hillclimb as an alternative to Adafactor.

``compress_collective`` (DESIGN.md §14) quantizes the flat DELTA to int8
per shard — one symmetric scale per mesh-device shard — before it is
gathered back to param shardings, cutting the step's dominant collective
~4x (int8 payload + one fp32 scale/shard vs fp32 everywhere).  A local
fp32 error-feedback vector (``state["ef"]``, same flat sharding as m/v)
carries the quantization residual into the next step, so the accumulated
applied update is unbiased — the same contract as the gradient link in
:mod:`repro.dist.compression`, sharing the same
:func:`repro.tiering.codec.quantize_int8` core.  Ordering matters: the
global-norm clip runs on the GRADIENT tree before flattening (identical in
both modes), and quantization happens strictly after the flat-space
optimizer math, so m/v/step trajectories stay bitwise independent of the
codec — only the applied delta differs, by at most one quantum per shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.optimizers import OptConfig, clip_by_global_norm, schedule
from repro.tiering.codec import dequantize_int8, quantize_int8


@dataclasses.dataclass
class FlatSpec:
    sizes: list
    shapes: list
    treedef: Any
    padded: int


def flat_spec(params, n_shards: int) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    total = sum(sizes)
    padded = int(np.ceil(total / n_shards) * n_shards)
    return FlatSpec(sizes, [l.shape for l in leaves], treedef, padded)


def flatten(tree, spec: FlatSpec) -> jax.Array:
    leaves = spec.treedef.flatten_up_to(tree)
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(-1) for l in leaves])
    return jnp.pad(flat, (0, spec.padded - flat.shape[0]))


def unflatten(flat: jax.Array, spec: FlatSpec, dtypes=None):
    out, off = [], 0
    for i, (sz, shp) in enumerate(zip(spec.sizes, spec.shapes)):
        leaf = flat[off:off + sz].reshape(shp)
        if dtypes is not None:
            leaf = leaf.astype(dtypes[i])
        out.append(leaf)
        off += sz
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def flat_sharding(mesh):
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def _n_shards(mesh) -> int:
    return int(np.prod(mesh.devices.shape)) if mesh is not None else 1


def zero1_init(params, mesh, compress_collective: bool = False,
               offload: bool = False):
    n = _n_shards(mesh)
    spec = flat_spec(params, n)
    sh = flat_sharding(mesh) if mesh is not None else None

    def z():
        buf = jnp.zeros((spec.padded,), jnp.float32)
        return jax.lax.with_sharding_constraint(buf, sh) if sh is not None \
            else buf

    state = {"m": z(), "v": z(), "step": jnp.zeros((), jnp.int32)}
    if compress_collective:
        # local error-feedback residual of the quantized delta collective —
        # flat-sharded exactly like m/v, never itself gathered
        state["ef"] = z()
    if offload:
        # park the master vectors in the slow tier between steps
        # (DESIGN.md §15): the train step prefetches them back during the
        # backward (``fetch_opt``) and re-offloads after the update
        state = offload_opt(state, mesh)
    return state, spec


def _opt_tiered(state, mesh, mover):
    """Move every flat master vector (m/v/ef — not the step scalar) between
    memory tiers with :mod:`repro.dist.host_offload`.  Identity without a
    mesh, and logical-only on backends without memory kinds (CPU), so the
    offloaded path stays BITWISE identical to the resident one — the tier
    move never changes values, only placement."""
    if mesh is None:
        return state
    spec = P(tuple(mesh.axis_names))
    return {k: (v if k == "step" else mover(v, mesh, spec))
            for k, v in state.items()}


def offload_opt(state, mesh):
    """Demote the ZeRO-1 master/EF vectors to the pinned-host slow tier."""
    from repro.dist import host_offload  # lazy: optim must stay dist-free
    return _opt_tiered(state, mesh, host_offload.to_slow_tier)


def fetch_opt(state, mesh):
    """Promote the master/EF vectors back to device memory.  Issue this
    BEFORE the gradient computation inside the jitted step: the fetch has
    no data dependency on the grads, so XLA's scheduler overlaps the
    host→device copy with the backward pass (prefetch-before-consume)."""
    from repro.dist import host_offload
    return _opt_tiered(state, mesh, host_offload.to_fast_tier)


def compress_delta(delta: jax.Array, ef: jax.Array, n_shards: int
                   ) -> tuple[jax.Array, jax.Array, int]:
    """int8-quantize the flat delta per mesh shard with error feedback.

    -> (applied delta fp32, new residual, collective wire bytes).  The
    padded flat length is divisible by ``n_shards`` by construction
    (:func:`flat_spec`), so the per-shard view is a plain reshape; each
    shard quantizes against its own symmetric scale — the same shape the
    gather collective moves, so the wire carries ``padded`` int8 payload
    bytes plus one fp32 scale per shard (~4x under fp32).
    """
    x = delta + ef
    q, scale = quantize_int8(x.reshape(n_shards, -1), axes=(1,))
    applied = dequantize_int8(q, scale, jnp.float32).reshape(-1)
    return applied, x - applied, int(q.size) + 4 * n_shards


def zero1_update(cfg: OptConfig, params, grads, state, spec: FlatSpec, mesh,
                 compress_collective: bool = False):
    """Flat-space AdamW; delta unflattened back to param shardings.

    ``compress_collective`` requires the ``"ef"`` residual in ``state``
    (init with ``zero1_init(..., compress_collective=True)``); the delta is
    int8-quantized per shard before the unflatten-gather and the residual
    carries to the next step.  The aux dict reports the gather's wire bytes
    in both modes (``collective_bytes``).
    """
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    g = flatten(grads, spec)
    if mesh is not None:
        g = jax.lax.with_sharding_constraint(g, flat_sharding(mesh))
    p_flat = flatten(params, spec)
    if mesh is not None:
        p_flat = jax.lax.with_sharding_constraint(p_flat, flat_sharding(mesh))
    b1, b2 = cfg.b1, cfg.b2
    m = b1 * state["m"] + (1 - b1) * g
    v = b2 * state["v"] + (1 - b2) * g * g
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p_flat
    delta = lr * u
    new_state = {"m": m, "v": v, "step": step}
    if compress_collective:
        delta, ef, wire = compress_delta(delta, state["ef"], _n_shards(mesh))
        if mesh is not None:
            ef = jax.lax.with_sharding_constraint(ef, flat_sharding(mesh))
        new_state["ef"] = ef
    else:
        if "ef" in state:        # state threads through unchanged when the
            new_state["ef"] = state["ef"]   # mode is toggled off mid-run
        wire = 4 * spec.padded
    # the delta stays fp32 through the unflatten-gather — the subtraction
    # below accumulates in fp32 and casts once, per leaf
    delta_tree = unflatten(delta, spec)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - d).astype(p.dtype),
        params, delta_tree)
    return new_params, new_state, {"gnorm": gnorm, "lr": lr,
                                   "collective_bytes": wire}
