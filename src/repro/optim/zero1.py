"""ZeRO-1: flat-sharded optimizer state over the full device mesh.

Adam's m/v are elementwise, so they need no tensor structure: flatten every
param into one padded 1-D vector sharded evenly across ALL mesh axes.  The
update runs in flat space (embarrassingly parallel); the delta is gathered
back to each param's own sharding by XLA when applied (one all-gather worth
of bytes per step — the classic ZeRO-1 trade of memory for collective).

For a 27B dense model on 256 chips this turns 216 GB of fp32 m+v into
0.84 GB/chip.  Used by the hillclimb as an alternative to Adafactor.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.optimizers import OptConfig, clip_by_global_norm, schedule


@dataclasses.dataclass
class FlatSpec:
    sizes: list
    shapes: list
    treedef: Any
    padded: int


def flat_spec(params, n_shards: int) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    total = sum(sizes)
    padded = int(np.ceil(total / n_shards) * n_shards)
    return FlatSpec(sizes, [l.shape for l in leaves], treedef, padded)


def flatten(tree, spec: FlatSpec) -> jax.Array:
    leaves = spec.treedef.flatten_up_to(tree)
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(-1) for l in leaves])
    return jnp.pad(flat, (0, spec.padded - flat.shape[0]))


def unflatten(flat: jax.Array, spec: FlatSpec, dtypes=None):
    out, off = [], 0
    for i, (sz, shp) in enumerate(zip(spec.sizes, spec.shapes)):
        leaf = flat[off:off + sz].reshape(shp)
        if dtypes is not None:
            leaf = leaf.astype(dtypes[i])
        out.append(leaf)
        off += sz
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def flat_sharding(mesh):
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def zero1_init(params, mesh):
    n = int(np.prod(mesh.devices.shape))
    spec = flat_spec(params, n)
    sh = flat_sharding(mesh)
    z = jax.lax.with_sharding_constraint(jnp.zeros((spec.padded,), jnp.float32), sh) \
        if mesh is not None else jnp.zeros((spec.padded,), jnp.float32)
    return {"m": z, "v": z, "step": jnp.zeros((), jnp.int32)}, spec


def zero1_update(cfg: OptConfig, params, grads, state, spec: FlatSpec, mesh):
    """Flat-space AdamW; delta unflattened back to param shardings."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    g = flatten(grads, spec)
    if mesh is not None:
        g = jax.lax.with_sharding_constraint(g, flat_sharding(mesh))
    p_flat = flatten(params, spec)
    if mesh is not None:
        p_flat = jax.lax.with_sharding_constraint(p_flat, flat_sharding(mesh))
    b1, b2 = cfg.b1, cfg.b2
    m = b1 * state["m"] + (1 - b1) * g
    v = b2 * state["v"] + (1 - b2) * g * g
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p_flat
    delta = lr * u
    dtypes = [l.dtype for l in spec.treedef.flatten_up_to(params)]
    delta_tree = unflatten(delta, spec, dtypes=None)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - d).astype(p.dtype),
        params, delta_tree)
    return new_params, {"m": m, "v": v, "step": step}, {"gnorm": gnorm, "lr": lr}
