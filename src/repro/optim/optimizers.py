"""Optimizers: AdamW and Adafactor (for the 671B/1T MoE cells), pure pytrees.

Both are written as (init, update) pairs over arbitrary param pytrees so
optimizer state inherits the params' shardings by construction; ZeRO-1 flat
sharding lives in optim/zero1.py.  Adafactor's factored second moment is the
memory plan for the giants: ~0 bytes/param vs Adam's 8.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale), tree), g


# -- AdamW ---------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"gnorm": gnorm, "lr": lr}


# -- Adafactor (factored, momentum-free) ----------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def st(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"s": jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, s):
        g2 = g * g + 1e-30
        if "vr" in s:
            vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + cfg.eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            u = g / (jnp.sqrt(v) + cfg.eps)
            new_s = {"v": v}
        # update clipping (Adafactor's RMS trick)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["s"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_s = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_params, {"s": new_s, "step": step}, {"gnorm": gnorm, "lr": lr}


def make_optimizer(cfg: OptConfig):
    if cfg.kind == "adafactor":
        return adafactor_init, lambda p, g, s: adafactor_update(cfg, p, g, s)
    return adamw_init, lambda p, g, s: adamw_update(cfg, p, g, s)
