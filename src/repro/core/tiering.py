"""TieredStore — two-tier page placement with promotion / 2Q demotion.

The TPU-native analogue of the paper's fast (DRAM) / slow (CXL) NUMA pair:
a fixed pool of fast-tier *slots* (HBM-resident cache buffers) in front of a
slow-tier *backing store* (host memory on real TPU; a logically separate
array on the CPU backend — see DESIGN.md §7).

Faithful pieces:
  * promotion of NeoProf-reported hot pages, bounded by the migration quota;
  * cold-page demotion via the kernel's LRU-2Q — adapted to a vectorized
    rank eviction with the same preference order
    (free < inactive-unreferenced < inactive-ref < active-unref < active-ref,
    ties by last touch).  New promotions enter the inactive (A1in) list and
    graduate to active (Am) on re-reference, exactly as 2Q;
  * the ``PG_demoted`` ping-pong flag: a promotion of a previously-demoted
    page counts as a ping-pong event (policy input P).

Everything is a pytree of device arrays updated by jitted pure functions, so
tier management composes with pjit/shard_map and never leaves the device.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class TierParams(NamedTuple):
    num_pages: int           # logical pages in the slow tier's address space
    num_slots: int           # fast-tier capacity (pages)
    quota_pages: int = 4096  # max promotions per migration interval


class TierState(NamedTuple):
    page_slot: jax.Array    # (num_pages,) int32 -> slot id, -1 if slow-tier
    slot_page: jax.Array    # (num_slots,) int32 -> page id, -1 if free
    active: jax.Array       # (num_slots,) bool — 2Q list: False=A1in, True=Am
    referenced: jax.Array   # (num_slots,) bool — touched since last scan
    last_touch: jax.Array   # (num_slots,) int32 — step of last touch
    demoted: jax.Array      # (num_pages,) bool — PG_demoted flag
    step: jax.Array         # () int32
    # Period statistics (drained by the daemon each policy interval).
    promoted: jax.Array     # () int32
    demoted_cnt: jax.Array  # () int32
    ping_pong: jax.Array    # () int32
    slow_reads: jax.Array   # () int32 — page-granular slow-tier read count
    fast_reads: jax.Array   # () int32


def tier_init(params: TierParams) -> TierState:
    z = jnp.zeros((), jnp.int32)
    return TierState(
        page_slot=jnp.full((params.num_pages,), -1, jnp.int32),
        slot_page=jnp.full((params.num_slots,), -1, jnp.int32),
        active=jnp.zeros((params.num_slots,), jnp.bool_),
        referenced=jnp.zeros((params.num_slots,), jnp.bool_),
        last_touch=jnp.zeros((params.num_slots,), jnp.int32),
        demoted=jnp.zeros((params.num_pages,), jnp.bool_),
        step=z, promoted=z, demoted_cnt=z, ping_pong=z,
        slow_reads=z, fast_reads=z,
    )


@jax.jit
def touch(state: TierState, page_ids: jax.Array) -> TierState:
    """Record accesses: hit/miss counts + 2Q reference/A1->Am graduation."""
    valid = page_ids >= 0
    slots = state.page_slot[jnp.where(valid, page_ids, 0)]
    hit = valid & (slots >= 0)
    n_slots = state.slot_page.shape[0]
    # misses scatter to an out-of-bounds index and are DROPPED — routing
    # them to index 0 would race with legitimate writes to slot 0.
    idx = jnp.where(hit, slots, n_slots)
    safe_slots = jnp.where(hit, slots, 0)
    upd = lambda arr, val: arr.at[idx].set(val, mode="drop")
    # re-referenced pages graduate to the active list (2Q A1 -> Am)
    new_active = upd(state.active, state.referenced[safe_slots] | state.active[safe_slots])
    new_ref = upd(state.referenced, jnp.ones_like(hit))
    new_lt = upd(state.last_touch, jnp.broadcast_to(state.step, hit.shape))
    return state._replace(
        active=new_active, referenced=new_ref, last_touch=new_lt,
        fast_reads=state.fast_reads + jnp.sum(hit, dtype=jnp.int32),
        slow_reads=state.slow_reads + jnp.sum(valid & ~hit, dtype=jnp.int32),
        step=state.step + 1,
    )


def _victim_rank(state: TierState) -> jax.Array:
    """2Q eviction preference as a sortable key (lower = evict first).

    Class order: free(0) < A1-unref(1) < A1-ref(2) < Am-unref(3) < Am-ref(4),
    i.e. occupied slots rank 1 + 2*active + referenced.
    """
    free = state.slot_page < 0
    klass = jnp.where(
        free, 0,
        1 + 2 * state.active.astype(jnp.int32)
        + state.referenced.astype(jnp.int32))
    # within a class, older last_touch evicts first (int32-safe packing:
    # class in the top bits, wrapped step counter below)
    return klass.astype(jnp.int32) * (1 << 24) + (state.last_touch & ((1 << 24) - 1))


@functools.partial(jax.jit, static_argnames=("k",))
def promote(
    state: TierState,
    hot_pages: jax.Array,   # (k,) int32, -1 padded — drained NeoProf buffer
    k: int,
) -> tuple[TierState, jax.Array, jax.Array]:
    """Promote up to k hot pages (quota already applied by the daemon).

    Returns (state, promoted_page_ids (k,), victim_slots (k,)): entry i says
    "copy slow[promoted[i]] into fast slot victim_slots[i]" (-1 = no-op), and
    the evicted page (if any) was written back.  Data movement is performed
    by the caller against its fast/slow buffers so this module stays
    data-layout agnostic.
    """
    hot_pages = hot_pages[:k]
    valid = hot_pages >= 0
    safe = jnp.where(valid, hot_pages, 0)
    # intra-batch dedup (duplicates can survive across sketch epochs)
    eq = (safe[:, None] == safe[None, :]) & valid[None, :]
    first = valid & ~jnp.any(eq & jnp.tril(jnp.ones((k, k), jnp.bool_), k=-1), axis=1)
    need = first & (state.page_slot[safe] < 0)     # not already resident

    # Rank-based 2Q victim selection: cheapest slots first.
    n_victims = min(k, state.slot_page.shape[0])
    rank = _victim_rank(state)
    _, victim_slots = jax.lax.top_k(-rank, n_victims)   # ascending rank
    # Assign the i-th needed page the i-th victim slot.
    order = jnp.cumsum(need.astype(jnp.int32)) - 1
    need = need & (order < n_victims)   # more hot pages than slots: defer
    slot_for = jnp.where(need, victim_slots[jnp.clip(order, 0, n_victims - 1)], -1)

    evicted_page = jnp.where(slot_for >= 0, state.slot_page[jnp.maximum(slot_for, 0)], -1)
    ev_valid = evicted_page >= 0
    n_pages = state.page_slot.shape[0]
    n_slots = state.slot_page.shape[0]
    # out-of-bounds + mode="drop" for all no-op lanes (index-0 routing would
    # race with legitimate writes to page/slot 0)
    ev_idx = jnp.where(ev_valid, evicted_page, n_pages)
    pg_idx = jnp.where(need, safe, n_pages)
    sl_idx = jnp.where(need, slot_for, n_slots)

    # Ping-pong: promoting a page whose PG_demoted flag is set.
    pp = jnp.sum(need & state.demoted[safe], dtype=jnp.int32)

    # demote victims
    page_slot = state.page_slot.at[ev_idx].set(-1, mode="drop")
    demoted = state.demoted.at[ev_idx].set(True, mode="drop")
    # install promotions (clear PG_demoted on promotion, per the kernel flag)
    page_slot = page_slot.at[pg_idx].set(slot_for, mode="drop")
    demoted = demoted.at[pg_idx].set(False, mode="drop")
    slot_page = state.slot_page.at[sl_idx].set(safe, mode="drop")
    active = state.active.at[sl_idx].set(False, mode="drop")   # enter A1in
    referenced = state.referenced.at[sl_idx].set(False, mode="drop")
    last_touch = state.last_touch.at[sl_idx].set(state.step, mode="drop")

    n_promoted = jnp.sum(need, dtype=jnp.int32)
    new_state = state._replace(
        page_slot=page_slot, slot_page=slot_page, active=active,
        referenced=referenced, last_touch=last_touch, demoted=demoted,
        promoted=state.promoted + n_promoted,
        demoted_cnt=state.demoted_cnt + jnp.sum(ev_valid, dtype=jnp.int32),
        ping_pong=state.ping_pong + pp,
    )
    return new_state, jnp.where(need, safe, -1), slot_for


@jax.jit
def migrate_data(
    fast: jax.Array, slow: jax.Array,
    promoted_pages: jax.Array, victim_slots: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Apply the data movement for a promotion batch (low-level helper).

    fast: (num_slots, *page_shape); slow: (num_pages, *page_shape).
    Victims are written back to the slow tier first, then hot pages are
    copied into their slots.  On real TPU ``slow`` carries a pinned_host
    memory-kind sharding; XLA emits the H2D/D2H copies.

    The full data plane — buffer placement, donation, demotion write-back
    targets, byte metering — lives in :mod:`repro.tiering.migrate`
    (DESIGN.md §8); prefer ``TieredMemory.bind_data`` + the daemon verbs.
    """
    ok = (promoted_pages >= 0) & (victim_slots >= 0)
    safe_page = jnp.maximum(promoted_pages, 0)
    safe_slot = jnp.maximum(victim_slots, 0)
    # Tiers are inclusive: ``slow`` is the full backing store, so read-mostly
    # victims need no write-back (dirty pages are written back by the adapter
    # that owns the data, e.g. the KV-tier flushes victim slots explicitly).
    gathered = slow[safe_page]
    mask = ok.reshape((-1,) + (1,) * (fast.ndim - 1))
    fast = fast.at[safe_slot].set(jnp.where(mask, gathered, fast[safe_slot]))
    return fast, slow


@jax.jit
def drain_period_stats(state: TierState) -> tuple[TierState, dict]:
    """Read & clear the per-period counters (daemon policy inputs)."""
    stats = {
        "promoted": state.promoted,
        "demoted": state.demoted_cnt,
        "ping_pong": state.ping_pong,
        "slow_reads": state.slow_reads,
        "fast_reads": state.fast_reads,
    }
    z = jnp.zeros((), jnp.int32)
    # 2Q aging: clear reference bits each period (CLOCK-style second chance).
    return state._replace(
        promoted=z, demoted_cnt=z, ping_pong=z, slow_reads=z, fast_reads=z,
        referenced=jnp.zeros_like(state.referenced),
    ), stats


def lookup(state: TierState, page_ids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(slot_or_minus1, hit_mask) for a batch of page ids."""
    valid = page_ids >= 0
    slots = jnp.where(valid, state.page_slot[jnp.where(valid, page_ids, 0)], -1)
    return slots, slots >= 0
