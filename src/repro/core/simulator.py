"""Two-tier memory simulator + workload generators (paper §VI evaluation).

Drives the *real* NeoMem components (JAX sketch / policy / TieredStore) and
the baseline profilers over page-access streams modeled on the paper's eight
benchmarks, and converts hit/miss/migration/overhead accounting into modeled
runtime via the measured tier characteristics of paper Fig. 3:

    fast tier  ~120 ns load-to-use,   slow tier ~430 ns  (3.6x),
    page migration at slow-tier bandwidth, profiling overhead per §II-C.

This is the engine behind benchmarks/fig11..fig16 — the CPU-runnable,
pure-algorithm reproduction of the paper's end-to-end results (repro band 5).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.core import tiering
from repro.core.baselines import BaselineCosts
from repro.core.neoprof import NeoProfParams
from repro.core.policy import PolicyParams
from repro.core.sketch import SketchParams
from repro.core.tiering import TierParams


@dataclasses.dataclass
class MemModel:
    """Tier timing model (paper Fig. 3 + Table II)."""

    fast_lat: float = 120e-9
    slow_lat: float = 430e-9
    page_bytes: int = 4096
    slow_bw: float = 12e9          # bytes/s (FPGA DDR4-2666 2ch, derated)
    line_bytes: int = 64

    def access_time(self, fast_hits: int, slow_hits: int) -> float:
        return fast_hits * self.fast_lat + slow_hits * self.slow_lat

    def migration_time(self, pages: int) -> float:
        return pages * self.page_bytes / self.slow_bw


# ---------------------------------------------------------------------------
# Workload stream generators — page-id streams mirroring the paper's suite
# ---------------------------------------------------------------------------

def _zipf_pages(rng, n_pages, s, size):
    # bounded zipf via inverse-CDF on precomputed weights (cheap for n<=1M)
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    w = ranks ** (-s)
    cdf = np.cumsum(w) / np.sum(w)
    u = rng.random(size)
    pages = np.searchsorted(cdf, u)
    perm = rng.permutation(n_pages)  # decorrelate rank from address
    return perm[pages]


def gups(n_pages: int, block: int, n_blocks: int, seed: int = 0,
         hot_frac: float = 0.1, hot_prob: float = 0.9,
         shift_at: int | None = None) -> Iterator[np.ndarray]:
    """HeMem-style skewed GUPS: hot_prob of traffic to a hot_frac region.

    ``shift_at`` relocates the hot set mid-stream (Fig. 16 convergence)."""
    rng = np.random.default_rng(seed)
    hot_n = max(1, int(n_pages * hot_frac))
    # hot region sits at the END of the address space: the init sweep has
    # already first-touch-filled the fast tier with low (cold) pages, so the
    # hot set starts slow-resident — the tiering system must earn its keep.
    hot_base = n_pages - hot_n
    for b in range(n_blocks):
        if shift_at is not None and b == shift_at:
            hot_base = (hot_base + n_pages // 2) % (n_pages - hot_n)
        is_hot = rng.random(block) < hot_prob
        hot = hot_base + rng.integers(0, hot_n, block)
        uni = rng.integers(0, n_pages, block)
        yield np.where(is_hot, hot, uni).astype(np.int64)


def xsbench(n_pages, block, n_blocks, seed=0):
    """MC neutronics macro-XS lookups: very skewed (paper: 'skewed hot regions')."""
    rng = np.random.default_rng(seed)
    for _ in range(n_blocks):
        yield _zipf_pages(rng, n_pages, 1.2, block).astype(np.int64)


def silo_ycsb(n_pages, block, n_blocks, seed=0):
    """YCSB-C zipf(0.99) point lookups."""
    rng = np.random.default_rng(seed)
    for _ in range(n_blocks):
        yield _zipf_pages(rng, n_pages, 0.99, block).astype(np.int64)


def btree(n_pages, block, n_blocks, seed=0):
    """Index lookups: tiny ultra-hot index levels + zipf leaves."""
    rng = np.random.default_rng(seed)
    idx_n = max(1, n_pages // 100)
    for _ in range(n_blocks):
        to_idx = rng.random(block) < 0.7
        idx = rng.integers(0, idx_n, block)
        leaf = idx_n + _zipf_pages(rng, n_pages - idx_n, 0.8, block)
        yield np.where(to_idx, idx, leaf).astype(np.int64)


def pagerank(n_pages, block, n_blocks, seed=0, n_iters: int = 16):
    """Graph iterations: power-law-hot vertices + per-iteration edge sweep.

    Phase structure (hot set intensity varies by iteration) drives the
    Fig. 14 dynamic-threshold study."""
    rng = np.random.default_rng(seed)
    per_iter = max(1, n_blocks // n_iters)
    for b in range(n_blocks):
        it = b // per_iter
        sweep_frac = 0.5 if it % 4 == 0 else 0.25   # phase change
        n_sweep = int(block * sweep_frac)
        sweep = (np.arange(n_sweep, dtype=np.int64) * 7 + b * block) % n_pages
        hot = _zipf_pages(rng, n_pages, 1.05, block - n_sweep)
        yield np.concatenate([sweep, hot]).astype(np.int64)


def deathstar(n_pages, block, n_blocks, seed=0):
    """Microservice mix: zipf(0.9) with slow working-set drift."""
    rng = np.random.default_rng(seed)
    for b in range(n_blocks):
        drift = (b * 17) % n_pages
        yield ((_zipf_pages(rng, n_pages, 0.9, block) + drift) % n_pages).astype(np.int64)


def stream_stencil(n_pages, block, n_blocks, seed=0):
    """bwaves/roms-like: dominant sequential sweep + small resident hot set."""
    rng = np.random.default_rng(seed)
    hot_n = max(1, n_pages // 50)
    pos = 0
    for _ in range(n_blocks):
        n_seq = int(block * 0.8)
        seq = (pos + np.arange(n_seq, dtype=np.int64)) % n_pages
        pos = (pos + n_seq) % n_pages
        hot = rng.integers(0, hot_n, block - n_seq)
        yield np.concatenate([seq, hot]).astype(np.int64)


WORKLOADS = {
    "deathstar": deathstar,
    "pagerank": pagerank,
    "xsbench": xsbench,
    "gups": gups,
    "silo": silo_ycsb,
    "btree": btree,
    "bwaves": stream_stencil,
    "roms": lambda *a, **k: stream_stencil(*a, **{**k, "seed": k.get("seed", 0) + 1}),
}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    name: str
    runtime: float                 # modeled seconds
    access_time: float
    migration_time: float
    overhead_time: float
    fast_hits: int
    slow_hits: int
    promoted: int
    ping_pong: int
    trace: list = dataclasses.field(default_factory=list)  # per-block dicts

    @property
    def hit_rate(self) -> float:
        t = self.fast_hits + self.slow_hits
        return self.fast_hits / max(t, 1)


def _first_touch_alloc(first_seen, free_slots, pages, tier):
    """Uniform first-touch allocation: new pages land in fast while it has room."""
    new = pages[~first_seen[pages]]
    if len(new) == 0 or free_slots <= 0:
        return tier, free_slots, np.empty((0,), np.int64)
    new = new[: free_slots]
    uniq = np.unique(new)
    first_seen[uniq] = True
    k = len(uniq)
    batch = np.asarray(uniq, np.int32)
    tier, promoted, victims = tiering.promote(tier, jnp.asarray(batch), k)
    return tier, free_slots - int(np.sum(np.asarray(promoted) >= 0)), uniq


def run_sim(
    method: str,
    stream: Iterator[np.ndarray],
    n_pages: int,
    fast_ratio: float = 1 / 3,           # fast:(fast+slow); 1:2 -> 1/3
    mem: MemModel | None = None,
    sketch_width: int = 1 << 14,
    sketch_depth: int = 2,
    quota_pages: int = 256,
    migration_interval: int = 1,
    threshold_update_period: int = 8,
    clear_interval: int = 64,
    fixed_theta: int | None = None,
    costs: BaselineCosts | None = None,
    epoch_blocks: int = 8,               # baseline scan epoch, in blocks
    collect_trace: bool = False,
    init_sweep: bool = True,             # sequential allocation pre-phase
) -> SimResult:
    """Run one (method x workload) cell and return modeled accounting.

    methods: neomem | neomem-fixed | pte-scan | pebs | autonuma | tpp |
             first-touch

    Every method drives the shared :class:`repro.tiering.TieredMemory` verbs
    (enqueue / migrate / drain), so quota, pending-queue, and stats
    arithmetic is the same code the serving daemon runs; the neomem methods
    additionally use the profile / collect / update-threshold verbs.
    """
    # Imported lazily: repro.core's package init imports this module, while
    # repro.tiering imports repro.core submodules.
    from repro.tiering.memory import DaemonParams, TieredMemory
    from repro.tiering.stats import TierStats, drain_tier_stats

    mem = mem or MemModel()
    costs = costs or BaselineCosts()
    num_slots = max(1, int(n_pages * fast_ratio))
    is_neomem = method.startswith("neomem")

    tmem = TieredMemory(
        NeoProfParams(sketch=SketchParams(width=sketch_width, depth=sketch_depth)),
        TierParams(n_pages, num_slots, quota_pages),
        daemon_params=DaemonParams(
            migration_interval=migration_interval,
            threshold_update_period=threshold_update_period,
            clear_interval=clear_interval, quota_pages=quota_pages),
        # policy quota bound: 4x the migration CAPACITY (paper's 256MB/s is
        # ~100x its typical demand; equal-to-capacity degenerates into a
        # starve/flood oscillation of p)
        policy_params=PolicyParams(
            m_quota_pages=4 * quota_pages * threshold_update_period),
        fixed_theta=fixed_theta)
    state = tmem.init()
    stats = TierStats(name=method)
    first_seen = np.zeros(n_pages, bool)
    free_slots = num_slots

    baseline = None
    if not is_neomem:
        from repro.core import baselines as B
        mk = {
            "first-touch": B.FirstTouch,
            "pte-scan": B.PteScan,
            "pebs": B.PebsSampler,
            "autonuma": lambda n, s, **kw: B.HintFault(n, s, promote_after=1, **kw),
            "tpp": lambda n, s, **kw: B.HintFault(n, s, promote_after=2, **kw),
        }[method]
        baseline = mk(n_pages, num_slots, costs=costs)

    res = SimResult(method, 0.0, 0.0, 0.0, 0.0, 0, 0, 0, 0)

    if init_sweep:
        # Application init: sequentially touch every page once (e.g. array
        # initialization).  First-touch allocation fills the fast tier with
        # the LOW pages — for every method alike, as on a real kernel.
        for lo in range(0, n_pages, 1 << 14):
            blk = np.arange(lo, min(lo + (1 << 14), n_pages), dtype=np.int64)
            tier, free_slots, _ = _first_touch_alloc(
                first_seen, free_slots, blk, state.tier)
            state = tmem.touch(state._replace(tier=tier),
                               jnp.asarray(blk, jnp.int32))
        # init accesses count toward runtime (via the final access_time
        # recomputation) but not toward promotion/ping-pong stats
        init_stats = TierStats()
        state = state._replace(tier=drain_tier_stats(state.tier, init_stats))
        stats.fast_reads += init_stats.fast_reads
        stats.slow_reads += init_stats.slow_reads

    for b, pages in enumerate(stream):
        # --- allocation (uniform across methods) ---------------------------
        tier, free_slots, _ = _first_touch_alloc(
            first_seen, free_slots, pages, state.tier)
        state = state._replace(tier=tier)

        # --- profiling ------------------------------------------------------
        if is_neomem:
            # NeoProf sits in the SLOW tier's controller: it only ever sees
            # accesses that miss the fast tier (paper Fig. 2).  Promoted
            # pages vanish from its stream, so the counter quantile
            # continuously re-targets the hottest still-slow pages.
            page_slot = np.asarray(state.tier.page_slot)
            slow_pages = pages[page_slot[pages] < 0]
            blk = np.full(len(pages), -1, np.int64)
            blk[: len(slow_pages)] = slow_pages
            state = tmem.profile(
                state, jnp.asarray(blk, jnp.int32),
                rd_bytes=float(len(slow_pages) * mem.line_bytes),
                budget_bytes=float(len(pages) * mem.line_bytes) * 2.0,
            )
            if (b + 1) % migration_interval == 0:
                state, _ = tmem.collect(state, stats)
                res.overhead_time += costs.neoprof_readout
        else:
            hot = baseline.observe(pages)
            if (b + 1) % epoch_blocks == 0:
                hot = np.union1d(hot, baseline.epoch_end())
            if method != "first-touch":
                tmem.enqueue(hot)

        # --- migration (quota-bounded; overflow stays queued) ---------------
        if method != "first-touch":
            state, event = tmem.migrate(state, stats)
            if event is not None:
                res.migration_time += mem.migration_time(event.n_promoted)

        # --- access accounting ----------------------------------------------
        state = tmem.touch(state, jnp.asarray(pages, jnp.int32))

        # --- NeoMem policy cadence -------------------------------------------
        if (b + 1) % threshold_update_period == 0:
            if is_neomem:
                state = tmem.update_threshold(state, stats)
                if collect_trace:
                    res.trace.append({
                        "block": b, "theta": stats.theta_trace[-1],
                        "bw": stats.bw_trace[-1], "err": stats.err_trace[-1],
                        "hit_rate": stats.drained_hit_rate,
                    })
            else:
                state = tmem.drain(state, stats)
                if collect_trace:
                    res.trace.append({"block": b,
                                      "hit_rate": stats.drained_hit_rate})

        if is_neomem and (b + 1) % clear_interval == 0:
            state = tmem.clear(state)

    # flush remaining period stats
    state = tmem.drain(state, stats)
    if baseline is not None:
        res.overhead_time += baseline.overhead

    res.fast_hits = stats.fast_reads
    res.slow_hits = stats.slow_reads
    res.promoted = stats.promoted
    res.ping_pong = stats.ping_pong
    res.access_time = mem.access_time(res.fast_hits, res.slow_hits)
    res.runtime = res.access_time + res.migration_time + res.overhead_time
    return res


def geomean_speedup(base: list[float], ours: list[float]) -> float:
    r = np.asarray(base) / np.asarray(ours)
    return float(np.exp(np.mean(np.log(r))))
