"""Profiling/tiering baselines from the paper's evaluation (§VI-A).

Each baseline sees the *same* physical access stream as NeoMem but through
its own (limited) profiling lens, and drives the same TieredStore.  The
limitations are modeled exactly as the paper analyzes them (§II-C):

  * first-touch  — allocate-to-fast-until-full, never migrate (paper's
                   First-touch NUMA).
  * pte-scan     — epoch-granular *binary* access bits (one access per page
                   per epoch max — low time resolution), scans cost CPU time
                   proportional to the page count; TLB-level visibility is
                   modeled by collapsing repeat accesses within an epoch.
  * hint-fault   — Bernoulli page-sampled instant notifications (AutoNUMA:
                   promote after 1 fault; TPP: after 2 with hysteresis),
                   per-fault overhead (TLB shootdown + fault).
  * pebs         — Bernoulli *access*-sampled LLC-miss records with
                   per-sample overhead; promote after k sampled hits.

All baselines are intentionally host-side Python/numpy: that is the point —
they burn "CPU" in the cost model, while NeoMem's profiling is on-device.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BaselineCosts:
    """Per-event profiling overheads (seconds) for the cost model.

    Defaults are calibrated to the paper's measurements: a PTE scan of a
    ~4M-page table takes ~1 s (§II-C "several seconds" for large systems);
    a hint fault (TLB shootdown + protection fault) ~2.5 us [5], [60]; a
    PEBS sample ~0.2 us amortized (Fig. 4-(c): 10-interval sampling >50%
    slowdown); NeoProf readout ~0 (0.021% measured, §VI-D).
    """

    pte_scan_per_page: float = 250e-9
    hint_fault: float = 2.5e-6
    pebs_sample: float = 0.2e-6
    neoprof_readout: float = 2e-6    # per migration interval: drain <=quota
                                     # addresses over MMIO (~1KB, amortized)


class FirstTouch:
    """No profiling, no migration."""

    name = "first-touch"

    def __init__(self, num_pages: int, num_slots: int, **_):
        self.overhead = 0.0

    def observe(self, pages: np.ndarray) -> np.ndarray:
        return np.empty((0,), np.int64)  # never promotes

    def epoch_end(self) -> None:
        pass


class PteScan:
    """Epoch access-bit scanning (DAMON/AMP-style, paper Obs. #1)."""

    name = "pte-scan"

    def __init__(self, num_pages: int, num_slots: int,
                 costs: BaselineCosts | None = None,
                 hot_after_epochs: int = 2, **_):
        self.num_pages = num_pages
        self.costs = costs or BaselineCosts()
        self.hot_after = hot_after_epochs
        self.access_bit = np.zeros(num_pages, bool)
        self.epoch_hits = np.zeros(num_pages, np.int8)
        self.overhead = 0.0

    def observe(self, pages: np.ndarray) -> np.ndarray:
        # TLB-level visibility: only the access *bit* is set, frequency lost.
        self.access_bit[pages] = True
        return np.empty((0,), np.int64)

    def epoch_end(self) -> np.ndarray:
        """Scan + clear; promote pages hot in >= hot_after consecutive epochs."""
        self.overhead += self.costs.pte_scan_per_page * self.num_pages
        self.epoch_hits = np.where(self.access_bit, self.epoch_hits + 1, 0).astype(np.int8)
        self.access_bit[:] = False
        return np.nonzero(self.epoch_hits >= self.hot_after)[0]


class HintFault:
    """Poisoned-PTE fault monitoring (AutoNUMA k=1 / TPP k=2, Obs. #2)."""

    def __init__(self, num_pages: int, num_slots: int,
                 costs: BaselineCosts | None = None,
                 sample_frac: float = 0.05, promote_after: int = 1,
                 seed: int = 0, **_):
        self.name = "autonuma" if promote_after == 1 else "tpp"
        self.costs = costs or BaselineCosts()
        self.num_pages = num_pages
        self.sample_frac = sample_frac
        self.promote_after = promote_after
        self.rng = np.random.default_rng(seed)
        self.poisoned = np.zeros(num_pages, bool)
        self.faults = np.zeros(num_pages, np.int16)
        self._repoison()
        self.overhead = 0.0

    def _repoison(self):
        self.poisoned[:] = False
        n = max(1, int(self.num_pages * self.sample_frac))
        self.poisoned[self.rng.choice(self.num_pages, n, replace=False)] = True

    def observe(self, pages: np.ndarray) -> np.ndarray:
        # A fault fires on the FIRST touch of a poisoned page; the poison is
        # then cleared (the fault handler unpoisons to make progress).
        faulted = np.unique(pages[self.poisoned[pages]])
        self.overhead += self.costs.hint_fault * len(faulted)
        self.poisoned[faulted] = False
        self.faults[faulted] += 1
        hot = faulted[self.faults[faulted] >= self.promote_after]
        self.faults[hot] = 0
        return hot

    def epoch_end(self) -> np.ndarray:
        self._repoison()
        return np.empty((0,), np.int64)


class PebsSampler:
    """PMU LLC-miss sampling (Obs. #3): rate-limited, per-sample overhead."""

    name = "pebs"

    def __init__(self, num_pages: int, num_slots: int,
                 costs: BaselineCosts | None = None,
                 sample_interval: int = 1000, promote_after: int = 2,
                 seed: int = 0, **_):
        self.costs = costs or BaselineCosts()
        self.interval = sample_interval
        self.promote_after = promote_after
        self.rng = np.random.default_rng(seed)
        self.counts = np.zeros(num_pages, np.int32)
        self.overhead = 0.0

    def observe(self, pages: np.ndarray) -> np.ndarray:
        take = self.rng.random(len(pages)) < (1.0 / self.interval)
        sampled = pages[take]
        self.overhead += self.costs.pebs_sample * len(sampled)
        np.add.at(self.counts, sampled, 1)
        hot = np.unique(sampled[self.counts[sampled] >= self.promote_after])
        self.counts[hot] = 0
        return hot

    def epoch_end(self) -> np.ndarray:
        self.counts[:] = 0
        return np.empty((0,), np.int64)
