"""NeoMem core: sketch-based device-side profiling + tiered memory management.

Public API:
  SketchParams/SketchState + sketch_* .... Count-Min hot-page detector
  NeoProfParams/NeoProfState/Commands .... the device-side profiler unit
  PolicyParams/PolicyState/update_threshold ... Algorithm 1
  TierParams/TierState + promote/touch ... two-tier page placement
  NeoMemDaemon ........................... orchestration cadences (legacy shim)
  run_sim/WORKLOADS ...................... paper-evaluation simulator

The unified tiering surface (TieredResource / TieredMemory / the multiplexed
daemon / TierStats) lives in :mod:`repro.tiering`; the most-used names are
re-exported here for convenience.
"""
from repro.core.sketch import (  # noqa: F401
    SketchParams, SketchState, sketch_init, sketch_update, sketch_query,
    sketch_clear, sketch_histogram, error_bound_from_hist, quantile_from_hist,
    h3_hash, make_seeds,
)
from repro.core.neoprof import (  # noqa: F401
    NeoProfParams, NeoProfState, NeoProfCommands, neoprof_init, neoprof_observe,
)
from repro.core.policy import (  # noqa: F401
    PolicyParams, PolicyState, StaticPolicy, update_threshold,
)
from repro.core.tiering import (  # noqa: F401
    TierParams, TierState, tier_init, touch, promote, migrate_data,
    drain_period_stats, lookup,
)
from repro.core.daemon import DaemonParams, NeoMemDaemon  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    MemModel, SimResult, WORKLOADS, run_sim, geomean_speedup,
)
_TIERING_EXPORTS = (
    "ResourceSpec", "TierStats", "TieredMemory", "TieredMemoryState",
    "TieredResource", "make_resource", "register_resource", "resource_kinds",
)


def __getattr__(name: str):
    # Lazy so that ``import repro.tiering`` (whose modules import repro.core
    # submodules) doesn't recurse into a partially-initialized package.
    if name in _TIERING_EXPORTS:
        import repro.tiering as _tm
        return getattr(_tm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
