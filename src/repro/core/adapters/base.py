"""Shared base for the legacy adapter shims (KVTier / ExpertCache / EmbedCache).

Each legacy adapter is now a thin view over ONE resource registered on a
:class:`repro.tiering.NeoMemDaemon`: the stream encoding lives in
:mod:`repro.tiering.resources`, the state is the :class:`TieredMemoryState`
pytree, and all hit-rate / policy arithmetic goes through the unified
:class:`repro.tiering.TierStats` path.  The ``.prof`` / ``.tier`` /
``.daemon`` attributes the seed tests poke at are preserved as properties.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro import tiering as tm
from repro.tiering.stats import LegacyDaemonStateView


def warn_deprecated(old: str, new: str) -> None:
    """One-liner the shims emit at construction (README: migration path)."""
    warnings.warn(
        f"{old} is a deprecation shim; register a {new} on the multiplexed "
        f"repro.tiering.NeoMemDaemon instead (see README.md 'Migrating off "
        f"the legacy adapters' and DESIGN.md §1).",
        DeprecationWarning, stacklevel=3)


class _DaemonView:
    """Legacy per-adapter ``daemon`` attribute (cmd / policy / state / tp)."""

    def __init__(self, handle: tm.ResourceHandle):
        self._h = handle
        self.cmd = handle.mem.cmd

    tp = property(lambda self: self._h.mem.tp)
    pp = property(lambda self: self._h.mem.pp)
    dp = property(lambda self: self._h.mem.dp)
    pol_params = property(lambda self: self._h.mem.pol_params)

    @property
    def policy(self):
        return self._h.mem.policy_state(self._h.state, self._h.stats)

    @property
    def state(self) -> LegacyDaemonStateView:
        return LegacyDaemonStateView(self._h.stats)


class LegacyTierAdapter:
    """prof/tier threading + daemon facade shared by the three shims."""

    def __init__(self, resource, daemon_params: tm.DaemonParams | None = None):
        self._daemon = tm.NeoMemDaemon(daemon_params or tm.DaemonParams())
        self._h = self._daemon.register(resource)
        self.daemon = _DaemonView(self._h)

    @property
    def spec(self) -> tm.ResourceSpec:
        return self._h.resource.spec

    @property
    def handle(self) -> tm.ResourceHandle:
        return self._h

    # legacy mutable-attribute surface -------------------------------------
    @property
    def prof(self):
        return self._h.state.prof

    @prof.setter
    def prof(self, value):
        self._h.state = self._h.state._replace(prof=value)

    @property
    def tier(self):
        return self._h.state.tier

    @tier.setter
    def tier(self, value):
        self._h.state = self._h.state._replace(tier=value)

    def tick(self) -> None:
        self._daemon.tick()

    def hit_rate(self) -> float:
        return self._h.hit_rate()

    # migration data plane — forwarded to the unified layer (DESIGN.md §8)
    def bind_data(self, slow_data) -> None:
        """Attach payload; promotions then move real bytes (metered)."""
        self._h.bind_data(slow_data)

    def read_rows(self, page_ids):
        """Serve payload rows: fast-buffer hit, slow-tier fallback."""
        return self._h.read_rows(page_ids)

    @property
    def migration_bytes(self) -> int:
        return self._h.stats.migration_bytes

    def residency(self) -> np.ndarray:
        """page -> fast-slot (-1 if slow-tier / host-resident)."""
        return np.asarray(self.tier.page_slot)
