"""Embedding-row tiering — NeoMem applied to vocab tables (§3.3).

The access stream is simply the token-id stream (the model's own input!);
pages are row-blocks of ROWS_PER_PAGE vocabulary rows.  For 256K-row tables
(gemma2) the hot tail fits comfortably in a small HBM-resident cache while
the cold mass lives host-side.  This is also the NeoMem surface for
attention-free archs (xlstm) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.daemon import DaemonParams, NeoMemDaemon
from repro.core.neoprof import NeoProfParams, neoprof_init, neoprof_observe
from repro.core.sketch import SketchParams
from repro.core.tiering import TierParams, tier_init
from repro.core import tiering

ROWS_PER_PAGE = 64


@dataclasses.dataclass
class EmbedTierConfig:
    vocab: int
    hot_slots: int
    rows_per_page: int = ROWS_PER_PAGE
    quota_pages: int = 64
    sketch_width: int = 1 << 14


class EmbedCache:
    def __init__(self, cfg: EmbedTierConfig, migrate_fn=None):
        self.cfg = cfg
        n_pages = (cfg.vocab + cfg.rows_per_page - 1) // cfg.rows_per_page
        self.prof_params = NeoProfParams(sketch=SketchParams(width=cfg.sketch_width))
        self.prof = neoprof_init(self.prof_params)
        tp = TierParams(n_pages, cfg.hot_slots, cfg.quota_pages)
        self.tier = tier_init(tp)
        self.daemon = NeoMemDaemon(self.prof_params, tp,
                                   DaemonParams(quota_pages=cfg.quota_pages),
                                   migrate_fn=migrate_fn)

    def observe_tokens(self, tokens: jax.Array) -> None:
        pages = (tokens.reshape(-1) // self.cfg.rows_per_page).astype(jnp.int32)
        if pages.shape[0] > 1 << 14:
            stride = pages.shape[0] // (1 << 14)
            pages = pages[::stride][: 1 << 14]
        self.prof = neoprof_observe(self.prof, pages, self.prof_params)
        self.tier = tiering.touch(self.tier, pages[: 4096])

    def tick(self):
        self.prof, self.tier = self.daemon.tick(self.prof, self.tier)

    def hit_rate(self) -> float:
        f = float(self.tier.fast_reads) + self.daemon.state.total_fast
        s = float(self.tier.slow_reads) + self.daemon.state.total_slow
        return f / max(f + s, 1.0)
