"""Embedding-row tiering shim — NeoMem applied to vocab tables (§3.3).

Deprecation shim over :class:`repro.tiering.EmbedRowsResource`: the access
stream is simply the token-id stream (the model's own input!); pages are
row-blocks of ``rows_per_page`` vocabulary rows.  This is also the NeoMem
surface for attention-free archs (xlstm) — see DESIGN.md §5.  New code
should register an ``"embeddings"`` resource on a shared daemon instead.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import tiering as tm
from repro.core.adapters.base import LegacyTierAdapter

ROWS_PER_PAGE = tm.EMBED_ROWS_PER_PAGE


@dataclasses.dataclass
class EmbedTierConfig:
    vocab: int
    hot_slots: int
    rows_per_page: int = ROWS_PER_PAGE
    quota_pages: int = 64
    sketch_width: int = 1 << 14


class EmbedCache(LegacyTierAdapter):
    def __init__(self, cfg: EmbedTierConfig, migrate_fn=None):
        from repro.core.adapters.base import warn_deprecated
        warn_deprecated("core.adapters.EmbedCache",
                        '"embeddings" TieredResource')
        self.cfg = cfg
        n_pages = (cfg.vocab + cfg.rows_per_page - 1) // cfg.rows_per_page
        spec = tm.ResourceSpec(
            name="embeddings", n_pages=n_pages, hot_slots=cfg.hot_slots,
            quota_pages=cfg.quota_pages, sketch_width=cfg.sketch_width)
        super().__init__(tm.EmbedRowsResource(
            spec, rows_per_page=cfg.rows_per_page, migrate_fn=migrate_fn))

    def observe_tokens(self, tokens: jax.Array) -> None:
        self._h.observe(jnp.asarray(tokens))
