"""Paged-KV tiering — NeoMem applied to long-context KV caches (§3.2).

The access stream is the set of page ids whose content contributed non-
trivial attention mass at each decode step (the analogue of LLC misses to
CXL memory: pages the model actually pulled from).  Between steps the daemon
promotes sketch-hot pages from the host-resident full history into the
fast-tier page slots that decode attends over (models.decode paged cache).

Scoring stream construction: we feed NeoProf the pages ranked by their
attention mass quantile — computed device-side from the paged kernel's
per-page softmax denominators — so a page's "access count" is the number of
steps it mattered.  This keeps the exact NeoMem machinery (sketch, hot
buffer, threshold policy) unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.daemon import DaemonParams, NeoMemDaemon
from repro.core.neoprof import NeoProfParams, neoprof_init, neoprof_observe
from repro.core.sketch import SketchParams
from repro.core.tiering import TierParams, tier_init
from repro.core import tiering


@dataclasses.dataclass
class KVTierConfig:
    n_pages_total: int           # full history pages (slow tier)
    hot_slots: int               # fast-tier page slots (per layer group)
    quota_pages: int = 64
    sketch_width: int = 1 << 14
    mass_threshold: float = 0.02  # page matters if it carries >=2% softmax mass


class KVTier:
    def __init__(self, cfg: KVTierConfig, migrate_fn=None):
        self.cfg = cfg
        self.prof_params = NeoProfParams(sketch=SketchParams(width=cfg.sketch_width))
        self.prof = neoprof_init(self.prof_params)
        tp = TierParams(cfg.n_pages_total, cfg.hot_slots, cfg.quota_pages)
        self.tier = tier_init(tp)
        self.daemon = NeoMemDaemon(self.prof_params, tp,
                                   DaemonParams(quota_pages=cfg.quota_pages),
                                   migrate_fn=migrate_fn)

    @staticmethod
    def important_pages(page_mass: jax.Array, page_ids: jax.Array,
                        threshold: float) -> jax.Array:
        """page_mass: (P,) per-page softmax mass; -> page-id stream (P,)
        with unimportant pages masked to -1 (NeoProf padding)."""
        total = jnp.maximum(jnp.sum(page_mass), 1e-30)
        keep = page_mass / total >= threshold
        return jnp.where(keep, page_ids, -1)

    def observe_step(self, page_mass: np.ndarray | jax.Array,
                     page_ids: np.ndarray | jax.Array) -> None:
        stream = self.important_pages(jnp.asarray(page_mass),
                                      jnp.asarray(page_ids, jnp.int32),
                                      self.cfg.mass_threshold)
        self.prof = neoprof_observe(self.prof, stream, self.prof_params)
        self.tier = tiering.touch(self.tier, stream)

    def tick(self):
        self.prof, self.tier = self.daemon.tick(self.prof, self.tier)

    def resident_pages(self) -> np.ndarray:
        sp = np.asarray(self.tier.slot_page)
        return sp[sp >= 0]

    def hit_rate(self) -> float:
        f = float(self.tier.fast_reads) + self.daemon.state.total_fast
        s = float(self.tier.slow_reads) + self.daemon.state.total_slow
        return f / max(f + s, 1.0)
