"""Paged-KV tiering shim — NeoMem applied to long-context KV caches (§3.2).

Deprecation shim: the stream encoding now lives in
:class:`repro.tiering.KVPagesResource` (pages ranked by their attention
softmax-mass quantile — see DESIGN.md §3.2) and the orchestration in the
multiplexed :class:`repro.tiering.NeoMemDaemon`.  Only the construction
path (config + DeprecationWarning + base adapter surface) survives; the
``important_pages`` / ``observe_step`` / ``resident_pages`` forwarders had
no remaining callers and are gone.  New code should register a ``"kv"``
resource on a shared daemon instead.
"""
from __future__ import annotations

import dataclasses

from repro import tiering as tm
from repro.core.adapters.base import LegacyTierAdapter


@dataclasses.dataclass
class KVTierConfig:
    n_pages_total: int           # full history pages (slow tier)
    hot_slots: int               # fast-tier page slots (per layer group)
    quota_pages: int = 64
    sketch_width: int = 1 << 14
    mass_threshold: float = 0.02  # page matters if it carries >=2% softmax mass


class KVTier(LegacyTierAdapter):
    def __init__(self, cfg: KVTierConfig, migrate_fn=None):
        from repro.core.adapters.base import warn_deprecated
        warn_deprecated("core.adapters.KVTier", '"kv" TieredResource')
        self.cfg = cfg
        spec = tm.ResourceSpec(
            name="kv", n_pages=cfg.n_pages_total, hot_slots=cfg.hot_slots,
            quota_pages=cfg.quota_pages, sketch_width=cfg.sketch_width,
            touch_cap=1 << 14)
        super().__init__(tm.KVPagesResource(
            spec, mass_threshold=cfg.mass_threshold, migrate_fn=migrate_fn))
        self.prof_params = spec.prof_params()
