"""MoE expert tiering — NeoMem applied to expert weights (DESIGN.md §3.1).

The access stream is the router's token->expert assignments (already
surfaced by models.moe as ``idx``).  A *page* is one expert's weight block
for one layer group: page_id = group * n_experts + expert.

Serving integration: the fast tier holds H hot experts' weights HBM-resident
per device; cold experts live in host memory (``pinned_host`` sharding on
real TPU — see host_offload.py).  On each migration interval the daemon
promotes the sketch-detected hot experts under quota; the serve step gathers
resident experts from the fast buffer and takes the slow path (host DMA,
modeled on CPU) for cold hits.

This adapter owns the mapping and the data movement callback; the policy
loop is the unmodified paper Algorithm 1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.daemon import DaemonParams, NeoMemDaemon
from repro.core.neoprof import NeoProfParams, neoprof_init, neoprof_observe
from repro.core.sketch import SketchParams
from repro.core.tiering import TierParams, tier_init
from repro.core import tiering


@dataclasses.dataclass
class ExpertTierConfig:
    n_groups: int
    n_experts: int
    hot_slots: int               # experts resident in HBM per layer group
    quota_pages: int = 32        # expert promotions per migration interval
    sketch_width: int = 1 << 14


class ExpertCache:
    """Host-side manager wiring NeoProf <-> TieredStore for expert weights."""

    def __init__(self, cfg: ExpertTierConfig, migrate_fn=None):
        self.cfg = cfg
        n_pages = cfg.n_groups * cfg.n_experts
        self.prof_params = NeoProfParams(
            sketch=SketchParams(width=cfg.sketch_width))
        self.prof = neoprof_init(self.prof_params)
        self.tier = tier_init(TierParams(
            num_pages=n_pages, num_slots=cfg.n_groups * cfg.hot_slots,
            quota_pages=cfg.quota_pages))
        self.daemon = NeoMemDaemon(
            self.prof_params,
            TierParams(n_pages, cfg.n_groups * cfg.hot_slots, cfg.quota_pages),
            DaemonParams(quota_pages=cfg.quota_pages),
            migrate_fn=migrate_fn)

    def page_ids(self, router_idx: jax.Array, group_ids: jax.Array) -> jax.Array:
        """(..., k) expert indices + per-row group ids -> flat page stream."""
        return (group_ids[..., None] * self.cfg.n_experts + router_idx).reshape(-1)

    def observe_step(self, router_streams: jax.Array) -> None:
        """router_streams: (G, n_moe, B, S, k) from the forward pass."""
        g = router_streams.shape[0]
        group_ids = jnp.arange(g, dtype=jnp.int32).reshape(
            (g,) + (1,) * (router_streams.ndim - 1))
        pages = (group_ids * self.cfg.n_experts
                 + router_streams.astype(jnp.int32)).reshape(-1)
        # cap the per-step stream (NeoProf snoops at line rate; we subsample
        # deterministically when the stream exceeds the block size)
        if pages.shape[0] > 1 << 14:
            stride = pages.shape[0] // (1 << 14)
            pages = pages[::stride][: 1 << 14]
        self.prof = neoprof_observe(self.prof, pages, self.prof_params)
        self.tier = tiering.touch(self.tier, pages[: 4096])

    def tick(self) -> None:
        self.prof, self.tier = self.daemon.tick(self.prof, self.tier)

    def residency(self) -> np.ndarray:
        """page -> fast-slot (-1 if host-resident)."""
        return np.asarray(self.tier.page_slot)

    def hit_rate(self) -> float:
        f = float(self.tier.fast_reads) + self.daemon.state.total_fast
        s = float(self.tier.slow_reads) + self.daemon.state.total_slow
        return f / max(f + s, 1.0)
