"""MoE expert tiering shim — NeoMem applied to expert weights (DESIGN.md §3.1).

Deprecation shim over :class:`repro.tiering.ExpertStreamResource`: the access
stream is the router's token->expert assignments; a *page* is one expert's
weight block for one layer group (page_id = group * n_experts + expert).
One :class:`~repro.tiering.ResourceSpec` sources BOTH the tier geometry and
the daemon quota (the old class constructed two separate ``TierParams``,
which could silently diverge).  New code should register an ``"experts"``
resource on a shared multiplexed daemon instead.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import tiering as tm
from repro.core.adapters.base import LegacyTierAdapter


@dataclasses.dataclass
class ExpertTierConfig:
    n_groups: int
    n_experts: int
    hot_slots: int               # experts resident in HBM per layer group
    quota_pages: int = 32        # expert promotions per migration interval
    sketch_width: int = 1 << 14


class ExpertCache(LegacyTierAdapter):
    """Host-side manager wiring NeoProf <-> TieredStore for expert weights."""

    def __init__(self, cfg: ExpertTierConfig, migrate_fn=None):
        from repro.core.adapters.base import warn_deprecated
        warn_deprecated("core.adapters.ExpertCache", '"experts" TieredResource')
        self.cfg = cfg
        spec = tm.ResourceSpec(
            name="experts", n_pages=cfg.n_groups * cfg.n_experts,
            hot_slots=cfg.n_groups * cfg.hot_slots,
            quota_pages=cfg.quota_pages, sketch_width=cfg.sketch_width)
        super().__init__(tm.ExpertStreamResource(
            spec, n_experts=cfg.n_experts, migrate_fn=migrate_fn))

    def observe_step(self, router_streams: jax.Array) -> None:
        """router_streams: (G, n_moe, B, S, k) from the forward pass."""
        self._h.observe(jnp.asarray(router_streams))
