"""NeoMem dynamic hotness-threshold policy — a faithful port of Algorithm 1.

Line-by-line mapping to the paper (§V-A):

  line 4   F  <- get_neoprof_hist()          -> hist (64 bins)
  line 5   B  <- get_bandwidth_util()        -> bandwidth_util
  line 6   P  <- get_ping_pong_count()       -> ping_pong ratio (tiering stats)
  line 7   E  <- get_error_bound(F)          -> sketch error bound
  line 8   M  <- get_migrate_pages_count()   -> pages migrated last period
  line 9-12  p <- clip(p * (1+B)^a / (1+P)^b)   if M < m_quota
  line 13    p <- max(p_min, p/2)               else   (quota constraint)
  line 14-15 p <- max(p_min, p/2)               if Q_F(1-p) < E (error bound)
  line 16  theta = Q_F(1-p)

The policy lives in "user space" (host-side, plain floats) exactly as the
paper's policy does — only the inputs come from device-side NeoProf reads.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import sketch as sk
from repro.core.sketch import SketchParams


@dataclasses.dataclass
class PolicyParams:
    """Defaults = paper Table IV."""

    m_quota_pages: int = 4096          # migration quota per period (pages)
    p_min: float = 0.0001              # 0.01%
    p_max: float = 0.0156              # 1.56%
    p_init: float = 0.001              # 0.1%
    alpha: float = 1.0
    beta: float = 2.0
    theta_min: int = 1                 # never call a never-touched page hot


@dataclasses.dataclass
class PolicyState:
    p: float
    theta: int = 1
    # Telemetry for EXPERIMENTS / Fig. 14-style traces.
    last_B: float = 0.0
    last_P: float = 0.0
    last_E: int = 0

    @staticmethod
    def init(params: PolicyParams) -> "PolicyState":
        return PolicyState(p=params.p_init, theta=params.theta_min)


def quantile_from_hist_np(hist: np.ndarray, q: float) -> int:
    """Host-side Q_F over the 64-bin counter histogram."""
    edges = sk.hist_edges()
    total = max(int(hist.sum()), 1)
    cum = np.cumsum(hist)
    bin_id = int(np.searchsorted(cum, q * total))
    bin_id = min(bin_id, len(hist) - 1)
    return int(edges[min(bin_id + 1, len(edges) - 1)])


def error_bound_np(hist: np.ndarray, sparams: SketchParams, delta: float = 0.25) -> int:
    edges = sk.hist_edges(sparams.counter_bits)
    rank = sparams.width * (delta ** (1.0 / sparams.depth))
    cum_from_top = np.cumsum(hist[::-1])[::-1]
    idx = np.nonzero(cum_from_top >= rank)[0]
    if len(idx) == 0:
        return 0
    return int(edges[min(int(idx[-1]) + 1, len(edges) - 1)])


def update_threshold(
    state: PolicyState,
    params: PolicyParams,
    hist: np.ndarray,
    bandwidth_util: float,
    ping_pong_ratio: float,
    migrated_pages: int,
    error_bound: int,
) -> PolicyState:
    """One pass of Algorithm 1's while-loop body."""
    p = state.p
    if migrated_pages < params.m_quota_pages:                    # line 9
        p = p * (1.0 + bandwidth_util) ** params.alpha \
            / (1.0 + ping_pong_ratio) ** params.beta             # line 10
        p = float(np.clip(p, params.p_min, params.p_max))        # line 11
    else:
        p = max(params.p_min, p / 2.0)                           # line 13

    if quantile_from_hist_np(hist, 1.0 - p) < error_bound:       # line 14
        p = max(params.p_min, p / 2.0)                           # line 15

    theta = max(params.theta_min, quantile_from_hist_np(hist, 1.0 - p))  # line 16
    return PolicyState(
        p=p, theta=theta,
        last_B=float(bandwidth_util), last_P=float(ping_pong_ratio),
        last_E=int(error_bound),
    )


@dataclasses.dataclass
class StaticPolicy:
    """Fixed-threshold baseline (paper Fig. 14 comparison)."""

    theta: int

    def update(self, *_args, **_kw) -> "StaticPolicy":
        return self
