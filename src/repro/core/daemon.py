"""Legacy single-resource NeoMem daemon — a deprecation shim.

The orchestration loop now lives in :mod:`repro.tiering` (a multiplexed
daemon driving N resources on one cadence with a shared quota budget).
This module keeps the original ``NeoMemDaemon(prof_params, tier_params)``
construction and explicit ``tick(prof, tier)`` threading as a thin wrapper
over :class:`repro.tiering.TieredMemory` so pre-existing callers keep
working.  The wider forwarding surface the shim once carried (``.policy``,
``.state``, ``.bind_data``, ``migrate_fn`` callbacks) had no remaining
callers and is gone; new code should register a
:class:`repro.tiering.TieredResource` with the multiplexed
:class:`repro.tiering.NeoMemDaemon` instead.
"""
from __future__ import annotations

import dataclasses

from repro.core.neoprof import NeoProfParams, NeoProfState
from repro.core.policy import PolicyParams
from repro.core.tiering import TierParams, TierState


@dataclasses.dataclass
class DaemonParams:
    """Legacy cadence params (quota defaults to 256, as before)."""

    migration_interval: int = 1        # ticks between promotion batches
    threshold_update_period: int = 8   # ticks between Algorithm-1 runs
    clear_interval: int = 64           # ticks between sketch resets
    quota_pages: int = 256             # per migration interval (m_quota)


class NeoMemDaemon:
    """Host-side daemon driving device-resident NeoProf + TieredStore."""

    def __init__(
        self,
        prof_params: NeoProfParams,
        tier_params: TierParams,
        daemon_params: DaemonParams | None = None,
        policy_params: PolicyParams | None = None,
    ):
        # Imported lazily: repro.core's package init imports this module,
        # while repro.tiering.memory imports repro.core submodules.
        from repro.core.adapters.base import warn_deprecated
        from repro.tiering.memory import DaemonParams as _DaemonParams
        from repro.tiering.memory import TieredMemory
        from repro.tiering.stats import TierStats

        warn_deprecated("core.daemon.NeoMemDaemon",
                        "TieredResource (or drive TieredMemory directly)")

        self.pp = prof_params
        self.tp = tier_params
        self.dp = daemon_params or DaemonParams()
        self.mem = TieredMemory(
            prof_params, tier_params,
            daemon_params=_DaemonParams(
                migration_interval=self.dp.migration_interval,
                threshold_update_period=self.dp.threshold_update_period,
                clear_interval=self.dp.clear_interval,
                quota_pages=self.dp.quota_pages),
            policy_params=policy_params)
        self.pol_params = self.mem.pol_params
        self.cmd = self.mem.cmd
        self.stats = TierStats(name="legacy")
        # p + tick carried across ticks (prof/tier are threaded by the caller)
        self._mstate = self.mem.init()

    def tick(
        self, prof: NeoProfState, tier: TierState
    ) -> tuple[NeoProfState, TierState]:
        """One daemon tick: run whatever cadences are due."""
        st = self._mstate._replace(prof=prof, tier=tier)
        st, _ = self.mem.tick(st, self.stats)
        self._mstate = st
        return st.prof, st.tier
