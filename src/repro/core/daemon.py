"""NeoMem daemon — the kernel-side orchestration loop (paper §III/§V).

Responsibilities (paper Fig. 5 (5)):
  * every ``migration_interval`` steps: drain NeoProf's hot-page buffer and
    promote (quota-bounded) via the TieredStore;
  * every ``threshold_update_period`` steps: run Algorithm 1 against the
    NeoProf histogram / bandwidth / ping-pong / error-bound readings;
  * every ``clear_interval`` steps: reset NeoProf counters (sketch epoch bump).

The paper expresses these cadences in wall time (10 ms / 5 s); here a "tick"
is one model step, preserving the rate *hierarchy*
(migration << threshold-update <= clear).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import tiering
from repro.core.neoprof import NeoProfCommands, NeoProfParams, NeoProfState
from repro.core.policy import PolicyParams, PolicyState, update_threshold
from repro.core.tiering import TierParams, TierState


@dataclasses.dataclass
class DaemonParams:
    migration_interval: int = 1        # ticks between promotion batches
    threshold_update_period: int = 8   # ticks between Algorithm-1 runs
    clear_interval: int = 64           # ticks between sketch resets
    quota_pages: int = 256             # per migration interval (m_quota)


@dataclasses.dataclass
class DaemonState:
    tick: int = 0
    migrated_this_period: int = 0
    # Cumulative telemetry (the per-period tier counters are drained by the
    # policy; lifetime totals live here)
    total_fast: int = 0
    total_slow: int = 0
    total_promoted: int = 0
    total_ping_pong: int = 0
    # Telemetry traces (Fig. 14-style)
    theta_trace: list = dataclasses.field(default_factory=list)
    bw_trace: list = dataclasses.field(default_factory=list)
    pp_trace: list = dataclasses.field(default_factory=list)


class NeoMemDaemon:
    """Host-side daemon driving device-resident NeoProf + TieredStore.

    ``migrate_fn(promoted_pages, victim_slots)`` is the adapter callback that
    applies the actual data movement (expert weights / KV pages / embedding
    rows).  The daemon itself is data-agnostic, mirroring the kernel daemon
    calling ``migrate_pages()``.
    """

    def __init__(
        self,
        prof_params: NeoProfParams,
        tier_params: TierParams,
        daemon_params: DaemonParams | None = None,
        policy_params: PolicyParams | None = None,
        migrate_fn: Callable[[jnp.ndarray, jnp.ndarray], None] | None = None,
    ):
        self.pp = prof_params
        self.tp = tier_params
        self.dp = daemon_params or DaemonParams()
        # policy quota bound: 4x migration capacity per update period
        # (equal-to-capacity degenerates into p starve/flood oscillation)
        self.pol_params = policy_params or PolicyParams(
            m_quota_pages=4 * self.dp.quota_pages * max(
                1, self.dp.threshold_update_period // self.dp.migration_interval)
        )
        self.cmd = NeoProfCommands(prof_params)
        self.policy = PolicyState.init(self.pol_params)
        self.state = DaemonState()
        self.migrate_fn = migrate_fn
        self._pending = np.empty((0,), np.int64)  # hot pages awaiting quota

    # ------------------------------------------------------------------
    def tick(
        self, prof: NeoProfState, tier: TierState
    ) -> tuple[NeoProfState, TierState]:
        """One daemon tick: run whatever cadences are due."""
        st, dp = self.state, self.dp
        st.tick += 1

        if st.tick % dp.migration_interval == 0:
            prof, tier = self._migrate(prof, tier)

        if st.tick % dp.threshold_update_period == 0:
            prof, tier = self._update_threshold(prof, tier)

        if st.tick % dp.clear_interval == 0:
            prof = self.cmd.reset(prof)

        return prof, tier

    # ------------------------------------------------------------------
    def _migrate(self, prof: NeoProfState, tier: TierState):
        prof, hot = self.cmd.drain_hotpages(prof)
        hot = np.concatenate([self._pending, np.asarray(hot, np.int64)])
        if len(hot) == 0:
            return prof, tier
        k = self.dp.quota_pages
        batch = np.full((k,), -1, np.int32)
        take = min(k, len(hot))
        batch[:take] = hot[:take]
        self._pending = hot[take:][: 1 << 14]
        tier, promoted, victims = tiering.promote(tier, jnp.asarray(batch), k)
        if self.migrate_fn is not None:
            self.migrate_fn(promoted, victims)
        self.state.migrated_this_period += int(np.sum(np.asarray(promoted) >= 0))
        return prof, tier

    def _update_threshold(self, prof: NeoProfState, tier: TierState):
        hist = self.cmd.get_hist(prof)
        bw = self.cmd.bandwidth_util(prof)
        err = self.cmd.get_error_bound(prof, hist)
        tier, stats = tiering.drain_period_stats(tier)
        promoted = int(stats["promoted"])
        # Laplace-damped: a single bounce at low volume must not crash p
        pp_ratio = float(stats["ping_pong"]) / max(
            promoted, self.dp.quota_pages // 2, 1)
        self.state.total_fast += int(stats["fast_reads"])
        self.state.total_slow += int(stats["slow_reads"])
        self.state.total_promoted += promoted
        self.state.total_ping_pong += int(stats["ping_pong"])

        # M = migration DEMAND (migrated + still-queued): Alg.1's quota
        # constraint throttles when demand exceeds capacity, not merely
        # when the migrator runs at capacity.
        self.policy = update_threshold(
            self.policy, self.pol_params, hist,
            bandwidth_util=bw, ping_pong_ratio=pp_ratio,
            migrated_pages=self.state.migrated_this_period + len(self._pending),
            error_bound=err,
        )
        prof = self.cmd.set_threshold(prof, self.policy.theta)
        self.state.migrated_this_period = 0
        self.state.theta_trace.append(self.policy.theta)
        self.state.bw_trace.append(bw)
        self.state.pp_trace.append(pp_ratio)
        return prof, tier
