"""NeoProf — the device-side profiler (paper §IV), as a JAX pytree module.

Composition (paper Fig. 6): Page Monitor (snoops the access stream — here,
the index streams the model itself computes), NeoProf Core (CM-sketch hot
page detector + hot-page buffer + histogram unit), State Monitor (bandwidth /
read-write accounting).  The host-facing command set of Table I is preserved
verbatim in :class:`NeoProfCommands` so the software stack above mirrors the
paper's driver/daemon split.

All update paths are jit-able and run *inside* the training/serving step —
the TPU analogue of device-side offload: profiling consumes no host cycles
and no extra HBM round-trips beyond the sketch working set.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import SketchParams, SketchState


class NeoProfParams(NamedTuple):
    sketch: SketchParams = SketchParams()
    hot_buffer_entries: int = 1 << 12   # paper: 16K
    delta: float = 0.25                 # error-bound confidence (paper ex.)

    # Pallas acceleration for the sketch update (interpret-mode on CPU).
    use_kernel: bool = False


class StateMonitor(NamedTuple):
    """Read/Write/bandwidth accounting (paper GetNrSample/GetRdCnt/GetWrCnt).

    'Cycles' are modeled as bytes-on-the-wire normalized by tier bandwidth;
    the OS-side policy only ever consumes the *ratio* B = (rd+wr)/total, so
    any consistent unit works (the paper makes the same approximation).
    """

    rd_bytes: jax.Array   # () float32 — slow-tier bytes read this period
    wr_bytes: jax.Array   # () float32 — slow-tier bytes written this period
    total_budget: jax.Array  # () float32 — bytes the tier could have moved

    @staticmethod
    def init() -> "StateMonitor":
        z = jnp.zeros((), jnp.float32)
        return StateMonitor(z, z, jnp.ones((), jnp.float32))


class NeoProfState(NamedTuple):
    sketch: SketchState
    monitor: StateMonitor
    hot_buf: jax.Array     # (hot_buffer_entries,) int32 page ids, -1 = empty
    hot_count: jax.Array   # () int32 valid entries in hot_buf
    dropped: jax.Array     # () int32 hot pages dropped on buffer overflow
    theta: jax.Array       # () int32 current hotness threshold


def neoprof_init(params: NeoProfParams, key: jax.Array | None = None) -> NeoProfState:
    return NeoProfState(
        sketch=sk.sketch_init(params.sketch, key),
        monitor=StateMonitor.init(),
        hot_buf=jnp.full((params.hot_buffer_entries,), -1, jnp.int32),
        hot_count=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        theta=jnp.ones((), jnp.int32),
    )


def _append_hot(
    hot_buf: jax.Array, hot_count: jax.Array, dropped: jax.Array,
    page_ids: jax.Array, mask: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compact masked page ids into the fixed-capacity hot buffer."""
    cap = hot_buf.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1 + hot_count
    ok = mask & (pos < cap)
    # overflow / non-hot lanes scatter out of bounds and are dropped
    idx = jnp.where(ok, pos, cap)
    hot_buf = hot_buf.at[idx].set(page_ids, mode="drop")
    n_new = jnp.sum(ok, dtype=jnp.int32)
    n_drop = jnp.sum(mask & ~ok, dtype=jnp.int32)
    return hot_buf, hot_count + n_new, dropped + n_drop


@functools.partial(jax.jit, static_argnames=("params",))
def neoprof_observe(
    state: NeoProfState,
    page_ids: jax.Array,
    params: NeoProfParams,
    rd_bytes: jax.Array | float = 0.0,
    wr_bytes: jax.Array | float = 0.0,
    budget_bytes: jax.Array | float = 0.0,
) -> NeoProfState:
    """Feed one block of the access stream (negative ids = padding).

    This is the Page Monitor + NeoProf Core pass: sketch update, hot
    detection, hot filtering, buffer append, and State Monitor accounting.
    """
    if params.use_kernel:
        from repro.kernels.neoprof_update import ops as kops
        new_sketch, newly_hot = kops.sketch_update(
            state.sketch, page_ids.astype(jnp.int32), state.theta, params.sketch
        )
    else:
        new_sketch, newly_hot = sk.sketch_update(
            state.sketch, page_ids.astype(jnp.int32), state.theta, params.sketch
        )
    hot_buf, hot_count, dropped = _append_hot(
        state.hot_buf, state.hot_count, state.dropped,
        jnp.where(page_ids >= 0, page_ids, 0).astype(jnp.int32), newly_hot,
    )
    mon = state.monitor
    mon = StateMonitor(
        rd_bytes=mon.rd_bytes + jnp.asarray(rd_bytes, jnp.float32),
        wr_bytes=mon.wr_bytes + jnp.asarray(wr_bytes, jnp.float32),
        total_budget=mon.total_budget + jnp.asarray(budget_bytes, jnp.float32),
    )
    return state._replace(
        sketch=new_sketch, monitor=mon,
        hot_buf=hot_buf, hot_count=hot_count, dropped=dropped,
    )


class NeoProfCommands:
    """The MMIO command set of paper Table I, as a host-side façade.

    Each verb is a cheap jitted read/write against the device-resident
    state — the analogue of a single MMIO transaction.
    """

    def __init__(self, params: NeoProfParams):
        self.params = params

    # -- control -----------------------------------------------------------
    def reset(self, state: NeoProfState) -> NeoProfState:          # 0x100
        return state._replace(
            sketch=sk.sketch_clear(state.sketch),
            monitor=StateMonitor.init(),
            hot_buf=jnp.full_like(state.hot_buf, -1),
            hot_count=jnp.zeros((), jnp.int32),
            dropped=jnp.zeros((), jnp.int32),
        )

    def set_threshold(self, state: NeoProfState, theta) -> NeoProfState:  # 0x200
        return state._replace(theta=jnp.asarray(theta, jnp.int32))

    # -- hot pages ----------------------------------------------------------
    def get_nr_hotpage(self, state: NeoProfState) -> int:          # 0x300
        return int(state.hot_count)

    def get_hotpages(self, state: NeoProfState) -> jnp.ndarray:    # 0x400 (seq.)
        n = int(state.hot_count)
        return jax.device_get(state.hot_buf)[:n]

    def drain_hotpages(self, state: NeoProfState) -> tuple[NeoProfState, jnp.ndarray]:
        pages = self.get_hotpages(state)
        return state._replace(
            hot_buf=jnp.full_like(state.hot_buf, -1),
            hot_count=jnp.zeros((), jnp.int32),
        ), pages

    # -- state monitor ------------------------------------------------------
    def get_nr_sample(self, state: NeoProfState) -> float:         # 0x500
        return float(state.monitor.total_budget)

    def get_rd_cnt(self, state: NeoProfState) -> float:            # 0x600
        return float(state.monitor.rd_bytes)

    def get_wr_cnt(self, state: NeoProfState) -> float:            # 0x700
        return float(state.monitor.wr_bytes)

    def bandwidth_util(self, state: NeoProfState) -> float:
        m = state.monitor
        return float((m.rd_bytes + m.wr_bytes) / jnp.maximum(m.total_budget, 1.0))

    # -- histogram unit ------------------------------------------------------
    def get_hist(self, state: NeoProfState) -> jnp.ndarray:        # 0x800-0xA00
        return jax.device_get(sk.sketch_histogram(state.sketch, self.params.sketch))

    def get_error_bound(self, state: NeoProfState, hist=None) -> int:
        h = self.get_hist(state) if hist is None else hist
        return int(sk.error_bound_from_hist(h, self.params.sketch, self.params.delta))
