"""Count-Min Sketch hot-page detector — the NeoProf core (paper §IV-B).

Faithful algorithmic port of NeoProf's sketch pipeline:

  * D hash lanes x W counters, H3 hash functions (paper Eq. 5),
  * valid bits for O(1) logical reset  -> generalized to an 8-bit *epoch tag*
    per entry (same lazy-reset semantics, no contiguous-bit hardware needed),
  * hot bits for in-sketch Bloom-style hot-page filtering (paper Fig. 7 (2)/(6)),
  * tight error-bound estimation via the counter histogram (paper Fig. 9,
    after Chen et al.): e = top-(W * delta^(1/D))-percentile counter value.

Everything here is pure JAX (jit-able, runs on-device inside a step — the
"device-side offload" analogue).  The Pallas kernel in
``repro.kernels.neoprof_update`` accelerates :func:`sketch_update` on TPU;
this module is also its reference semantics.

Block-synchronous semantics: the hardware pipeline processes one address per
cycle; we process a *block* of S addresses at once.  A page is "newly hot"
for a block iff (a) its post-block estimate exceeds theta, (b) its hot bits
were not all set *before* the block, and (c) it is the first occurrence of
that page within the block (intra-block dedup — the parallel analogue of the
serial hot filter).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Page ids are < 2**PAGE_ID_BITS.  32-bit ids address 16 TB of 4K pages in the
# paper (Table III); our logical page spaces (experts / KV pages / vocab rows)
# are far smaller, but we keep the width for fidelity.
PAGE_ID_BITS = 30
HIST_BINS = 64


class SketchParams(NamedTuple):
    """Static sketch geometry (paper Table III defaults: W=512K, D=2)."""

    width: int = 1 << 14  # W counters per lane
    depth: int = 2        # D lanes
    counter_bits: int = 16  # saturate like the paper's 16-bit counters

    @property
    def counter_max(self) -> int:
        return (1 << self.counter_bits) - 1


class SketchState(NamedTuple):
    """Device-resident sketch state (a pytree; donate-able)."""

    counts: jax.Array      # (D, W) int32, saturating at counter_max
    epochs: jax.Array      # (D, W) uint8 epoch tags (generalized valid bits)
    hot: jax.Array         # (D, W) bool hot bits
    cur_epoch: jax.Array   # () uint8 current epoch
    n_seen: jax.Array      # () int32 items streamed this epoch (N in Eq. 3)
    seeds: jax.Array       # (D, PAGE_ID_BITS) int32 H3 seeds


def make_seeds(key: jax.Array, depth: int, width: int) -> jax.Array:
    """H3 seed matrix: one m-bit row seed per input bit per lane."""
    m_bits = int(np.log2(width))
    assert 1 << m_bits == width, "sketch width must be a power of two"
    return jax.random.randint(
        key, (depth, PAGE_ID_BITS), 0, 1 << m_bits, dtype=jnp.int32
    )


def sketch_init(params: SketchParams, key: jax.Array | None = None) -> SketchState:
    key = key if key is not None else jax.random.PRNGKey(0)
    d, w = params.depth, params.width
    return SketchState(
        counts=jnp.zeros((d, w), jnp.int32),
        epochs=jnp.zeros((d, w), jnp.uint8),
        hot=jnp.zeros((d, w), jnp.bool_),
        cur_epoch=jnp.zeros((), jnp.uint8),
        n_seen=jnp.zeros((), jnp.int32),
        seeds=make_seeds(key, d, w),
    )


def h3_hash(page_ids: jax.Array, seeds: jax.Array) -> jax.Array:
    """Vectorized H3 hash (paper Eq. 5): XOR of seeds at set input bits.

    page_ids: (...,) int32; seeds: (D, PAGE_ID_BITS) int32 -> (D, ...) int32.
    """
    h = jnp.zeros((seeds.shape[0],) + page_ids.shape, jnp.int32)
    for bit in range(PAGE_ID_BITS):  # static unroll — PAGE_ID_BITS XORs
        mask = ((page_ids >> bit) & 1).astype(jnp.bool_)
        h = jnp.where(mask[None], h ^ seeds[:, bit][(...,) + (None,) * page_ids.ndim], h)
    return h


def sketch_clear(state: SketchState) -> SketchState:
    """O(1) logical reset (paper's valid-bit trick): bump the epoch tag.

    Counters whose tag != cur_epoch read as zero and are re-initialized on
    their next touch.  Hot bits are cleared for real (they are one bit-plane;
    the paper resets them contiguously "in a few cycles").
    """
    return state._replace(
        cur_epoch=(state.cur_epoch + jnp.uint8(1)),
        hot=jnp.zeros_like(state.hot),
        n_seen=jnp.zeros_like(state.n_seen),
    )


def _live_counts(state: SketchState) -> jax.Array:
    """Counters, with stale-epoch entries reading as zero."""
    return jnp.where(state.epochs == state.cur_epoch, state.counts, 0)


def _first_occurrence(page_ids: jax.Array, valid: jax.Array) -> jax.Array:
    """Mask of first occurrence of each id within the block (O(S^2) compare)."""
    s = page_ids.shape[0]
    eq = (page_ids[:, None] == page_ids[None, :]) & valid[None, :]
    earlier = jnp.tril(jnp.ones((s, s), jnp.bool_), k=-1)
    return valid & ~jnp.any(eq & earlier, axis=1)


@functools.partial(jax.jit, static_argnames=("params",))
def sketch_update(
    state: SketchState,
    page_ids: jax.Array,
    theta: jax.Array,
    params: SketchParams,
) -> tuple[SketchState, jax.Array]:
    """Stream a block of page ids into the sketch; return newly-hot mask.

    page_ids: (S,) int32, negative entries are padding.
    theta:    () int32 hotness threshold.
    Returns (new_state, newly_hot) with newly_hot: (S,) bool — True on the
    first in-block occurrence of a page that crossed theta and whose hot bits
    were not already all set.
    """
    valid = page_ids >= 0
    safe_ids = jnp.where(valid, page_ids, 0)
    idx = h3_hash(safe_ids, state.seeds)  # (D, S)

    live = _live_counts(state)
    d = params.depth

    # Counter increments: per-lane bincount of the block (the MXU-friendly
    # form the Pallas kernel mirrors with segment tiles).
    def lane_add(lane_counts, lane_idx):
        return lane_counts.at[lane_idx].add(valid.astype(jnp.int32))

    new_counts = jax.vmap(lane_add)(live, idx)
    new_counts = jnp.minimum(new_counts, params.counter_max)

    # Post-block estimate (Eq. 2): min over lanes of the hashed counters.
    gathered = jax.vmap(lambda c, i: c[i])(new_counts, idx)  # (D, S)
    est = jnp.min(gathered, axis=0)

    # Hot filter (paper Fig. 7 (6)): previously-recorded iff all hot bits set.
    hot_bits_before = jax.vmap(lambda hb, i: hb[i])(state.hot, idx)  # (D, S)
    already_hot = jnp.all(hot_bits_before, axis=0)
    is_hot = valid & (est > theta)
    newly_hot = is_hot & ~already_hot & _first_occurrence(safe_ids, valid)

    # Set hot bits for every detected hot page (incl. re-detections).
    def lane_set_hot(lane_hot, lane_idx):
        return lane_hot.at[lane_idx].max(is_hot)

    new_hot = jax.vmap(lane_set_hot)(state.hot, idx)

    del d
    # Storing the full lazily-zeroed array makes every entry current, so the
    # epoch tag can be refreshed wholesale (identical read-back semantics to
    # the hardware's per-entry valid bit; keeps exact state parity with the
    # Pallas kernel which rewrites whole segments anyway).
    new_state = state._replace(
        counts=new_counts,
        epochs=jnp.full_like(state.epochs, state.cur_epoch),
        hot=new_hot,
        n_seen=state.n_seen + jnp.sum(valid, dtype=jnp.int32),
    )
    return new_state, newly_hot


@functools.partial(jax.jit, static_argnames=("params",))
def sketch_query(state: SketchState, page_ids: jax.Array, params: SketchParams) -> jax.Array:
    """Point-query estimated access counts (Eq. 2)."""
    idx = h3_hash(page_ids, state.seeds)
    live = _live_counts(state)
    gathered = jax.vmap(lambda c, i: c[i])(live, idx)
    return jnp.min(gathered, axis=0)


# ---------------------------------------------------------------------------
# Histogram unit + error bound (paper Fig. 9)
# ---------------------------------------------------------------------------

def hist_edges(counter_bits: int = 16, bins: int = HIST_BINS) -> np.ndarray:
    """Static geometric-ish bin edges over [0, counter_max].

    bin k covers [edges[k], edges[k+1]).  First bins are exact small counts
    (0,1,2,...) — where hot-threshold decisions live — then geometric growth.
    """
    max_v = (1 << counter_bits) - 1
    exact = list(range(17))  # 0..16 exact
    geo = np.unique(
        np.round(np.geomspace(17, max_v + 1, bins + 1 - len(exact))).astype(np.int64)
    )
    edges = np.unique(np.concatenate([np.array(exact, np.int64), geo]))
    # pad/trim to exactly bins+1 edges
    while len(edges) < bins + 1:
        edges = np.append(edges, edges[-1] + 1)
    return edges[: bins + 1].astype(np.int32)


@functools.partial(jax.jit, static_argnames=("params",))
def sketch_histogram(state: SketchState, params: SketchParams) -> jax.Array:
    """64-bin histogram of row-0 live counters (the NeoProf histogram unit)."""
    edges = jnp.asarray(hist_edges(params.counter_bits))
    row0 = _live_counts(state)[0]
    bin_idx = jnp.clip(jnp.searchsorted(edges, row0, side="right") - 1, 0, HIST_BINS - 1)
    return jnp.zeros((HIST_BINS,), jnp.int32).at[bin_idx].add(1)


def error_bound_from_hist(
    hist: jax.Array | np.ndarray,
    params: SketchParams,
    delta: float = 0.25,
) -> jax.Array:
    """Tight error bound e (paper §IV-B, after Chen et al. [13]).

    e = the value at rank W * delta^(1/D) counting from the LARGEST counter
    (with D=2, delta=0.25 -> the median, as in the paper's example).  We read
    it off the histogram: the upper edge of the bin where the from-the-top
    cumulative count crosses the rank.
    """
    edges = jnp.asarray(hist_edges(params.counter_bits))
    hist = jnp.asarray(hist)
    rank = params.width * (delta ** (1.0 / params.depth))
    cum_from_top = jnp.cumsum(hist[::-1])[::-1]  # pages with bin >= k
    crossed = cum_from_top >= rank
    # highest bin index where cumulative-from-top still >= rank
    bin_id = jnp.max(jnp.where(crossed, jnp.arange(HIST_BINS), -1))
    return jnp.where(bin_id < 0, 0, edges[jnp.clip(bin_id + 1, 0, HIST_BINS)]).astype(jnp.int32)


def quantile_from_hist(hist: jax.Array | np.ndarray, q: jax.Array | float) -> jax.Array:
    """Q_F(q): counter value such that a fraction q of counters lie below.

    Used by Algorithm 1 line 16: theta = Q_F(1 - p).
    """
    edges = jnp.asarray(hist_edges())
    hist = jnp.asarray(hist)
    total = jnp.maximum(jnp.sum(hist), 1)
    cum = jnp.cumsum(hist)
    target = q * total
    bin_id = jnp.argmax(cum >= target)  # first bin reaching the quantile
    return edges[jnp.clip(bin_id + 1, 0, HIST_BINS)].astype(jnp.int32)
