import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point BEFORE any other jax-touching import —
the XLA_FLAGS line above executes first, forcing 512 placeholder host
devices so jax.make_mesh can build the production meshes.

Per cell it records: compile success, memory_analysis (bytes/device),
cost_analysis (FLOPs / bytes), and the collective-op byte census parsed from
the compiled HLO — everything the roofline module (repro.roofline) consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k --mesh single                            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""
import argparse
import json
import re
import time
import traceback
from collections import Counter

import jax


def _collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective in the (SPMD-partitioned) HLO."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8,
                   "u64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    out = {op: {"count": 0, "bytes": 0} for op in ops}
    # lines look like:  %ag = f32[16,1024]{1,0} all-gather(...)
    pat = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
        + "|".join(ops) + r")\(")
    for mt in pat.finditer(hlo_text):
        dt, shape_s, op = mt.groups()
        if dt not in dtype_bytes:
            continue
        numel = 1
        if shape_s:
            for d in shape_s.split(","):
                numel *= int(d)
        out[op]["count"] += 1
        out[op]["bytes"] += numel * dtype_bytes[dt]
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out: dict,
             variant: str | None = None) -> None:
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_specs

    mesh_name = "multi" if multi_pod else "single"
    key = f"{arch}|{shape}|{mesh_name}"
    if variant:
        key += f"|{variant}"
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "variant": variant}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            spec = cell_specs(arch, shape, mesh, variant=variant)
            if "skip" in spec:
                rec["status"] = "skipped"
                rec["reason"] = spec["skip"]
                out[key] = rec
                print(f"SKIP {key}: {spec['skip'][:60]}")
                return
            fn = spec["fn"]
            jitted = jax.jit(fn, donate_argnums=spec.get("donate", ()))
            t_l = time.time()
            lowered = jitted.lower(*spec["args"])
            rec["lower_s"] = round(time.time() - t_l, 1)
            t_c = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t_c, 1)

            ma = compiled.memory_analysis()
            print(ma)
            if ma is not None:
                rec["memory"] = {
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                }
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # jax<=0.4 returns [dict]
                ca = ca[0] if ca else {}
            print({k: ca.get(k) for k in ("flops", "bytes accessed")})
            if ca:
                rec["cost"] = {
                    "flops": float(ca.get("flops", -1)),
                    "bytes_accessed": float(ca.get("bytes accessed", -1)),
                }
            txt = compiled.as_text()
            rec["collectives"] = _collective_bytes(txt)  # static census
            from repro.roofline.census import census
            rec["census"] = census(txt)                  # trip-count-aware
            rec["hlo_ops"] = dict(Counter(
                m.group(1) for m in re.finditer(
                    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
                    r"collective-permute|fusion|custom-call|scatter|gather)\(",
                    txt)))
            rec["status"] = "ok"
            rec["total_s"] = round(time.time() - t0, 1)
            print(f"OK   {key} (lower {rec['lower_s']}s, "
                  f"compile {rec['compile_s']}s)")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"FAIL {key}: {rec['error'][:200]}")
    out[key] = rec


def main() -> None:
    from repro.configs.base import SHAPES
    from repro.configs.registry import list_archs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true",
                    help="merge into existing --out file")
    ap.add_argument("--variant", default=None,
                    choices=[None, "tiered_experts", "fsdp", "local_grads"],
                    help="perf-pass variant (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out: dict = {}
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            out = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_cell(arch, shape, mp, out, variant=args.variant)
                with open(args.out, "w") as f:   # checkpoint after each cell
                    json.dump(out, f, indent=1)

    n_ok = sum(1 for r in out.values() if r["status"] == "ok")
    n_skip = sum(1 for r in out.values() if r["status"] == "skipped")
    n_err = sum(1 for r in out.values() if r["status"] == "error")
    print(f"\ndry-run complete: {n_ok} ok / {n_skip} skipped / {n_err} failed "
          f"-> {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
