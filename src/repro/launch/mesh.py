"""Production mesh construction (brief-mandated shapes).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    # jax >= 0.5 takes explicit axis types; 0.4.x has neither the kwarg nor
    # jax.sharding.AxisType — Auto is the default there anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return _make((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def chips(mesh) -> int:
    import math
    return math.prod(mesh.devices.shape)
