"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these.  Returns (fn, args, in_shardings, label) per cell:

  train_4k      -> train_step(state, batch)
  prefill_32k   -> prefill(params, tokens)
  decode_32k    -> decode_step(params, cache, token)     (full KV cache)
  long_500k     -> decode_step_paged(params, cache, token) (NeoMem fast tier)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, cell_is_skipped
from repro.configs.registry import get_config
from repro.dist.sharding import cache_pspecs, param_pspecs
from repro.models import decode as dec
from repro.models import transformer as tr
from repro.train import step as train_step_mod

PAGE_T = 256            # tokens per KV page (NeoMem tiering page)
HOT_SLOTS = 512         # fast-tier page slots per layer (long_500k)


def _dp(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _sds(shape, dtype, mesh=None, spec=None):
    sh = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _abstract_params(cfg: ArchConfig, mesh):
    shapes = jax.eval_shape(lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(shapes, mesh)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs, is_leaf=lambda x: hasattr(x, "shape")), specs


def _microbatches(cfg: ArchConfig, global_batch: int, seq: int, mesh) -> int:
    """Grad-accumulation factor: keep per-device microbatch tokens ~<= 8K."""
    dp = int(np.prod([mesh.shape[a] for a in _dp(mesh)]))
    per_dev_rows = max(1, global_batch // dp)
    target_rows = max(1, (8192 + seq - 1) // seq)
    m = max(1, per_dev_rows // target_rows)
    while per_dev_rows % m:
        m -= 1
    return m


HOT_EXPERT_FRAC = 16    # E_hot = E / frac resident (NeoMem fast tier)
N_FETCH = 16            # cold experts DMA'd per interval (1 per EP shard)


def _tiered_expert_params(cfg: ArchConfig, params, mesh):
    """Swap full FSDP expert weights for NeoMem fast-tier residents:
    (G, E, D, F) -> hot (G, E_hot, D, F) TP-sharded + replicated fetch
    buffers + residency map.  (§Perf cell A optimization.)"""
    e = cfg.moe.n_experts
    e_hot = max(mesh.shape["model"], e // HOT_EXPERT_FRAC)
    g = cfg.n_groups
    d, f = cfg.d_model, cfg.moe.expert_ff
    ns = lambda spec: NamedSharding(mesh, spec)
    mk = lambda shape, spec: jax.ShapeDtypeStruct(
        shape, jnp.bfloat16, sharding=ns(spec))
    for blk in params["blocks"]:
        ffn = blk.get("ffn")
        if ffn is None or "w_gate" not in ffn or len(ffn["w_gate"].shape) < 4:
            continue
        ffn["w_gate"] = mk((g, e_hot, d, f), P(None, "model", None, None))
        ffn["w_in"] = mk((g, e_hot, d, f), P(None, "model", None, None))
        ffn["w_out"] = mk((g, e_hot, f, d), P(None, "model", None, None))
        ffn["fetch_gate"] = mk((g, N_FETCH, d, f), P(None, "model", None, None))
        ffn["fetch_in"] = mk((g, N_FETCH, d, f), P(None, "model", None, None))
        ffn["fetch_out"] = mk((g, N_FETCH, f, d), P(None, "model", None, None))
        ffn["fetch_ids"] = jax.ShapeDtypeStruct(
            (g, N_FETCH), jnp.int32, sharding=ns(P(None, "model")))
        ffn["residency"] = jax.ShapeDtypeStruct(
            (g, e), jnp.int32, sharding=ns(P(None, None)))
    return params


def cell_specs(arch: str, shape_name: str, mesh, *, tcfg=None,
               variant: str | None = None) -> dict[str, Any]:
    """Build the lowerable (fn, args, shardings) for one dry-run cell.

    variants: None (baseline) | 'tiered_experts' (§Perf A) | 'fsdp' (§Perf B)
    """
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        return {"skip": skip}
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    b, s = shp["global_batch"], shp["seq_len"]
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bspec = P(dp, None)
    label = f"{arch}:{shape_name}"

    if variant == "fsdp":
        shapes = jax.eval_shape(lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
        from repro.dist.sharding import param_pspecs as pps
        specs = pps(shapes, mesh, fsdp=True)
        params = jax.tree.map(
            lambda sd, p: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh, p)),
            shapes, specs, is_leaf=lambda x: hasattr(x, "shape"))
        pspecs = specs
    else:
        params, pspecs = _abstract_params(cfg, mesh)
    if variant == "tiered_experts":
        assert cfg.moe is not None, "tiered_experts needs a MoE arch"
        params = _tiered_expert_params(cfg, params, mesh)
    ep = train_step_mod._ep_context(cfg, mesh)

    if shp["kind"] == "train":
        from repro.optim.optimizers import OptConfig
        tcfg = tcfg or train_step_mod.TrainConfig(
            opt=OptConfig(kind="adafactor" if cfg.moe else "adamw"),
            microbatches=_microbatches(cfg, b, s, mesh),
            local_grads=(variant == "local_grads"))
        state = train_step_mod.make_state_shapes(cfg, tcfg)
        st_sh = train_step_mod.state_shardings(state, mesh,
                                               fsdp=(variant == "fsdp"))
        state = jax.tree.map(
            lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
            state, st_sh)
        batch = {
            "tokens": _sds((b, s), jnp.int32, mesh, bspec),
            "labels": _sds((b, s), jnp.int32, mesh, bspec),
        }
        if cfg.n_aux_tokens:
            batch["aux_embeds"] = _sds((b, cfg.n_aux_tokens, cfg.d_model),
                                       jnp.bfloat16, mesh, P(dp, None, None))
        fn = train_step_mod.build_train_step(cfg, mesh, tcfg)
        return {"fn": fn, "args": (state, batch), "label": label,
                "donate": (0,), "tcfg": tcfg, "cfg": cfg}

    if shp["kind"] == "prefill":
        def fn(params, batch):
            logits, _ = dec.prefill(cfg, params, batch["tokens"],
                                    aux_embeds=batch.get("aux_embeds"),
                                    ep_axes=ep)
            return logits
        batch = {"tokens": _sds((b, s), jnp.int32, mesh, bspec)}
        if cfg.n_aux_tokens:
            batch["aux_embeds"] = _sds((b, cfg.n_aux_tokens, cfg.d_model),
                                       jnp.bfloat16, mesh, P(dp, None, None))
        return {"fn": fn, "args": (params, batch), "label": label, "cfg": cfg}

    if shp["kind"] == "decode":
        cache_shapes = jax.eval_shape(
            lambda: dec.init_cache(cfg, b, s, dtype=jnp.bfloat16))
        # decode_32k baseline: batch over DP, k/v sequence over 'model'
        # (XLA all-gathers per layer — the hillclimb replaces this with
        # sharded flash-decode); rule set lives in repro.dist.sharding
        cspecs = cache_pspecs(cache_shapes, mesh)
        cache = jax.tree.map(
            lambda sd, sp: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)),
            cache_shapes, cspecs)
        token = _sds((b, 1), jnp.int32, mesh, bspec)
        aux = None
        if cfg.n_aux_tokens:
            n_aux = cfg.n_aux_tokens
            aux = _sds((b, n_aux, cfg.d_model), jnp.bfloat16, mesh,
                       P(dp, None, None))

        def fn(params, cache, token, aux_embeds=None):
            return dec.decode_step(cfg, params, cache, token,
                                   aux_embeds=aux_embeds, ep_axes=ep)
        args = (params, cache, token) + ((aux,) if aux is not None else ())
        return {"fn": fn, "args": args, "label": label, "donate": (1,),
                "cfg": cfg}

    # long_500k paged decode
    n_slots = HOT_SLOTS
    cache_shapes = jax.eval_shape(
        lambda: dec.init_paged_cache(cfg, b, n_slots, PAGE_T,
                                     dtype=jnp.bfloat16))
    slot_axes = tuple(mesh.axis_names)
    # long_500k: page slots sharded over ALL mesh axes (B=1)
    cspecs = cache_pspecs(cache_shapes, mesh, slot_axes=slot_axes)
    cache = jax.tree.map(
        lambda sd, sp: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)),
        cache_shapes, cspecs)
    token = _sds((b, 1), jnp.int32, mesh, P(None, None))
    smesh = {"mesh": mesh, "axes": slot_axes}

    def fn(params, cache, token):
        return dec.decode_step_paged(cfg, params, cache, token,
                                     page_t=PAGE_T, ep_axes=ep, smesh=smesh)
    return {"fn": fn, "args": (params, cache, token), "label": label,
            "donate": (1,), "cfg": cfg}


