"""Paper Fig. 3: tiered-memory characterization.

(a) tier latency gap (cost model constants vs paper's measured 430ns/120ns);
(b) end-to-end slowdown running fully on the slow tier vs fully fast —
reproduced by pinning the simulator's fast ratio to ~0 / 1.
"""
from __future__ import annotations

from repro.core.simulator import MemModel, WORKLOADS, run_sim

from benchmarks.common import BLOCK, N_PAGES, SKETCH_W, Timer, emit

WL = ["deathstar", "pagerank", "xsbench", "gups"]


def run(quick: bool = False):
    mem = MemModel()
    emit("fig03a_latency_ratio", 0.0,
         f"slow/fast={mem.slow_lat/mem.fast_lat:.2f}x "
         f"(paper: 430ns/120ns=3.6x)")
    n_blocks = 30 if quick else 60
    with Timer() as t:
        for wl in WL:
            rs = {}
            for ratio, tag in ((0.999, "fast"), (0.001, "slow")):
                stream = WORKLOADS[wl](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=2)
                rs[tag] = run_sim("first-touch", stream, n_pages=N_PAGES,
                                  fast_ratio=ratio, sketch_width=SKETCH_W)
            slowdown = rs["slow"].runtime / rs["fast"].runtime - 1.0
            emit(f"fig03b_slowdown_{wl}", t.s * 1e6 / len(WL),
                 f"slow-tier-only +{100*slowdown:.0f}% (paper: +64%..+295%)")


if __name__ == "__main__":
    run()
