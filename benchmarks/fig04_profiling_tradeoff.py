"""Paper Fig. 4: profiling-mechanism analysis.

(a) PTE-scan time/space-resolution vs overhead frontier against the NeoProf
    point (hot-set recall vs modeled overhead);
(b) TLB-proxy vs true-access dispersion: correlation between per-page
    first-touch epochs counts (what PTE-scan sees) and true access counts;
(c) PEBS sampling-rate vs overhead + recall curve.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import BaselineCosts, PebsSampler, PteScan
from repro.core.neoprof import NeoProfCommands, NeoProfParams, neoprof_init, neoprof_observe
from repro.core.sketch import SketchParams
from repro.core.simulator import WORKLOADS

from benchmarks.common import BLOCK, N_PAGES, Timer, emit


def _hot_set(n_pages):
    return set(range(n_pages - n_pages // 10, n_pages))


def _recall(detected, hot):
    return len(set(map(int, detected)) & hot) / max(len(hot), 1)


def run(quick: bool = False):
    n_blocks = 24 if quick else 48
    hot = _hot_set(N_PAGES)
    costs = BaselineCosts()

    # (a) PTE-scan frontier: scan period in blocks (time resolution)
    with Timer() as t:
        for period in (2, 8, 32):
            ps = PteScan(N_PAGES, 0, hot_after_epochs=2)
            stream = WORKLOADS["gups"](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=4)
            det: set = set()
            for b, pages in enumerate(stream):
                ps.observe(pages)
                if (b + 1) % period == 0:
                    det |= set(ps.epoch_end().tolist())
            emit(f"fig04a_ptescan_period{period}", t.s * 1e6,
                 f"recall={_recall(det, hot):.2f} overhead_ms="
                 f"{ps.overhead*1e3:.2f}")

    # NeoProf point: full recall at ~0 overhead
    pp = NeoProfParams(sketch=SketchParams(width=1 << 12))
    prof = neoprof_init(pp)
    cmd = NeoProfCommands(pp)
    prof = cmd.set_threshold(prof, 16)
    det = set()
    import jax.numpy as jnp
    stream = WORKLOADS["gups"](n_pages=N_PAGES, block=BLOCK,
                               n_blocks=n_blocks, seed=4)
    n_reads = 0
    for pages in stream:
        prof = neoprof_observe(prof, jnp.asarray(pages.astype(np.int32)), pp)
        prof, hotpages = cmd.drain_hotpages(prof)
        det |= set(hotpages.tolist())
        n_reads += 1
    emit("fig04a_neoprof", 0.0,
         f"recall={_recall(det, hot):.2f} overhead_ms="
         f"{n_reads*costs.neoprof_readout*1e3:.3f}")

    # (b) TLB-proxy dispersion: epoch-binary counts vs true counts
    stream = WORKLOADS["silo"](n_pages=N_PAGES, block=BLOCK,
                               n_blocks=n_blocks, seed=5)
    true = np.zeros(N_PAGES)
    tlbish = np.zeros(N_PAGES)
    seen_this_epoch = np.zeros(N_PAGES, bool)
    for b, pages in enumerate(stream):
        np.add.at(true, pages, 1)
        first = ~seen_this_epoch[pages]
        tlbish[pages[first]] += 1
        seen_this_epoch[pages] = True
        if (b + 1) % 8 == 0:
            seen_this_epoch[:] = False
    mask = true > 0
    corr = np.corrcoef(true[mask], tlbish[mask])[0, 1]
    emit("fig04b_tlb_vs_llc_corr", 0.0,
         f"pearson={corr:.2f} (paper: high dispersion => weak proxy)")

    # (c) PEBS: rate vs overhead + recall
    for interval in (10, 100, 1000, 10000):
        pb = PebsSampler(N_PAGES, 0, sample_interval=interval,
                         promote_after=2)
        stream = WORKLOADS["gups"](n_pages=N_PAGES, block=BLOCK,
                                   n_blocks=n_blocks, seed=6)
        det = set()
        n_acc = 0
        for pages in stream:
            det |= set(pb.observe(pages).tolist())
            n_acc += len(pages)
        slowdown = pb.overhead / (n_acc * 200e-9)
        emit(f"fig04c_pebs_interval{interval}", 0.0,
             f"recall={_recall(det, hot):.2f} overhead_frac={slowdown:.3f}")


if __name__ == "__main__":
    run()
