"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract.
``--quick`` shrinks streams 4x for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


MODULES = [
    "fig03_tier_gap",
    "fig04_profiling_tradeoff",
    "fig11_main_speedup",
    "fig12_ratio_sweep",
    "fig13_traffic",
    "fig14_policy_dynamics",
    "fig15_sensitivity",
    "fig16_convergence",
    "kernel_bench",
    "serve_bench",
    "traffic_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    failures = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        print(f"# === benchmarks.{name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(quick=args.quick)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
