"""Serving benchmark: tokens/s + tier hit rates + measured migration bytes/s.

Drives the ServeEngine's multi-resource tiering path (paged KV + embedding
rows, plus experts on the MoE arch) on smoke-scale models and records the
perf trajectory into ``BENCH_serve.json`` — one row per served arch with
throughput, the unified TierStats snapshot of every registered resource,
and the migration data plane's measured traffic (payload bytes the daemon
epochs physically moved, next to the hit rates they bought).  The decode
steps read embedding/expert rows in-jit through the tiered store and the
"kv" resource profiles kernel-exported softmax mass (DESIGN.md §10).

It also runs the hotness-fidelity A/B (the ``mass_ab`` section): the
zipf-hot trace served twice, once with the old ``page_len`` fill proxy and
once with the kernel-true mass stream — identical trace, identical model,
only the profiling stream differs.  CI gates kernel >= fill on the
steady-state KV hit rate (validate_bench.py): the paper's claim that
proxy quality, not policy, limits tiering, measured in-repo.

The emitted schema is documented key-by-key in benchmarks/README.md and
validated in CI by benchmarks/validate_bench.py.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant
from repro.workloads import DEFAULT_TENANTS, make_trace, play

from benchmarks.common import emit, steady_start, update_bench_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

CASES = [
    ("llama3.2-3b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                         migration_interval=4, resources=("embeddings",),
                         embed_hot_slots=4), 2, 16),
    ("kimi-k2-1t-a32b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                             migration_interval=4,
                             resources=("experts", "embeddings"),
                             expert_hot_slots=2, embed_hot_slots=2), 2, 16),
]

# The fidelity A/B: kv-only lane serving over the zipf-hot trace, fill proxy
# vs kernel mass (ServeConfig.kv_mass_source) — everything else identical.
AB_ARCH = "llama3.2-3b"
AB_ARRIVAL = "mmpp"
AB_KW = dict(max_seq=64, paged=True, page_t=4, hot_slots=6,
             migration_interval=4, kv_quota=16, kv_tier_slots=12,
             kv_mass_threshold=0.01, lanes=4, kv_segments=6)


def _bench(arch: str, scfg_kw: dict, batch: int, prompt_len: int,
           n_tokens: int) -> dict:
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(**scfg_kw))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    # untimed warmup: one short generate traces+compiles every jitted body
    # the timed run uses (prefill scan, decode step, flush scatter), so the
    # throughput window below measures steady-state execution, not XLA.
    # The trace/compile wall is recorded separately as ``compile_s``.
    t0 = time.perf_counter()
    eng.generate(prompts, n_tokens=2)
    compile_s = time.perf_counter() - t0
    moved0 = {n: r["migration_bytes"]
              for n, r in eng.tier_stats().items()}
    t0 = time.perf_counter()
    out = eng.generate(prompts, n_tokens=n_tokens)
    dt = time.perf_counter() - t0
    assert out.shape == (batch, n_tokens)
    resources = eng.tier_stats()
    # migration traffic of the timed window only (warmup bytes excluded)
    moved = sum(r["migration_bytes"] - moved0[n]
                for n, r in resources.items())
    return {
        "arch": arch,
        "batch": batch,
        "prompt_len": prompt_len,
        "n_tokens": n_tokens,
        "compile_s": compile_s,
        "tokens_per_s": batch * n_tokens / dt,
        "wall_s": dt,
        "migration_bytes": moved,
        "migration_bytes_per_s": moved / dt,
        "resources": resources,
    }


def _kv_counts(eng) -> tuple[int, int]:
    row = eng.tier_stats()["kv"]
    return row["fast_reads"], row["slow_reads"]


def _mass_ab_run(source: str, n_steps: int) -> dict:
    """One arm of the fidelity A/B: the zipf-hot trace through the lane
    scheduler with the given "kv" mass source; the steady-state window is
    ``common.steady_start`` — the same convention traffic_bench uses."""
    cfg = get_smoke_config(AB_ARCH)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      ServeConfig(**AB_KW, kv_mass_source=source))
    sched = Scheduler(eng, [Tenant(t.name, t.weight) for t in DEFAULT_TENANTS],
                      SchedConfig(preempt_patience=24))
    trace = make_trace("zipf-hot", n_steps=n_steps, vocab=cfg.vocab, seed=0,
                       arrival=AB_ARRIVAL)
    mid: list[tuple[int, int]] = []

    def snap(s):
        if not mid and s.step_count >= steady_start(trace.n_steps):
            mid.append(_kv_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap)
    wall = time.perf_counter() - t0
    rep = sched.report()
    f1, s1 = mid[0]
    f2, s2 = _kv_counts(eng)
    return {
        "kv_mass_source": source,
        "steps": rep["steps"],
        "tokens": rep["tokens"],
        "wall_s": wall,
        "kv_hit": f2 / max(f2 + s2, 1),
        "kv_hit_steady": (f2 - f1) / max((f2 + s2) - (f1 + s1), 1),
        "kv_promoted": rep["resources"]["kv"]["promoted"],
        "migration_bytes": rep["resources"]["kv"]["migration_bytes"],
    }


def _mass_ab(quick: bool) -> dict:
    # even the quick arm needs enough steps for the placement map to
    # converge past its cold start — the fidelity signal lives in the
    # steady-state window, not the warmup
    n_steps = 160 if quick else 320
    rows = {src: _mass_ab_run(src, n_steps) for src in ("fill", "kernel")}
    return {"arch": AB_ARCH, "trace": "zipf-hot", "arrival": AB_ARRIVAL,
            "lanes": AB_KW["lanes"], "seed": 0, "trace_steps": n_steps,
            "fill": rows["fill"], "kernel": rows["kernel"]}


def run(quick: bool = False):
    n_tokens = 8 if quick else 32
    rows = [_bench(arch, kw, batch, plen, n_tokens)
            for arch, kw, batch, plen in CASES]
    for r in rows:
        hits = " ".join(f"{name}_hit={res['hit_rate']:.3f}"
                        for name, res in sorted(r["resources"].items()))
        emit(f"serve_{r['arch']}", r["wall_s"] * 1e6 / (r['batch'] * n_tokens),
             f"tok_s={r['tokens_per_s']:.1f} "
             f"mig_B_s={r['migration_bytes_per_s']:.0f} {hits}")
    ab = _mass_ab(quick)
    emit("serve_mass_ab", 0.0,
         f"kv_hit_steady kernel={ab['kernel']['kv_hit_steady']:.3f} "
         f"fill={ab['fill']['kv_hit_steady']:.3f} "
         f"gap={ab['kernel']['kv_hit_steady'] - ab['fill']['kv_hit_steady']:+.3f}")
    update_bench_json(OUT_PATH, quick=quick, cases=rows, mass_ab=ab)
    emit("serve_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return rows


if __name__ == "__main__":
    run()
