"""Serving benchmark: tokens/s + tier hit rates + measured migration bytes/s.

Run with ``--compress`` for the codec A/B (the ``compress`` section): the
same lane-scheduler trace served under each slow-store codec
(``none`` / ``fp32`` / ``int8``, tiering/codec.py, DESIGN.md §14) at the
same page quota, gating the wire-byte cut, hit-rate parity, logit drift,
and the zero1 ``compress_collective`` parity + collective byte cut.

Drives the ServeEngine's multi-resource tiering path (paged KV + embedding
rows, plus experts on the MoE arch) on smoke-scale models and records the
perf trajectory into ``BENCH_serve.json`` — one row per served arch with
throughput, the unified TierStats snapshot of every registered resource,
and the migration data plane's measured traffic (payload bytes the daemon
epochs physically moved, next to the hit rates they bought).  The decode
steps read embedding/expert rows in-jit through the tiered store and the
"kv" resource profiles kernel-exported softmax mass (DESIGN.md §10).

It also runs the hotness-fidelity A/B (the ``mass_ab`` section): the
zipf-hot trace served twice, once with the old ``page_len`` fill proxy and
once with the kernel-true mass stream — identical trace, identical model,
only the profiling stream differs.  CI gates kernel >= fill on the
steady-state KV hit rate (validate_bench.py): the paper's claim that
proxy quality, not policy, limits tiering, measured in-repo.

The emitted schema is documented key-by-key in benchmarks/README.md and
validated in CI by benchmarks/validate_bench.py.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant
from repro.workloads import DEFAULT_TENANTS, make_trace, play

from benchmarks.common import emit, steady_start, update_bench_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

CASES = [
    ("llama3.2-3b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                         migration_interval=4, resources=("embeddings",),
                         embed_hot_slots=4), 2, 16),
    ("kimi-k2-1t-a32b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                             migration_interval=4,
                             resources=("experts", "embeddings"),
                             expert_hot_slots=2, embed_hot_slots=2), 2, 16),
]

# The fidelity A/B: kv-only lane serving over the zipf-hot trace, fill proxy
# vs kernel mass (ServeConfig.kv_mass_source) — everything else identical.
AB_ARCH = "llama3.2-3b"
AB_ARRIVAL = "mmpp"
AB_KW = dict(max_seq=64, paged=True, page_t=4, hot_slots=6,
             migration_interval=4, kv_quota=16, kv_tier_slots=12,
             kv_mass_threshold=0.01, lanes=4, kv_segments=6)

# The codec A/B (DESIGN.md §14): the fidelity-A/B serving shape plus tiered
# embeddings, so both the KV flush path and the in-jit embedding read path
# run through the slow-store codec.  The fp arm is the ``fp32`` codec — a
# full-precision store that is numerically the identity for the engine's
# bf16 rows — so the int8/fp32 byte ratio measures compression against a
# true full-precision slow tier at the SAME page quota.
COMPRESS_ARMS = ("none", "fp32", "int8")
COMPRESS_KW = dict(AB_KW, resources=("embeddings",), embed_hot_slots=6,
                   embed_quota=8, embed_rows_per_page=8)
# Logit-drift probe: single-request decode sized to stay inside the paged
# ring (prompt + steps <= (hot_slots-1)*page_t), so drift isolates the
# embedding read path's dequantization.
# The overlap A/B (DESIGN.md §15): the MoE smoke arch served twice —
# synchronous data plane vs the double-buffered async one — so the gate
# covers every resource class at once (paged KV + experts + embeddings).
# Identical model/trace/quota: same tokens, same migration bytes; only
# WHEN decode pays for the copies differs (sync: a metered block every
# epoch; async: the copy overlaps decode and the commit is a pointer swap).
OVERLAP_ARCH = "kimi-k2-1t-a32b"
OVERLAP_KW = dict(max_seq=64, paged=True, page_t=4, hot_slots=6,
                  migration_interval=4, kv_quota=16,
                  resources=("experts", "embeddings"),
                  expert_hot_slots=2, embed_hot_slots=2)
OVERLAP_STALL_RATIO = 0.25   # async stall gate: <= 1/4 of the sync arm's

PROBE_PROMPT, PROBE_STEPS = 12, 8
PROBE_DRIFT_BOUND = 0.25     # max |logit(int8) - logit(none)|, fp32 compare
COMPRESS_BYTES_RATIO = 0.35  # int8/fp32 migration-byte gate (expect ~0.26)
COMPRESS_HIT_EPS = 0.02      # steady hit-rate degradation allowance
ZERO1_STEPS = 6
ZERO1_DRIFT_TOL = 1e-3       # max |param(fp32) - param(int8+EF)| after run
ZERO1_BYTES_RATIO = 0.30     # collective byte gate (expect ~0.25)


def _bench(arch: str, scfg_kw: dict, batch: int, prompt_len: int,
           n_tokens: int) -> dict:
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(**scfg_kw))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    # untimed warmup: one short generate traces+compiles every jitted body
    # the timed run uses (prefill scan, decode step, flush scatter), so the
    # throughput window below measures steady-state execution, not XLA.
    # The trace/compile wall is recorded separately as ``compile_s``.
    t0 = time.perf_counter()
    eng.generate(prompts, n_tokens=2)
    compile_s = time.perf_counter() - t0
    moved0 = {n: r["migration_bytes"]
              for n, r in eng.tier_stats().items()}
    t0 = time.perf_counter()
    out = eng.generate(prompts, n_tokens=n_tokens)
    dt = time.perf_counter() - t0
    assert out.shape == (batch, n_tokens)
    resources = eng.tier_stats()
    # migration traffic of the timed window only (warmup bytes excluded)
    moved = sum(r["migration_bytes"] - moved0[n]
                for n, r in resources.items())
    return {
        "arch": arch,
        "batch": batch,
        "prompt_len": prompt_len,
        "n_tokens": n_tokens,
        "compile_s": compile_s,
        "tokens_per_s": batch * n_tokens / dt,
        "wall_s": dt,
        "migration_bytes": moved,
        "migration_bytes_per_s": moved / dt,
        "resources": resources,
    }


def _kv_counts(eng) -> tuple[int, int]:
    row = eng.tier_stats()["kv"]
    return row["fast_reads"], row["slow_reads"]


def _mass_ab_run(source: str, n_steps: int) -> dict:
    """One arm of the fidelity A/B: the zipf-hot trace through the lane
    scheduler with the given "kv" mass source; the steady-state window is
    ``common.steady_start`` — the same convention traffic_bench uses."""
    cfg = get_smoke_config(AB_ARCH)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      ServeConfig(**AB_KW, kv_mass_source=source))
    sched = Scheduler(eng, [Tenant(t.name, t.weight) for t in DEFAULT_TENANTS],
                      SchedConfig(preempt_patience=24))
    trace = make_trace("zipf-hot", n_steps=n_steps, vocab=cfg.vocab, seed=0,
                       arrival=AB_ARRIVAL)
    mid: list[tuple[int, int]] = []

    def snap(s):
        if not mid and s.step_count >= steady_start(trace.n_steps):
            mid.append(_kv_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap)
    wall = time.perf_counter() - t0
    rep = sched.report()
    f1, s1 = mid[0]
    f2, s2 = _kv_counts(eng)
    return {
        "kv_mass_source": source,
        "steps": rep["steps"],
        "tokens": rep["tokens"],
        "wall_s": wall,
        "kv_hit": f2 / max(f2 + s2, 1),
        "kv_hit_steady": (f2 - f1) / max((f2 + s2) - (f1 + s1), 1),
        "kv_promoted": rep["resources"]["kv"]["promoted"],
        "migration_bytes": rep["resources"]["kv"]["migration_bytes"],
    }


def _mass_ab(quick: bool) -> dict:
    # even the quick arm needs enough steps for the placement map to
    # converge past its cold start — the fidelity signal lives in the
    # steady-state window, not the warmup
    n_steps = 160 if quick else 320
    rows = {src: _mass_ab_run(src, n_steps) for src in ("fill", "kernel")}
    return {"arch": AB_ARCH, "trace": "zipf-hot", "arrival": AB_ARRIVAL,
            "lanes": AB_KW["lanes"], "seed": 0, "trace_steps": n_steps,
            "fill": rows["fill"], "kernel": rows["kernel"]}


def _tier_counts(eng) -> dict[str, tuple[int, int]]:
    return {n: (row["fast_reads"], row["slow_reads"])
            for n, row in eng.tier_stats().items()}


def _compress_run(codec: str, n_steps: int) -> tuple[dict, list]:
    """One codec arm: the zipf-hot trace through the lane scheduler with the
    slow stores encoded as ``codec``; same trace, same page quota, same
    model — only the wire format differs.  Returns the arm row plus the
    finished requests' exact output streams (for the bit-exactness gate)."""
    cfg = get_smoke_config(AB_ARCH)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(**COMPRESS_KW,
                                               slow_codec=codec))
    sched = Scheduler(eng, [Tenant(t.name, t.weight) for t in DEFAULT_TENANTS],
                      SchedConfig(preempt_patience=24))
    trace = make_trace("zipf-hot", n_steps=n_steps, vocab=cfg.vocab, seed=0,
                       arrival=AB_ARRIVAL)
    mid: list[dict] = []

    def snap(s):
        if not mid and s.step_count >= steady_start(trace.n_steps):
            mid.append(_tier_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap)
    wall = time.perf_counter() - t0
    rep = sched.report()
    assert rep["completed"] == rep["submitted"], "requests left undrained"
    after = _tier_counts(eng)
    steady = {}
    for name, (f1, s1) in mid[0].items():
        f2, s2 = after[name]
        steady[name] = (f2 - f1) / max((f2 + s2) - (f1 + s1), 1)
    resources = rep["resources"]
    outputs = [(r.tenant, r.prompt.tobytes(), tuple(r.out))
               for r in sched.finished]
    return {
        "codec": codec,
        "steps": rep["steps"],
        "tokens": rep["tokens"],
        "wall_s": wall,
        "hit_steady": steady,
        "wire_row_bytes": {n: eng.daemon[n].mem.row_bytes
                           for n in resources},
        "migration_bytes": sum(r["migration_bytes"]
                               for r in resources.values()),
        "max_epoch_bytes": sum(r["max_epoch_bytes"]
                               for r in resources.values()),
        "quota_bytes": sum(r["quota_bytes"] for r in resources.values()),
        "resources": resources,
    }, outputs


def _logit_probe() -> dict:
    """Single-request decode under each codec, logits captured per step.

    The ``fp32`` arm must match ``none`` EXACTLY (bf16 -> fp32 -> bf16 is
    the identity — this is what makes it the fp arm, and what proves the
    codec plumbing itself is transparent); the ``int8`` arm's drift is
    bounded: every embedding row decodes within scale/2 per element.
    """
    cfg = get_smoke_config(AB_ARCH)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(7).integers(
        0, cfg.vocab, (1, PROBE_PROMPT)).astype(np.int32)
    kw = dict(COMPRESS_KW)
    for k in ("lanes", "kv_segments"):
        kw.pop(k)                         # single-request mode
    logits, tokens = {}, {}
    for codec in COMPRESS_ARMS:
        eng = ServeEngine(cfg, params, ServeConfig(**kw, slow_codec=codec))
        tok = eng.prefill(prompt)
        steps, toks = [], [int(tok[0])]
        for _ in range(PROBE_STEPS):
            lg = eng._advance(jnp.asarray(tok)[:, None])
            steps.append(np.asarray(lg[:, -1], np.float32))
            tok = np.asarray(jnp.argmax(lg[:, -1], -1))
            toks.append(int(tok[0]))
        logits[codec] = np.stack(steps)
        tokens[codec] = toks
    drift_fp32 = float(np.max(np.abs(logits["fp32"] - logits["none"])))
    drift_int8 = float(np.max(np.abs(logits["int8"] - logits["none"])))
    return {
        "prompt_len": PROBE_PROMPT,
        "n_steps": PROBE_STEPS,
        "tokens_match_none_fp32": tokens["fp32"] == tokens["none"],
        "drift_fp32": drift_fp32,
        "drift_int8": drift_int8,
        "drift_bound": PROBE_DRIFT_BOUND,
    }


def _zero1_compress() -> dict:
    """The codec subsystem's second consumer: ZeRO-1's delta gather
    quantized per shard with error feedback vs the fp32 baseline —
    same grads, same schedule, parity-bounded params, ~4x fewer
    collective bytes (optim/zero1.py)."""
    from repro.optim import zero1
    from repro.optim.optimizers import OptConfig

    cfg = OptConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                    total_steps=100)
    rng = np.random.default_rng(11)
    params = {"w": jnp.asarray(rng.normal(size=(32, 48)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(96,)), jnp.float32)}
    st_f, spec = zero1.zero1_init(params, None)
    st_c, _ = zero1.zero1_init(params, None, compress_collective=True)
    pf, pc = params, params
    bytes_f = bytes_c = 0
    for i in range(ZERO1_STEPS):
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.normal(size=p.shape) * 0.1,
                                  jnp.float32), params)
        pf, st_f, om_f = zero1.zero1_update(cfg, pf, grads, st_f, spec, None)
        pc, st_c, om_c = zero1.zero1_update(cfg, pc, grads, st_c, spec, None,
                                            compress_collective=True)
        bytes_f += int(om_f["collective_bytes"])
        bytes_c += int(om_c["collective_bytes"])
    drift = max(float(jnp.max(jnp.abs(pf[k] - pc[k]))) for k in params)
    return {
        "steps": ZERO1_STEPS,
        "padded": spec.padded,
        "bytes_fp32": bytes_f,
        "bytes_int8": bytes_c,
        "byte_ratio": bytes_c / bytes_f,
        "byte_ratio_bound": ZERO1_BYTES_RATIO,
        "update_drift": drift,
        "drift_tolerance": ZERO1_DRIFT_TOL,
    }


def _compress_ab(quick: bool) -> dict:
    n_steps = 160 if quick else 320
    arms, outputs = {}, {}
    for codec in COMPRESS_ARMS:
        arms[codec], outputs[codec] = _compress_run(codec, n_steps)
    ratio = (arms["int8"]["migration_bytes"]
             / max(arms["fp32"]["migration_bytes"], 1))
    return {
        "arch": AB_ARCH, "trace": "zipf-hot", "arrival": AB_ARRIVAL,
        "lanes": COMPRESS_KW["lanes"], "seed": 0, "trace_steps": n_steps,
        "quick": quick,
        "arms": arms,
        "bytes_ratio_int8_fp32": ratio,
        "bytes_ratio_bound": COMPRESS_BYTES_RATIO,
        "hit_eps": COMPRESS_HIT_EPS,
        # the bit-exactness gate: the fp32 store changes NOTHING about the
        # served stream (every request's every output token identical),
        # which also certifies the codec plumbing as the identity under
        # codec="none" — the pre-codec data path
        "tokens_match_none_fp32": outputs["fp32"] == outputs["none"],
        "probe": _logit_probe(),
        "zero1": _zero1_compress(),
    }


def _overlap_run(async_on: bool, batch: int, prompt_len: int,
                 n_tokens: int) -> tuple[np.ndarray, dict]:
    cfg = get_smoke_config(OVERLAP_ARCH)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(async_migration=async_on,
                                               **OVERLAP_KW))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    eng.generate(prompts, n_tokens=2)       # trace+compile warmup
    compile_s = time.perf_counter() - t0
    # close the warmup's books so the timed window meters only itself: the
    # forced finalize commits any epoch the warmup left in flight (its
    # block time lands in the warmup stall baseline, subtracted below)
    eng.daemon.finalize()
    res0 = eng.tier_stats()
    t0 = time.perf_counter()
    out = eng.generate(prompts, n_tokens=n_tokens)
    wall = time.perf_counter() - t0
    eng.daemon.finalize()                   # equal-bytes accounting barrier
    res = eng.tier_stats()
    moved = sum(r["migration_bytes"] - res0[n]["migration_bytes"]
                for n, r in res.items())
    stall = sum(r["stall_s"] - res0[n]["stall_s"] for n, r in res.items())
    return out, {
        "mode": "async" if async_on else "sync",
        "steps": n_tokens,
        "compile_s": compile_s,
        "wall_s": wall,
        "tokens_per_s": batch * n_tokens / wall,
        "stall_s": stall,
        "migration_bytes": moved,
        "resources": res,
    }


def _overlap_ab(quick: bool) -> dict:
    batch, prompt_len = 2, 12
    n_tokens = 16 if quick else 32
    out_sync, arm_sync = _overlap_run(False, batch, prompt_len, n_tokens)
    out_async, arm_async = _overlap_run(True, batch, prompt_len, n_tokens)
    return {
        "arch": OVERLAP_ARCH,
        "batch": batch,
        "prompt_len": prompt_len,
        "n_tokens": n_tokens,
        "tokens_match": bool(np.array_equal(out_sync, out_async)),
        "stall_ratio_bound": OVERLAP_STALL_RATIO,
        "sync": arm_sync,
        "async": arm_async,
    }


def run_overlap(quick: bool = False) -> dict:
    ov = _overlap_ab(quick)
    s, a = ov["sync"], ov["async"]
    emit("serve_overlap", 0.0,
         f"match={ov['tokens_match']} "
         f"stall sync={s['stall_s']:.3f}s async={a['stall_s']:.3f}s "
         f"(gate <= {ov['stall_ratio_bound']} x) "
         f"bytes sync={s['migration_bytes']} async={a['migration_bytes']}")
    update_bench_json(OUT_PATH, overlap=ov)
    emit("serve_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return ov


def run_compress(quick: bool = False) -> dict:
    comp = _compress_ab(quick)
    emit("serve_compress_bytes", 0.0,
         f"int8/fp32 mig bytes={comp['bytes_ratio_int8_fp32']:.3f} "
         f"(gate <= {comp['bytes_ratio_bound']}) "
         f"int8={comp['arms']['int8']['migration_bytes']} "
         f"fp32={comp['arms']['fp32']['migration_bytes']}")
    emit("serve_compress_fidelity", 0.0,
         f"match(none,fp32)={comp['tokens_match_none_fp32']} "
         f"drift fp32={comp['probe']['drift_fp32']:.2e} "
         f"int8={comp['probe']['drift_int8']:.3f} "
         f"(gate <= {comp['probe']['drift_bound']})")
    z = comp["zero1"]
    emit("serve_compress_zero1", 0.0,
         f"drift={z['update_drift']:.2e} (tol {z['drift_tolerance']}) "
         f"bytes ratio={z['byte_ratio']:.3f} (gate <= {z['byte_ratio_bound']})")
    update_bench_json(OUT_PATH, compress=comp)
    emit("serve_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return comp


def run(quick: bool = False):
    n_tokens = 8 if quick else 32
    rows = [_bench(arch, kw, batch, plen, n_tokens)
            for arch, kw, batch, plen in CASES]
    for r in rows:
        hits = " ".join(f"{name}_hit={res['hit_rate']:.3f}"
                        for name, res in sorted(r["resources"].items()))
        emit(f"serve_{r['arch']}", r["wall_s"] * 1e6 / (r['batch'] * n_tokens),
             f"tok_s={r['tokens_per_s']:.1f} "
             f"mig_B_s={r['migration_bytes_per_s']:.0f} {hits}")
    ab = _mass_ab(quick)
    emit("serve_mass_ab", 0.0,
         f"kv_hit_steady kernel={ab['kernel']['kv_hit_steady']:.3f} "
         f"fill={ab['fill']['kv_hit_steady']:.3f} "
         f"gap={ab['kernel']['kv_hit_steady'] - ab['fill']['kv_hit_steady']:+.3f}")
    update_bench_json(OUT_PATH, quick=quick, cases=rows, mass_ab=ab)
    emit("serve_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="shorter traces / fewer decode tokens")
    ap.add_argument("--compress", action="store_true",
                    help="run only the codec A/B (the `compress` section)")
    ap.add_argument("--overlap", action="store_true",
                    help="run only the async-migration A/B (the `overlap` "
                         "section, DESIGN.md §15)")
    ns = ap.parse_args()
    if ns.compress:
        run_compress(quick=ns.quick)
    elif ns.overlap:
        run_overlap(quick=ns.quick)
    else:
        run(quick=ns.quick)
