"""Serving benchmark: tokens/s + tier hit rates + measured migration bytes/s.

Drives the ServeEngine's multi-resource tiering path (paged KV + embedding
rows, plus experts on the MoE arch) on smoke-scale models and records the
perf trajectory into ``BENCH_serve.json`` — one row per served arch with
throughput, the unified TierStats snapshot of every registered resource,
and the migration data plane's measured traffic (payload bytes the daemon
epochs physically moved, next to the hit rates they bought).

The emitted schema is documented key-by-key in benchmarks/README.md and
validated in CI by benchmarks/validate_bench.py.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine

from benchmarks.common import emit, update_bench_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

CASES = [
    ("llama3.2-3b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                         migration_interval=4, resources=("embeddings",),
                         embed_hot_slots=4), 2, 16),
    ("kimi-k2-1t-a32b", dict(max_seq=256, paged=True, page_t=8, hot_slots=6,
                             migration_interval=4,
                             resources=("experts", "embeddings"),
                             expert_hot_slots=2, embed_hot_slots=2), 2, 16),
]


def _bench(arch: str, scfg_kw: dict, batch: int, prompt_len: int,
           n_tokens: int) -> dict:
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(**scfg_kw))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, n_tokens=n_tokens)
    dt = time.perf_counter() - t0
    assert out.shape == (batch, n_tokens)
    resources = eng.tier_stats()
    moved = sum(r["migration_bytes"] for r in resources.values())
    return {
        "arch": arch,
        "batch": batch,
        "prompt_len": prompt_len,
        "n_tokens": n_tokens,
        "tokens_per_s": batch * n_tokens / dt,
        "wall_s": dt,
        "migration_bytes": moved,
        "migration_bytes_per_s": moved / dt,
        "resources": resources,
    }


def run(quick: bool = False):
    n_tokens = 8 if quick else 32
    rows = [_bench(arch, kw, batch, plen, n_tokens)
            for arch, kw, batch, plen in CASES]
    for r in rows:
        hits = " ".join(f"{name}_hit={res['hit_rate']:.3f}"
                        for name, res in sorted(r["resources"].items()))
        emit(f"serve_{r['arch']}", r["wall_s"] * 1e6 / (r['batch'] * n_tokens),
             f"tok_s={r['tokens_per_s']:.1f} "
             f"mig_B_s={r['migration_bytes_per_s']:.0f} {hits}")
    update_bench_json(OUT_PATH, quick=quick, cases=rows)
    emit("serve_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return rows


if __name__ == "__main__":
    run()
