"""Paper Fig. 11: end-to-end speedup of NeoMem vs 5 baselines, 8 workloads.

Modeled runtime = access time (hit/miss x tier latency) + migration time +
profiling overhead, driven by the REAL NeoMem components (JAX sketch,
Algorithm-1 policy, TieredStore) on structure-preserving workload streams.
Paper claim under reproduction: 32%..67% geomean speedup.
"""
from __future__ import annotations

from repro.core.simulator import WORKLOADS, geomean_speedup, run_sim

from benchmarks.common import (BLOCK, FAST_RATIO, METHODS, N_BLOCKS, N_PAGES,
                               SIM_KW, Timer, emit)

WL = ["deathstar", "pagerank", "xsbench", "gups", "silo", "btree",
      "bwaves", "roms"]


def run(quick: bool = False):
    n_blocks = N_BLOCKS // 4 if quick else N_BLOCKS
    results: dict[str, dict[str, float]] = {m: {} for m in METHODS}
    hit: dict[str, dict[str, float]] = {m: {} for m in METHODS}
    with Timer() as t:
        for wl in WL:
            for m in METHODS:
                stream = WORKLOADS[wl](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=11)
                r = run_sim(m, stream, n_pages=N_PAGES,
                            fast_ratio=FAST_RATIO, **SIM_KW)
                results[m][wl] = r.runtime
                hit[m][wl] = r.hit_rate
    for m in METHODS:
        if m == "neomem":
            continue
        sp = geomean_speedup([results[m][w] for w in WL],
                             [results["neomem"][w] for w in WL])
        per_wl = " ".join(f"{w}={results[m][w]/results['neomem'][w]:.2f}x"
                          for w in WL)
        emit(f"fig11_geomean_speedup_vs_{m}",
             t.s * 1e6 / (len(WL) * len(METHODS)),
             f"{sp:.3f}x | {per_wl}")
    emit("fig11_neomem_hit_rates", 0.0,
         " ".join(f"{w}={hit['neomem'][w]:.2f}" for w in WL))
    return results


if __name__ == "__main__":
    run()
