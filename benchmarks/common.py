"""Shared benchmark harness config + CSV emission."""
from __future__ import annotations

import json
import os
import time

# scaled-down but structure-preserving defaults (paper: ~4M pages, 1:2 ratio)
N_PAGES = 4096
BLOCK = 2048
N_BLOCKS = 240
FAST_RATIO = 1 / 3           # fast:(fast+slow) = 1:2 (paper default)
SKETCH_W = 1 << 14           # W = 4x page count (paper: 512K for ~4M pages)
QUOTA = 128

# cadence: migration every block, Alg.1 every 4, sketch clear every 16
SIM_KW = dict(quota_pages=QUOTA, sketch_width=SKETCH_W, migration_interval=1,
              threshold_update_period=4, clear_interval=16)

METHODS = ["neomem", "pebs", "tpp", "autonuma", "pte-scan", "first-touch"]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def steady_start(n_steps: int) -> int:
    """First scheduler step of the steady-state measurement window (the
    second half of the arrival window).  ONE convention shared by
    traffic_bench's adaptivity gate and serve_bench's mass-fidelity A/B —
    the two gates must never measure different windows."""
    return n_steps // 2


def update_bench_json(path: str, **sections) -> None:
    """Read-modify-write BENCH_serve.json: replace the given top-level
    sections, preserving every other — the serve and traffic writers stay
    order-independent.  A missing file starts from the minimal schema the
    validator requires (benchmarks/README.md)."""
    doc: dict = {"quick": False, "cases": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc.update(sections)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        self._final = None
        return self

    def __exit__(self, *a):
        self._final = time.perf_counter() - self.t0

    @property
    def s(self) -> float:
        return self._final if self._final is not None \
            else time.perf_counter() - self.t0
