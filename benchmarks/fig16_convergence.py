"""Paper Fig. 16: convergence after a hot-set shift (GUPS).

Claims: NeoMem holds the highest steady-state rate, converges fastest after
the shift; baselines recover slower / noisier.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import WORKLOADS, run_sim

from benchmarks.common import BLOCK, FAST_RATIO, N_BLOCKS, N_PAGES, SIM_KW, Timer, emit


def run(quick: bool = False):
    n_blocks = 160 if quick else 320
    shift = n_blocks // 2
    with Timer() as t:
        for m in ("neomem", "pebs", "tpp", "pte-scan"):
            stream = WORKLOADS["gups"](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=61,
                                       shift_at=shift)
            r = run_sim(m, stream, n_pages=N_PAGES, fast_ratio=FAST_RATIO,
                        collect_trace=True, **SIM_KW)
            # trace hit_rate is cumulative; convert to per-period rates
            tot = [tr["hit_rate"] * (i + 1) for i, tr in enumerate(r.trace)]
            per = [tot[0]] + [tot[i] - tot[i - 1] for i in range(1, len(tot))]
            n = len(per)
            pre = float(np.mean(per[n // 2 - 4:n // 2]))
            post = float(np.mean(per[-4:]))
            dip = float(min(per[n // 2:n // 2 + 4])) if n > 4 else 0.0
            # recovery: periods after the shift until within 90% of pre rate
            rec = next((i for i, h in enumerate(per[n // 2:])
                        if h >= 0.9 * pre), n // 2)
            emit(f"fig16_{m}", t.s * 1e6 / 4,
                 f"pre_shift_hit={pre:.3f} dip={dip:.3f} post_hit={post:.3f} "
                 f"recovery_periods={rec}")


if __name__ == "__main__":
    run()
