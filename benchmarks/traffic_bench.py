"""Traffic benchmark: the full serving stack under multi-tenant traces.

Drives the continuous-batching scheduler (`repro.serve.sched`) over the
three workload traces (`repro.workloads`): zipf-hot, diurnal-shift, and
scan-antagonist, each with >= 2 tenants multiplexed onto one ServeEngine /
NeoMemDaemon.  Per trace it records throughput, p50/p99 per-token latency,
hit rates (lifetime + steady-state second-half window), migration bytes/s,
preemptions, and per-tenant rows into the ``traffic`` section of
``BENCH_serve.json`` (schema in benchmarks/README.md, validated in CI by
validate_bench.py).

The NeoMem adaptivity signal asserted here: identical arrival load, only
token content differs (workloads/traces.py), so the zipf-hot trace must
reach a HIGHER steady-state hit rate than scan-antagonist — a stable hot
set the sketch can find and pin versus an antagonist scan thrashing it.

Arrivals follow the bursty MMPP process (2-state modulated Bernoulli,
workloads/traces.py): same mean offered load as plain Bernoulli, but the
queueing/preemption pressure — and thus the p99 story — lives in the
bursts, as in production serving traces.  The "kv" resource profiles the
kernel-exported softmax mass (ServeConfig.kv_mass_source, DESIGN.md §10);
the fill-vs-kernel fidelity A/B itself lives in serve_bench.py
(``mass_ab``).

Latency is reported SPLIT (DESIGN.md §11): ``ttft_ms`` (arrival -> first
token) and ``tpot_ms`` (inter-token decode gaps) are different
distributions (the old combined ``latency_ms`` row served its one-release
deprecation window and is gone).  Every trace gets an untimed per-case
warmup that traces+compiles
the engine's jitted bodies first, recorded as ``compile_s``, so wall_s /
tokens_per_s / migration_bytes_per_s are steady-state numbers, not XLA.

The ``prefill`` section is the chunked-prefill TTFT A/B (DESIGN.md §11):
one 512-token prompt served twice through the Scheduler on the same seed —
token-at-a-time streaming (prefill_chunk=0) vs the chunked scan
(prefill_chunk=64 >= page_t) — each arm warmed by an untimed full request
first.  CI gates chunked TTFT <= 1/4 of streaming with bit-exact output
tokens (validate_bench.py): the prompt-length tail latency fix, measured.

The ``kv_reuse`` section (DESIGN.md §12) replays the SAME agentic
multi-turn trace through three arms — reuse off, prefix matching, and
substring matching over the content-addressed KV page store
(``ServeConfig.reuse_pages``) — greedy, same seed.  CI gates: bit-exact
outputs across all three arms (reuse must never change tokens), substring
prefill-tokens-saved > 0, substring page-hit rate > prefix (hole-skipping
over evicted / unflushed front-of-history pages is the point), and the
substring arm's steady-state KV hit rate no worse than reuse-off.

The ``disagg`` section (DESIGN.md §13) is the prefill/decode
disaggregation A/B: the prefill-heavy trace (chat = short prompts / long
outputs, doc = long prompts / short outputs) served by the unified
scheduler and by split prefill-worker/decode-worker pools over the
slow-tier hand-off fabric, SAME total lane budget, greedy, one seed.
Decode inter-token gaps are read off each arm's decode-worker virtual
clock and split by whether a chunk scan was in flight.  CI gates:
bit-exact outputs across arms, hand-off bytes > 0 both directions (zero
unified), disagg during-prefill TPOT p50 within 10% of quiet vs the
unified arm measurably degrading on the identical trace.

    PYTHONPATH=src:. python benchmarks/traffic_bench.py \
        [--quick] [--reuse] [--disagg]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant
from repro.workloads import (DEFAULT_TENANTS, TenantProfile, make_trace,
                             play)

from benchmarks.common import emit, steady_start, update_bench_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

# The traffic section runs the three CONTENT kinds (identical arrival load,
# only token content differs — the adaptivity-gap premise); the agentic
# kind has its own session-structured arrivals and is the kv_reuse A/B's
# workload below.
CONTENT_KINDS = ("zipf-hot", "diurnal-shift", "scan-antagonist")
# Ride-along kinds benched with the trio (same schema row, own load shape):
# prod-mixture replays the bimodal public-trace prompt-length mixture
# (repro.workloads §prod-mixture) — zipf-hot content under realistic
# length dispersion.  Selectable via --kinds.
BENCH_KINDS = CONTENT_KINDS + ("prod-mixture",)

ARCH = "llama3.2-3b"
LANES = 4
ARRIVAL = "mmpp"
SERVE_KW = dict(
    max_seq=64, paged=True, page_t=4, hot_slots=6, migration_interval=4,
    resources=("embeddings",), embed_hot_slots=6, embed_quota=8,
    embed_rows_per_page=8,            # 256-token vocab -> 32 row pages
    kv_quota=16, kv_tier_slots=12, kv_mass_threshold=0.01,
    lanes=LANES, kv_segments=LANES + 2,
)

# The chunked-prefill TTFT A/B (DESIGN.md §11): a >= 512-token prompt, chunk
# >= page_t, chunk <= the ring-wrap cap (hot_slots-1)*page_t = 80.
PREFILL_KW = dict(
    max_seq=640, paged=True, page_t=16, hot_slots=6, migration_interval=8,
    kv_quota=16, kv_tier_slots=12, kv_mass_threshold=0.01,
    lanes=2, kv_segments=2,
)
PREFILL_PROMPT = 512
PREFILL_CHUNK = 64
PREFILL_NEW = 4


def _warm_engine(eng, chunk: int = 0) -> float:
    """Untimed per-case warmup: trace+compile the engine's jitted bodies by
    calling the jit wrappers directly on the live lane shapes with every
    lane masked inactive — pure calls, outputs discarded, no daemon or
    cache state touched.  Returns the trace+compile wall (``compile_s``)
    so the timed window that follows is steady-state execution."""
    t0 = time.perf_counter()
    if eng.cache is None:
        eng.start_lanes()
    lanes = eng.scfg.lanes
    idle = jnp.zeros(lanes, bool)
    out = eng._decode_paged(eng.params, eng.cache,
                            jnp.zeros((lanes, 1), jnp.int32),
                            eng._tier_reads(), idle)
    jax.block_until_ready(out[0])
    if chunk > 0:
        out = eng._prefill_paged_jit(eng.params, eng.cache,
                                     jnp.zeros((lanes, chunk), jnp.int32),
                                     jnp.zeros((lanes, chunk), bool), idle,
                                     eng._tier_reads())
        jax.block_until_ready(out[0])
    return time.perf_counter() - t0


def _read_counts(eng) -> dict[str, tuple[int, int]]:
    """Merged (fast, slow) read counts per resource, for windowed rates."""
    return {n: (row["fast_reads"], row["slow_reads"])
            for n, row in eng.tier_stats().items()}


def _window_rate(before: dict, after: dict) -> tuple[float, dict[str, float]]:
    """(combined, per-resource) hit rate over the [before, after) window."""
    per, tot_f, tot_r = {}, 0, 0
    for n, (f1, s1) in before.items():
        f2, s2 = after[n]
        df, dr = f2 - f1, (f2 + s2) - (f1 + s1)
        per[n] = df / max(dr, 1)
        tot_f += df
        tot_r += dr
    return tot_f / max(tot_r, 1), per


def _bench_trace(kind: str, params, n_steps: int, seed: int) -> dict:
    cfg = get_smoke_config(ARCH)
    eng = ServeEngine(cfg, params, ServeConfig(**SERVE_KW))
    compile_s = _warm_engine(eng)
    tenants = [Tenant(t.name, t.weight) for t in DEFAULT_TENANTS]
    sched = Scheduler(eng, tenants, SchedConfig(preempt_patience=24,
                                                seed=seed))
    trace = make_trace(kind, n_steps=n_steps, vocab=cfg.vocab, seed=seed,
                       arrival=ARRIVAL)
    mid_counts: list[dict] = []

    def snap_mid(s):                             # steady-state window start
        if not mid_counts and s.step_count >= steady_start(trace.n_steps):
            mid_counts.append(_read_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap_mid)
    wall = time.perf_counter() - t0
    rep = sched.report()
    steady, steady_per = _window_rate(mid_counts[0], _read_counts(eng))
    resources = rep["resources"]
    fast = sum(r["fast_reads"] for r in resources.values())
    reads = fast + sum(r["slow_reads"] for r in resources.values())
    moved = sum(r["migration_bytes"] for r in resources.values())
    assert rep["completed"] == rep["submitted"], "requests left undrained"
    return {
        "trace": kind,
        "seed": trace.seed,
        "arrival": trace.arrival,
        "kv_mass_source": eng.scfg.kv_mass_source,
        "trace_steps": trace.n_steps,
        "steps": rep["steps"],
        "lanes": LANES,
        "submitted": rep["submitted"],
        "completed": rep["completed"],
        "tokens": rep["tokens"],
        "compile_s": compile_s,
        "wall_s": wall,
        "tokens_per_s": rep["tokens"] / wall,
        "ttft_ms": rep["ttft_ms"],
        "tpot_ms": rep["tpot_ms"],
        "hit_rate": fast / max(reads, 1),
        "hit_rate_steady": steady,
        "resource_hit_steady": steady_per,
        "migration_bytes": moved,
        "migration_bytes_per_s": moved / wall,
        "preemptions": rep["preemptions"],
        "queued_peak": rep["queued_peak"],
        "tenants": rep["tenants"],
        "resources": resources,
    }


def _prefill_arm(params, chunk: int) -> dict:
    """One arm of the chunked-prefill TTFT A/B: a fresh engine + scheduler,
    one UNTIMED warmup request that traces+compiles the arm's whole path
    (streaming decode step or chunk scan, plus the flush scatter), then the
    measured request — its TTFT is steady-state arrival -> first-token
    wall, not XLA compile.  The warmup wall is recorded as ``compile_s``."""
    cfg = get_smoke_config(ARCH)
    eng = ServeEngine(cfg, params, ServeConfig(**PREFILL_KW))
    sched = Scheduler(eng, [Tenant("a")],
                      SchedConfig(prefill_chunk=chunk, seed=0))
    rng = np.random.default_rng(11)
    warm = rng.integers(0, cfg.vocab, PREFILL_PROMPT).astype(np.int32)
    prompt = rng.integers(0, cfg.vocab, PREFILL_PROMPT).astype(np.int32)
    t0 = time.perf_counter()
    sched.submit("a", warm, max_new=PREFILL_NEW)
    sched.run(max_steps=4 * PREFILL_PROMPT)
    compile_s = time.perf_counter() - t0
    req = sched.submit("a", prompt, max_new=PREFILL_NEW)
    sched.run(max_steps=8 * PREFILL_PROMPT)
    rows = Scheduler._latency_rows([req])
    return {
        "chunk": chunk,
        "compile_s": compile_s,
        "steps": sched.step_count,
        "ttft_ms": rows["ttft_ms"]["mean"],        # one request: exact
        "tpot_ms": rows["tpot_ms"],
        "tokens": [int(t) for t in req.out],
    }


def _bench_prefill(params) -> dict:
    """The prompt-length tail-latency A/B (DESIGN.md §11): the identical
    512-token request served token-at-a-time (prefill_chunk=0) and through
    the chunked scan (prefill_chunk=64 >= page_t), same seed, greedy
    sampling — chunked must land the first token in <= 1/4 the time with
    bit-exact output tokens (gated in validate_bench.py)."""
    token = _prefill_arm(params, chunk=0)
    chunked = _prefill_arm(params, chunk=PREFILL_CHUNK)
    match = token["tokens"] == chunked["tokens"]
    ratio = chunked["ttft_ms"] / max(token["ttft_ms"], 1e-9)
    assert match, (
        "chunked prefill diverged from token-at-a-time streaming: "
        f"{chunked['tokens']} != {token['tokens']}")
    assert ratio <= 0.25, (
        f"chunked TTFT {chunked['ttft_ms']:.1f}ms not <= 1/4 of "
        f"token-at-a-time {token['ttft_ms']:.1f}ms (ratio {ratio:.3f})")
    return {
        "arch": ARCH,
        "prompt_len": PREFILL_PROMPT,
        "max_new": PREFILL_NEW,
        "page_t": PREFILL_KW["page_t"],
        "chunk": PREFILL_CHUNK,
        "lanes": PREFILL_KW["lanes"],
        "seed": 0,
        "tokens_match": bool(match),
        "ttft_ratio": ratio,
        "token": token,
        "chunked": chunked,
    }


# The kv_reuse A/B (DESIGN.md §12): agentic multi-turn sessions over the
# content-addressed page store.  Tenant prompt_len bounds the per-TURN user
# block; the pool is sized BELOW the trace's distinct-page footprint so LRU
# eviction punches front-of-history holes that only substring matching can
# skip past.  prefill_chunk is on so gap scans interleave with installs.
REUSE_TENANTS = (
    TenantProfile("agent-a", weight=1.0, prompt_len=(3, 6), out_len=(3, 5)),
    TenantProfile("agent-b", weight=1.0, prompt_len=(3, 6), out_len=(3, 5)),
)
REUSE_TRACE_KW = dict(turn_gap=16, sys_len=12, n_convs=2, work_len=4,
                      max_total=56)
# Pool sized BELOW the trace's live footprint (~4 conversations x ~13 pages)
# so LRU eviction reaches live front-of-history pages: the shared system
# pages stay hot (re-touched by the sibling conversation), early history
# evicts, and only substring matching recovers the surviving tail.
REUSE_PAGES = 32
REUSE_CHUNK = 8
REUSE_STEPS = 224          # enough steps for deep (7-8 turn) conversations


def _reuse_arm(params, trace, mode: str, reuse_pages: int) -> dict:
    """One arm of the reuse A/B: a fresh engine + scheduler replaying the
    identical agentic trace, greedy.  ``reuse_pages=0`` disables the store
    (the baseline arm); otherwise ``mode`` selects prefix vs substring
    admission matching (SchedConfig.reuse_match)."""
    cfg = get_smoke_config(ARCH)
    eng = ServeEngine(cfg, params, ServeConfig(**SERVE_KW,
                                               reuse_pages=reuse_pages))
    compile_s = _warm_engine(eng, chunk=REUSE_CHUNK)
    tenants = [Tenant(t.name, t.weight) for t in trace.tenants]
    sched = Scheduler(eng, tenants,
                      SchedConfig(preempt_patience=24, seed=0,
                                  prefill_chunk=REUSE_CHUNK,
                                  reuse_match=mode))
    mid_counts: list[dict] = []

    def snap_mid(s):
        if not mid_counts and s.step_count >= steady_start(trace.n_steps):
            mid_counts.append(_read_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap_mid)
    wall = time.perf_counter() - t0
    rep = sched.report()
    assert rep["completed"] == rep["submitted"], "requests left undrained"
    _, steady_per = _window_rate(mid_counts[0], _read_counts(eng))
    return {
        "mode": "off" if reuse_pages == 0 else mode,
        "reuse_pages": reuse_pages,
        "steps": rep["steps"],
        "completed": rep["completed"],
        "tokens": rep["tokens"],
        "compile_s": compile_s,
        "wall_s": wall,
        "kv_hit_steady": steady_per["kv"],
        "ttft_ms": rep["ttft_ms"],
        "reuse": eng.reuse_stats() if eng.reuse is not None else None,
        "outputs": {int(r.rid): [int(t) for t in r.out]
                    for r in sched.finished},
    }


def _bench_reuse(params, n_steps: int, seed: int) -> dict:
    """Cross-request KV reuse A/B (DESIGN.md §12): the identical agentic
    trace served with reuse off, prefix matching, and substring matching.
    Gates (asserted here AND in validate_bench.py): outputs bit-exact
    across arms, substring saves prefill tokens, substring page-hit rate
    beats prefix (hole-skipping), substring steady KV hit >= off."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace("agentic", n_steps=max(n_steps, REUSE_STEPS),
                       vocab=cfg.vocab, tenants=REUSE_TENANTS, seed=seed,
                       **REUSE_TRACE_KW)
    off = _reuse_arm(params, trace, "substring", reuse_pages=0)
    prefix = _reuse_arm(params, trace, "prefix", REUSE_PAGES)
    substr = _reuse_arm(params, trace, "substring", REUSE_PAGES)
    match = off["outputs"] == prefix["outputs"] == substr["outputs"]
    assert match, "KV reuse changed output tokens — bit-exactness gate lost"
    saved = substr["reuse"]["tokens_saved"]
    assert saved > 0, "substring reuse saved no prefill tokens"
    hp, hs = prefix["reuse"]["hit_rate"], substr["reuse"]["hit_rate"]
    assert hs > hp, (
        f"substring page-hit rate {hs:.3f} must beat prefix {hp:.3f} — "
        "hole-skipping found nothing beyond the shared prefix")
    assert substr["kv_hit_steady"] >= off["kv_hit_steady"], (
        f"reuse degraded the steady KV hit rate: {substr['kv_hit_steady']:.3f}"
        f" < {off['kv_hit_steady']:.3f}")
    for arm in (off, prefix, substr):
        del arm["outputs"]                 # compared above; too bulky to keep
    return {
        "arch": ARCH,
        "trace": "agentic",
        "seed": seed,
        "trace_steps": trace.n_steps,
        "turns": len(trace.arrivals),
        "lanes": LANES,
        "page_t": SERVE_KW["page_t"],
        "reuse_pages": REUSE_PAGES,
        "prefill_chunk": REUSE_CHUNK,
        "tenants": {t.name: t.weight for t in REUSE_TENANTS},
        "tokens_match": bool(match),
        "prefill_tokens_saved": saved,
        "hit_rate_gap": hs - hp,
        "off": off,
        "prefix": prefix,
        "substring": substr,
    }


# The disaggregation A/B (DESIGN.md §13): the identical prefill-heavy
# trace — a "chat" tenant streaming short prompts with long outputs, a
# "doc" tenant dropping long prompts with short outputs — served by the
# unified scheduler (3 lanes, chunked prefill in-pool) and by the split
# scheduler (2 decode lanes + 1 dedicated prefill-worker lane: the same
# total hardware budget) over the slow-tier hand-off fabric.  Decode
# inter-token gaps are measured on each arm's DECODE worker virtual clock
# (serve/sched.py module docstring) and split by whether a chunked prefill
# was in flight during the gap: the unified arm inherits every chunk-scan
# wall, the disagg arm must stay flat (<= 10% p50 degradation) because the
# walls run on the prefill worker's clock — while the hand-off install /
# gather costs it DOES pay stay on the decode clock, honestly counted.
DISAGG_KW = dict(
    max_seq=56, paged=True, page_t=4, hot_slots=6, migration_interval=4,
    kv_quota=16, kv_tier_slots=12, kv_mass_threshold=0.01,
)
DISAGG_TOTAL_LANES = 3
DISAGG_PRE_LANES = 1
DISAGG_SEGMENTS = 6          # both pools + hand-offs in flight
DISAGG_CHUNK = 16            # <= the ring-wrap cap (hot_slots-1)*page_t = 20
DISAGG_STEPS = 240
DISAGG_VICTIM = "chat"       # the decode-heavy tenant whose TPOT we gate


def _decode_gaps(sched, tenant: str) -> tuple[list[float], list[float]]:
    """One tenant's decode inter-token gaps on the decode worker's virtual
    clock, split into (during, quiet) by whether any step in the gap's
    window had a chunked prefill in flight (Scheduler.prefill_busy)."""
    busy = sched.prefill_busy
    during, quiet = [], []
    for r in sched.finished:
        if r.tenant != tenant:
            continue
        for i in range(1, len(r.token_clock)):
            gap = r.token_clock[i] - r.token_clock[i - 1]
            s1, s2 = r.token_steps[i - 1], r.token_steps[i]
            overlapped = any(busy[s] for s in range(s1 + 1, s2 + 1))
            (during if overlapped else quiet).append(gap)
    return during, quiet


def _disagg_arm(params, trace, prefill_lanes: int) -> dict:
    """One arm of the disaggregation A/B: unified (prefill_lanes=0) or the
    split scheduler, same chunk size, same total lane budget, greedy."""
    cfg = get_smoke_config(ARCH)
    lanes = DISAGG_TOTAL_LANES - prefill_lanes
    eng = ServeEngine(cfg, params, ServeConfig(
        **DISAGG_KW, lanes=lanes, kv_segments=DISAGG_SEGMENTS))
    # unified prefills in-pool (warm that shape); the disagg decode engine
    # never scans a chunk — its prefill worker is warmed separately below
    compile_s = _warm_engine(
        eng, chunk=DISAGG_CHUNK if prefill_lanes == 0 else 0)
    tenants = [Tenant(t.name, t.weight) for t in trace.tenants]
    sched = Scheduler(eng, tenants, SchedConfig(
        preempt_patience=24, seed=trace.seed,
        prefill_chunk=DISAGG_CHUNK, prefill_lanes=prefill_lanes))
    if sched.peng is not None:
        compile_s += _warm_engine(sched.peng, chunk=DISAGG_CHUNK)
    t0 = time.perf_counter()
    play(trace, sched)
    wall = time.perf_counter() - t0
    rep = sched.report()
    assert rep["completed"] == rep["submitted"], "requests left undrained"
    during, quiet = _decode_gaps(sched, DISAGG_VICTIM)
    p_d = float(np.percentile(np.asarray(during), 50) * 1e3) if during else 0.0
    p_q = float(np.percentile(np.asarray(quiet), 50) * 1e3) if quiet else 0.0
    return {
        "mode": rep["mode"],
        "lanes": lanes,
        "prefill_lanes": prefill_lanes,
        "compile_s": compile_s,
        "steps": rep["steps"],
        "wall_s": wall,
        "completed": rep["completed"],
        "tokens": rep["tokens"],
        "preemptions": rep["preemptions"],
        "tpot_quiet_ms": p_q,
        "tpot_during_ms": p_d,
        "tpot_n": {"during": len(during), "quiet": len(quiet)},
        "tpot_degradation": p_d / max(p_q, 1e-9) - 1.0,
        "ttft_ms": rep["ttft_ms"],
        "handoff": rep["handoff"],
        "clock": rep["clock"],
        "resources": rep["resources"],
        "outputs": {int(r.rid): [int(t) for t in r.out]
                    for r in sched.finished},
    }


def _bench_disagg(params, seed: int) -> dict:
    """Prefill/decode disaggregation A/B (DESIGN.md §13).  Gates (asserted
    here AND in validate_bench.py): outputs bit-exact across arms, the
    disagg arm's hand-off fabric carried bytes both ways, decode-lane TPOT
    under concurrent prefill degrades <= 10% in the disagg arm and
    measurably more in the unified arm on the identical trace.  Always runs
    the full DISAGG_STEPS trace (even under --quick): the gate compares
    p50s of the during/quiet gap populations, and shrinking the trace
    shrinks the 'during' sample below where the medians are stable."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace("prefill-heavy", n_steps=DISAGG_STEPS,
                       vocab=cfg.vocab, seed=seed, arrival=ARRIVAL)
    uni = _disagg_arm(params, trace, prefill_lanes=0)
    dis = _disagg_arm(params, trace, prefill_lanes=DISAGG_PRE_LANES)
    match = uni.pop("outputs") == dis.pop("outputs")
    assert match, ("disaggregation changed output tokens — "
                   "bit-exactness gate lost")
    ho = dis["handoff"]
    assert ho["count"] > 0 and ho["bytes_out"] > 0 and ho["bytes_in"] > 0, \
        f"hand-off fabric idle: {ho}"
    dd, ud = dis["tpot_degradation"], uni["tpot_degradation"]
    assert dd <= 0.10, (
        f"disagg decode TPOT degraded {dd:+.1%} under concurrent prefill "
        "(gate <= 10%) — the dedicated prefill lane did not isolate decode")
    assert ud > dd, (
        f"unified degradation {ud:+.1%} not above disagg {dd:+.1%} — "
        "the trace carries no prefill contention to isolate")
    return {
        "arch": ARCH,
        "trace": trace.kind,
        "seed": seed,
        "arrival": trace.arrival,
        "trace_steps": trace.n_steps,
        "page_t": DISAGG_KW["page_t"],
        "chunk": DISAGG_CHUNK,
        "total_lanes": DISAGG_TOTAL_LANES,
        "victim_tenant": DISAGG_VICTIM,
        "tokens_match": bool(match),
        "unified": uni,
        "disagg": dis,
    }


def run(quick: bool = False, reuse_only: bool = False,
        disagg_only: bool = False, kinds: tuple[str, ...] = BENCH_KINDS):
    n_steps = 120 if quick else 320
    params = tr.init_params(get_smoke_config(ARCH), jax.random.PRNGKey(0))
    if reuse_only:
        kr = _bench_reuse(params, n_steps, seed=0)
        emit("traffic_kv_reuse", 0.0,
             f"saved={kr['prefill_tokens_saved']} "
             f"hit sub={kr['substring']['reuse']['hit_rate']:.3f} "
             f"pre={kr['prefix']['reuse']['hit_rate']:.3f} "
             f"match={kr['tokens_match']}")
        update_bench_json(OUT_PATH, kv_reuse=kr)
        emit("traffic_bench_json", 0.0, os.path.normpath(OUT_PATH))
        return kr
    if disagg_only:
        dg = _bench_disagg(params, seed=0)
        emit("traffic_disagg", dg["disagg"]["tpot_during_ms"],
             f"tpot dur/quiet disagg={dg['disagg']['tpot_during_ms']:.1f}/"
             f"{dg['disagg']['tpot_quiet_ms']:.1f}ms "
             f"deg={dg['disagg']['tpot_degradation']:+.1%} "
             f"vs unified={dg['unified']['tpot_degradation']:+.1%} "
             f"handoffs={dg['disagg']['handoff']['count']} "
             f"match={dg['tokens_match']}")
        update_bench_json(OUT_PATH, disagg=dg)
        emit("traffic_bench_json", 0.0, os.path.normpath(OUT_PATH))
        return dg
    rows = [_bench_trace(kind, params, n_steps, seed=0)
            for kind in dict.fromkeys(CONTENT_KINDS + tuple(kinds))]
    by_kind = {r["trace"]: r for r in rows}
    gap = (by_kind["zipf-hot"]["hit_rate_steady"]
           - by_kind["scan-antagonist"]["hit_rate_steady"])
    assert gap > 0, (
        "adaptivity signal lost: zipf-hot steady hit rate "
        f"{by_kind['zipf-hot']['hit_rate_steady']:.3f} <= scan-antagonist "
        f"{by_kind['scan-antagonist']['hit_rate_steady']:.3f}")
    for r in rows:
        emit(f"traffic_{r['trace']}",
             r["tpot_ms"]["p50"] * 1e3,
             f"tok_s={r['tokens_per_s']:.1f} "
             f"ttft_p99={r['ttft_ms']['p99']:.1f}ms "
             f"tpot_p99={r['tpot_ms']['p99']:.1f}ms "
             f"hit={r['hit_rate']:.3f} steady={r['hit_rate_steady']:.3f} "
             f"mig_B_s={r['migration_bytes_per_s']:.0f} "
             f"preempt={r['preemptions']}")
    emit("traffic_adaptivity_gap", 0.0,
         f"zipf-scan steady hit gap={gap:+.3f}")
    pf = _bench_prefill(params)
    emit("traffic_prefill", pf["chunked"]["ttft_ms"] * 1e3,
         f"ttft chunked={pf['chunked']['ttft_ms']:.1f}ms "
         f"token={pf['token']['ttft_ms']:.1f}ms "
         f"ratio={pf['ttft_ratio']:.3f} match={pf['tokens_match']}")
    kr = _bench_reuse(params, n_steps, seed=0)
    emit("traffic_kv_reuse", 0.0,
         f"saved={kr['prefill_tokens_saved']} "
         f"hit sub={kr['substring']['reuse']['hit_rate']:.3f} "
         f"pre={kr['prefix']['reuse']['hit_rate']:.3f} "
         f"match={kr['tokens_match']}")
    dg = _bench_disagg(params, seed=0)
    emit("traffic_disagg", dg["disagg"]["tpot_during_ms"],
         f"tpot dur/quiet disagg={dg['disagg']['tpot_during_ms']:.1f}/"
         f"{dg['disagg']['tpot_quiet_ms']:.1f}ms "
         f"deg={dg['disagg']['tpot_degradation']:+.1%} "
         f"vs unified={dg['unified']['tpot_degradation']:+.1%} "
         f"handoffs={dg['disagg']['handoff']['count']} "
         f"match={dg['tokens_match']}")
    update_bench_json(OUT_PATH, traffic={
        "quick": quick,
        "arch": ARCH,
        "lanes": LANES,
        "arrival": ARRIVAL,
        "tenants": {t.name: t.weight for t in DEFAULT_TENANTS},
        "traces": rows,
    }, prefill=pf, kv_reuse=kr, disagg=dg)
    emit("traffic_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reuse", action="store_true",
                    help="run only the kv_reuse A/B section")
    ap.add_argument("--disagg", action="store_true",
                    help="run only the prefill/decode disaggregation A/B")
    ap.add_argument("--kinds", default=",".join(BENCH_KINDS),
                    help="comma-separated trace kinds for the traffic "
                    "section (the adaptivity-gap trio always runs)")
    args = ap.parse_args()
    run(quick=args.quick, reuse_only=args.reuse, disagg_only=args.disagg,
        kinds=tuple(k for k in args.kinds.split(",") if k))
