"""Traffic benchmark: the full serving stack under multi-tenant traces.

Drives the continuous-batching scheduler (`repro.serve.sched`) over the
three workload traces (`repro.workloads`): zipf-hot, diurnal-shift, and
scan-antagonist, each with >= 2 tenants multiplexed onto one ServeEngine /
NeoMemDaemon.  Per trace it records throughput, p50/p99 per-token latency,
hit rates (lifetime + steady-state second-half window), migration bytes/s,
preemptions, and per-tenant rows into the ``traffic`` section of
``BENCH_serve.json`` (schema in benchmarks/README.md, validated in CI by
validate_bench.py).

The NeoMem adaptivity signal asserted here: identical arrival load, only
token content differs (workloads/traces.py), so the zipf-hot trace must
reach a HIGHER steady-state hit rate than scan-antagonist — a stable hot
set the sketch can find and pin versus an antagonist scan thrashing it.

Arrivals follow the bursty MMPP process (2-state modulated Bernoulli,
workloads/traces.py): same mean offered load as plain Bernoulli, but the
queueing/preemption pressure — and thus the p99 story — lives in the
bursts, as in production serving traces.  The "kv" resource profiles the
kernel-exported softmax mass (ServeConfig.kv_mass_source, DESIGN.md §10);
the fill-vs-kernel fidelity A/B itself lives in serve_bench.py
(``mass_ab``).

    PYTHONPATH=src:. python benchmarks/traffic_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant
from repro.workloads import DEFAULT_TENANTS, TRACE_KINDS, make_trace, play

from benchmarks.common import emit, steady_start, update_bench_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ARCH = "llama3.2-3b"
LANES = 4
ARRIVAL = "mmpp"
SERVE_KW = dict(
    max_seq=64, paged=True, page_t=4, hot_slots=6, migration_interval=4,
    resources=("embeddings",), embed_hot_slots=6, embed_quota=8,
    embed_rows_per_page=8,            # 256-token vocab -> 32 row pages
    kv_quota=16, kv_tier_slots=12, kv_mass_threshold=0.01,
    lanes=LANES, kv_segments=LANES + 2,
)


def _read_counts(eng) -> dict[str, tuple[int, int]]:
    """Merged (fast, slow) read counts per resource, for windowed rates."""
    return {n: (row["fast_reads"], row["slow_reads"])
            for n, row in eng.tier_stats().items()}


def _window_rate(before: dict, after: dict) -> tuple[float, dict[str, float]]:
    """(combined, per-resource) hit rate over the [before, after) window."""
    per, tot_f, tot_r = {}, 0, 0
    for n, (f1, s1) in before.items():
        f2, s2 = after[n]
        df, dr = f2 - f1, (f2 + s2) - (f1 + s1)
        per[n] = df / max(dr, 1)
        tot_f += df
        tot_r += dr
    return tot_f / max(tot_r, 1), per


def _bench_trace(kind: str, params, n_steps: int, seed: int) -> dict:
    cfg = get_smoke_config(ARCH)
    eng = ServeEngine(cfg, params, ServeConfig(**SERVE_KW))
    tenants = [Tenant(t.name, t.weight) for t in DEFAULT_TENANTS]
    sched = Scheduler(eng, tenants, SchedConfig(preempt_patience=24,
                                                seed=seed))
    trace = make_trace(kind, n_steps=n_steps, vocab=cfg.vocab, seed=seed,
                       arrival=ARRIVAL)
    mid_counts: list[dict] = []

    def snap_mid(s):                             # steady-state window start
        if not mid_counts and s.step_count >= steady_start(trace.n_steps):
            mid_counts.append(_read_counts(eng))

    t0 = time.perf_counter()
    play(trace, sched, on_step=snap_mid)
    wall = time.perf_counter() - t0
    rep = sched.report()
    steady, steady_per = _window_rate(mid_counts[0], _read_counts(eng))
    resources = rep["resources"]
    fast = sum(r["fast_reads"] for r in resources.values())
    reads = fast + sum(r["slow_reads"] for r in resources.values())
    moved = sum(r["migration_bytes"] for r in resources.values())
    assert rep["completed"] == rep["submitted"], "requests left undrained"
    return {
        "trace": kind,
        "seed": trace.seed,
        "arrival": trace.arrival,
        "kv_mass_source": eng.scfg.kv_mass_source,
        "trace_steps": trace.n_steps,
        "steps": rep["steps"],
        "lanes": LANES,
        "submitted": rep["submitted"],
        "completed": rep["completed"],
        "tokens": rep["tokens"],
        "wall_s": wall,
        "tokens_per_s": rep["tokens"] / wall,
        "latency_ms": rep["latency_ms"],
        "hit_rate": fast / max(reads, 1),
        "hit_rate_steady": steady,
        "resource_hit_steady": steady_per,
        "migration_bytes": moved,
        "migration_bytes_per_s": moved / wall,
        "preemptions": rep["preemptions"],
        "queued_peak": rep["queued_peak"],
        "tenants": rep["tenants"],
        "resources": resources,
    }


def run(quick: bool = False):
    n_steps = 120 if quick else 320
    params = tr.init_params(get_smoke_config(ARCH), jax.random.PRNGKey(0))
    rows = [_bench_trace(kind, params, n_steps, seed=0)
            for kind in TRACE_KINDS]
    by_kind = {r["trace"]: r for r in rows}
    gap = (by_kind["zipf-hot"]["hit_rate_steady"]
           - by_kind["scan-antagonist"]["hit_rate_steady"])
    assert gap > 0, (
        "adaptivity signal lost: zipf-hot steady hit rate "
        f"{by_kind['zipf-hot']['hit_rate_steady']:.3f} <= scan-antagonist "
        f"{by_kind['scan-antagonist']['hit_rate_steady']:.3f}")
    for r in rows:
        emit(f"traffic_{r['trace']}",
             r["latency_ms"]["p50"] * 1e3,
             f"tok_s={r['tokens_per_s']:.1f} p99={r['latency_ms']['p99']:.1f}ms "
             f"hit={r['hit_rate']:.3f} steady={r['hit_rate_steady']:.3f} "
             f"mig_B_s={r['migration_bytes_per_s']:.0f} "
             f"preempt={r['preemptions']}")
    emit("traffic_adaptivity_gap", 0.0,
         f"zipf-scan steady hit gap={gap:+.3f}")
    update_bench_json(OUT_PATH, traffic={
        "quick": quick,
        "arch": ARCH,
        "lanes": LANES,
        "arrival": ARRIVAL,
        "tenants": {t.name: t.weight for t in DEFAULT_TENANTS},
        "traces": rows,
    })
    emit("traffic_bench_json", 0.0, os.path.normpath(OUT_PATH))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
