"""Paper Fig. 14: dynamic threshold vs fixed thresholds on PageRank.

Claims: (a) the dynamic policy beats every fixed theta; (b) theta adapts
over the run (trace recorded); (c) bandwidth responds to promotions.
"""
from __future__ import annotations

from repro.core.simulator import WORKLOADS, run_sim

from benchmarks.common import BLOCK, FAST_RATIO, N_BLOCKS, N_PAGES, SIM_KW, Timer, emit

FIXED = [2, 8, 32, 128]


def run(quick: bool = False):
    n_blocks = N_BLOCKS // 4 if quick else N_BLOCKS

    def sim(theta=None):
        stream = WORKLOADS["pagerank"](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=41)
        return run_sim("neomem", stream, n_pages=N_PAGES,
                       fast_ratio=FAST_RATIO, fixed_theta=theta,
                       collect_trace=True, **SIM_KW)

    with Timer() as t:
        dyn = sim(None)
        emit("fig14_dynamic", t.s * 1e6,
             f"runtime_ms={dyn.runtime*1e3:.2f} hit={dyn.hit_rate:.3f}")
        for th in FIXED:
            r = sim(th)
            emit(f"fig14_fixed_theta{th}", 0.0,
                 f"runtime_ms={r.runtime*1e3:.2f} hit={r.hit_rate:.3f} "
                 f"vs_dynamic={r.runtime/dyn.runtime:.2f}x")
    thetas = [tr["theta"] for tr in dyn.trace]
    bws = [f"{tr['bw']:.2f}" for tr in dyn.trace]
    emit("fig14_theta_trace", 0.0, " ".join(map(str, thetas[:32])))
    emit("fig14_bw_trace", 0.0, " ".join(bws[:32]))


if __name__ == "__main__":
    run()
