"""Kernel microbenchmarks (paper Table III analogue): NeoProf throughput.

Interpret-mode wall times are NOT TPU times; reported for relative tracking.
Also reports the sketch's modeled VMEM footprint per segment tile.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import SketchParams, sketch_init
from repro.core import sketch as sk
from repro.kernels.neoprof_update import ops as kops

from benchmarks.common import emit


def run(quick: bool = False):
    sp = SketchParams(width=1 << 14, depth=2)
    st = sketch_init(sp)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 1 << 20, 2048).astype(np.int32))

    # pure-jax reference path (the production CPU-fallback)
    f = jax.jit(lambda s, i: sk.sketch_update(s, i, jnp.int32(64), sp))
    f(st, ids)[0].counts.block_until_ready()
    n = 3 if quick else 10
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(st, ids)
    out[0].counts.block_until_ready()
    dt = (time.perf_counter() - t0) / n
    emit("neoprof_update_jax", dt * 1e6,
         f"{2048/dt/1e6:.1f}M pages/s (CPU jit; W=16K D=2)")

    # Pallas interpret path (correctness harness, not perf)
    g = jax.jit(lambda s, i: kops.sketch_update(s, i, jnp.int32(64), sp,
                                                interpret=True))
    g(st, ids)[0].counts.block_until_ready()
    t0 = time.perf_counter()
    out = g(st, ids)
    out[0].counts.block_until_ready()
    dt = time.perf_counter() - t0
    emit("neoprof_update_pallas_interpret", dt * 1e6,
         "interpret-mode (correctness only)")

    # modeled TPU VMEM footprint per grid step
    seg = 512
    vmem = (sp.depth * seg * 4 * 3        # counts/epochs/hot blocks
            + 2048 * 4                      # stream ids
            + sp.depth * 2048 * 4 * 2)      # est/hot_before accumulators
    emit("neoprof_update_vmem_per_tile", 0.0, f"{vmem/1024:.0f} KiB (seg=512)")
    emit("sketch_sram_total", 0.0,
         f"{sp.depth*sp.width*2/1024:.0f} KiB counter array "
         f"(paper ASIC: 512K x 16b x 2 = 2 MiB)")


if __name__ == "__main__":
    run()
