"""Paper Fig. 12: performance across fast:slow memory ratios (1:2, 1:4, 1:8).

NeoMem vs PEBS (the paper's second-best); claim: NeoMem's lead widens as the
fast tier shrinks (higher classification accuracy matters more).
"""
from __future__ import annotations

from repro.core.simulator import WORKLOADS, run_sim

from benchmarks.common import BLOCK, N_BLOCKS, N_PAGES, SIM_KW, Timer, emit

WL = ["pagerank", "btree", "gups", "xsbench"]
RATIOS = {"1:2": 1 / 3, "1:4": 1 / 5, "1:8": 1 / 9}


def run(quick: bool = False):
    n_blocks = N_BLOCKS // 4 if quick else N_BLOCKS
    with Timer() as t:
        for wl in WL:
            parts = []
            for tag, ratio in RATIOS.items():
                rs = {}
                for m in ("neomem", "pebs"):
                    stream = WORKLOADS[wl](n_pages=N_PAGES, block=BLOCK,
                                           n_blocks=n_blocks, seed=21)
                    rs[m] = run_sim(m, stream, n_pages=N_PAGES,
                                    fast_ratio=ratio, **SIM_KW)
                parts.append(f"{tag}={rs['pebs'].runtime/rs['neomem'].runtime:.2f}x")
            emit(f"fig12_{wl}_speedup_vs_pebs",
                 t.s * 1e6 / (len(WL) * len(RATIOS) * 2), " ".join(parts))


if __name__ == "__main__":
    run()
