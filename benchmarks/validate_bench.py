"""Validate BENCH_serve.json against the documented schema (CI gate).

Checks what benchmarks/README.md documents: every case and resource row
carries the expected keys, the serve bench actually moved migration bytes
(the data plane is live, not simulated), and no epoch exceeded its byte
quota.  When the ``traffic`` section is present (benchmarks/
traffic_bench.py), additionally checks the multi-tenant trace schema — all
three trace kinds, >= 2 tenants, latency percentiles, drained queues — and
the NeoMem adaptivity signal: the zipf-hot trace's steady-state hit rate
must exceed scan-antagonist's.

The ``mass_ab`` section (written by serve_bench, so ``make bench-serve``
runs the gate in the CI fast tier) carries the hotness-fidelity A/B:
the zipf trace served with the kernel-exported softmax-mass stream vs the
old page-fill proxy.  The gate asserts kernel >= fill on the steady-state
KV hit rate — device-true hotness must never profile WORSE than the
host proxy it replaced (DESIGN.md §10).

The ``prefill`` section (written by traffic_bench, so ``make
bench-traffic`` runs the gate in CI) carries the chunked-prefill TTFT A/B
(DESIGN.md §11): one >= 512-token prompt served token-at-a-time vs through
the chunked scan on the same seed.  The gate asserts chunked TTFT <= 1/4
of streaming with bit-exact output tokens and a nonzero TPOT row — the
prompt-length tail-latency fix must not regress, and the split ttft_ms /
tpot_ms schema must be present on every trace and tenant row.  (The
combined ``latency_ms`` row finished its one-release deprecation window:
its PRESENCE is now an error.)

The ``kv_reuse`` section (written by traffic_bench, DESIGN.md §12) carries
the cross-request KV reuse A/B: the identical agentic multi-turn trace
served with the content-addressed page store off, with prefix matching,
and with substring matching.  Gates: output tokens bit-exact across all
three arms, substring prefill-tokens-saved > 0, substring page-hit rate
strictly above prefix (hole-skipping must recover evicted-front history),
and the substring arm's steady-state KV hit rate no worse than reuse-off.
The ``disagg`` section (written by traffic_bench, DESIGN.md §13) carries
the prefill/decode disaggregation A/B: the identical prefill-heavy trace
served by the unified scheduler and by the split prefill-pool/decode-pool
scheduler over the slow-tier hand-off fabric, same seed.  Gates: output
tokens bit-exact across arms, the disagg arm actually handed requests off
through the slow store (count and producer/consumer bytes nonzero), the
decode pool's TPOT during concurrent prefill degrades <= 10% over its
quiet-period TPOT (per-worker virtual clocks), while the unified arm shows
a measurably larger degradation on the same trace — the disaggregation
payoff, not a workload artifact.

The ``compress`` section (written by ``serve_bench --compress``, so
``make bench-compress`` runs the gate in CI) carries the slow-tier codec
A/B (DESIGN.md §14): the identical zipf-hot trace served under the
``none`` / ``fp32`` / ``int8`` slow-store codecs at the same page quota.
Gates: identical served load across arms, output tokens bit-exact between
``none`` and ``fp32`` (a full-precision store must change nothing), the
int8 arm's migration bytes <= 0.35x the fp32 arm's, its steady hit rate
within eps of fp32 per resource, the logit probe's fp32 drift exactly 0
and int8 drift within its bound, and the zero1 ``compress_collective``
consumer: fp32-parity update drift within tolerance at <= 0.30x the
collective bytes.

The ``overlap`` section (written by ``serve_bench --overlap``, so ``make
bench-overlap`` runs the gate in CI) carries the async-migration A/B
(DESIGN.md §15): the MoE smoke arch — paged KV + experts + embeddings —
served with the synchronous data plane and with the double-buffered async
one, same model/trace/quota.  Gates: output tokens bit-exact (the stale
committed epoch must serve the same bytes), per-resource migration bytes
identical across arms (overlap hides the copies, it must not skip them),
the sync arm's decode stall nonzero and the async arm's <= 1/4 of it, and
every resource that moved payload reporting achieved overlap
(``overlap_bytes_per_decode_s`` > 0).

Every resource row is additionally held to the telemetry conservation
laws: ``hit_rate`` must equal ``fast_reads / (fast_reads + slow_reads)``
(every metered read is either fast or slow — none lost, none invented),
and ``max_epoch_bytes`` — the LARGEST migration epoch, hand-off flushes
AND the issued-but-uncommitted epoch's ``inflight_bytes`` included — must
respect ``quota_bytes``, which ``last_epoch_bytes`` can never exceed.

Run after ``make bench-serve`` / ``make bench-traffic`` /
``make bench-reuse`` / ``make bench-disagg``:

    PYTHONPATH=src:. python benchmarks/validate_bench.py [path]
"""
from __future__ import annotations

import json
import os
import sys

CASE_KEYS = {
    "arch", "batch", "prompt_len", "n_tokens", "compile_s", "tokens_per_s",
    "wall_s", "migration_bytes", "migration_bytes_per_s", "resources",
}
RESOURCE_KEYS = {
    "name", "fast_reads", "slow_reads", "hit_rate", "promoted", "demoted",
    "ping_pong", "migration_bytes", "last_epoch_bytes", "max_epoch_bytes",
    "quota_bytes", "migration_epochs", "flush_bytes", "inflight_bytes",
    "stall_s", "overlap_bytes_per_decode_s",
}
TRACE_KEYS = {
    "trace", "seed", "arrival", "kv_mass_source", "trace_steps", "steps",
    "lanes", "submitted", "completed", "tokens", "compile_s", "wall_s",
    "tokens_per_s", "ttft_ms", "tpot_ms", "hit_rate",
    "hit_rate_steady", "resource_hit_steady", "migration_bytes",
    "migration_bytes_per_s", "preemptions", "queued_peak",
    "tenants", "resources",
}
TRACE_KINDS = {"zipf-hot", "diurnal-shift", "scan-antagonist"}
ARRIVAL_KINDS = {"bernoulli", "mmpp"}
TENANT_KEYS = {"weight", "completed", "tokens", "kv_hit_rate", "ttft_ms",
               "tpot_ms"}
LATENCY_KEYS = {"p50", "p99", "mean", "n"}
# the split that replaced the combined latency_ms row (deprecation window
# closed — latency_ms may no longer appear on any row)
LATENCY_ROWS = ("ttft_ms", "tpot_ms")
PREFILL_KEYS = {"arch", "prompt_len", "max_new", "page_t", "chunk", "lanes",
                "seed", "tokens_match", "ttft_ratio", "token", "chunked"}
PREFILL_ARM_KEYS = {"chunk", "compile_s", "steps", "ttft_ms", "tpot_ms",
                    "tokens"}
MASS_AB_KEYS = {"arch", "trace", "arrival", "lanes", "seed", "trace_steps",
                "fill", "kernel"}
MASS_AB_ARM_KEYS = {"kv_mass_source", "steps", "tokens", "wall_s", "kv_hit",
                    "kv_hit_steady", "kv_promoted", "migration_bytes"}
KV_REUSE_KEYS = {"arch", "trace", "seed", "trace_steps", "turns", "lanes",
                 "page_t", "reuse_pages", "prefill_chunk", "tenants",
                 "tokens_match", "prefill_tokens_saved", "hit_rate_gap",
                 "off", "prefix", "substring"}
KV_REUSE_ARM_KEYS = {"mode", "reuse_pages", "steps", "completed", "tokens",
                     "compile_s", "wall_s", "kv_hit_steady", "ttft_ms",
                     "reuse"}
KV_REUSE_STAT_KEYS = {"pool_pages", "indexed", "free", "shared_refs",
                      "lookups", "matchable", "page_hits", "hit_rate",
                      "tokens_saved", "published", "evicted", "rejected",
                      "shared_mass_share"}
DISAGG_KEYS = {"arch", "trace", "seed", "arrival", "trace_steps", "page_t",
               "chunk", "total_lanes", "victim_tenant", "tokens_match",
               "unified", "disagg"}
DISAGG_ARM_KEYS = {"mode", "lanes", "prefill_lanes", "compile_s", "steps",
                   "wall_s", "completed", "tokens", "preemptions",
                   "tpot_quiet_ms", "tpot_during_ms", "tpot_n",
                   "tpot_degradation", "ttft_ms", "handoff", "clock",
                   "resources"}
HANDOFF_KEYS = {"count", "bytes_out", "bytes_in", "depth_peak"}
# Decode-lane TPOT flatness under concurrent prefill (DESIGN.md §13): the
# disagg arm's decode worker may degrade at most 10%; the unified arm must
# show a measurably larger hit on the identical trace for the A/B to mean
# anything (floor calibrated well below observed unified degradation).
DISAGG_MAX_DEGRADATION = 0.10
UNIFIED_MIN_DEGRADATION = 0.25
COMPRESS_KEYS = {"arch", "trace", "arrival", "lanes", "seed", "trace_steps",
                 "quick", "arms", "bytes_ratio_int8_fp32",
                 "bytes_ratio_bound", "hit_eps", "tokens_match_none_fp32",
                 "probe", "zero1"}
COMPRESS_ARM_KEYS = {"codec", "steps", "tokens", "wall_s", "hit_steady",
                     "wire_row_bytes", "migration_bytes", "max_epoch_bytes",
                     "quota_bytes", "resources"}
COMPRESS_PROBE_KEYS = {"prompt_len", "n_steps", "tokens_match_none_fp32",
                       "drift_fp32", "drift_int8", "drift_bound"}
COMPRESS_ZERO1_KEYS = {"steps", "padded", "bytes_fp32", "bytes_int8",
                       "byte_ratio", "byte_ratio_bound", "update_drift",
                       "drift_tolerance"}
COMPRESS_ARMS = ("none", "fp32", "int8")
OVERLAP_KEYS = {"arch", "batch", "prompt_len", "n_tokens", "tokens_match",
                "stall_ratio_bound", "sync", "async"}
OVERLAP_ARM_KEYS = {"mode", "steps", "compile_s", "wall_s", "tokens_per_s",
                    "stall_s", "migration_bytes", "resources"}


def _check_resources(tag: str, resources: dict, errors: list[str]) -> None:
    """Schema + the telemetry conservation laws, per resource row: the
    per-epoch quota must hold for EVERY epoch (``max_epoch_bytes``, which
    bounds ``last_epoch_bytes`` by construction), and the reported hit
    rate must be exactly the fast share of the metered reads — every read
    is either fast or slow, none lost, none invented."""
    for name, row in resources.items():
        rmissing = RESOURCE_KEYS - set(row)
        if rmissing:
            errors.append(f"{tag}/{name}: missing keys {sorted(rmissing)}")
            continue
        if row["quota_bytes"] and row["max_epoch_bytes"] > row["quota_bytes"]:
            errors.append(
                f"{tag}/{name}: max_epoch_bytes {row['max_epoch_bytes']}"
                f" exceeds quota_bytes {row['quota_bytes']} — some epoch "
                "(hand-off flushes included) broke the migration budget")
        if row["last_epoch_bytes"] > row["max_epoch_bytes"]:
            errors.append(
                f"{tag}/{name}: last_epoch_bytes {row['last_epoch_bytes']}"
                f" exceeds max_epoch_bytes {row['max_epoch_bytes']} — "
                "the epoch maximum lost an epoch")
        if row["inflight_bytes"] > row["max_epoch_bytes"]:
            errors.append(
                f"{tag}/{name}: inflight_bytes {row['inflight_bytes']}"
                f" exceeds max_epoch_bytes {row['max_epoch_bytes']} — "
                "the snapshot failed to fold the in-flight epoch")
        if not 0.0 <= row["hit_rate"] <= 1.0:
            errors.append(f"{tag}/{name}: hit_rate {row['hit_rate']} "
                          "out of [0, 1]")
        if row["hit_rate"] > 0 and row["fast_reads"] == 0:
            errors.append(f"{tag}/{name}: nonzero hit_rate with zero "
                          "fast_reads — read metering is broken")
        reads = row["fast_reads"] + row["slow_reads"]
        expect = row["fast_reads"] / reads if reads else 0.0
        if abs(row["hit_rate"] - expect) > 1e-9:
            errors.append(
                f"{tag}/{name}: hit_rate {row['hit_rate']:.6f} != "
                f"fast/(fast+slow) {expect:.6f} — read conservation lost")


def _check_traffic(traffic: dict, errors: list[str]) -> None:
    missing = {"quick", "arch", "lanes", "tenants", "traces"} - set(traffic)
    if missing:
        errors.append(f"traffic: missing keys {sorted(missing)}")
        return
    rows = {r.get("trace", "?"): r for r in traffic["traces"]}
    absent = TRACE_KINDS - set(rows)
    if absent:
        errors.append(f"traffic: missing trace kinds {sorted(absent)}")
    for kind, r in rows.items():
        tag = f"traffic/{kind}"
        missing = TRACE_KEYS - set(r)
        if missing:
            errors.append(f"{tag}: missing keys {sorted(missing)}")
            continue
        if r["arrival"] not in ARRIVAL_KINDS:
            errors.append(f"{tag}: unknown arrival process {r['arrival']!r}")
        if len(r["tenants"]) < 2:
            errors.append(f"{tag}: fewer than 2 tenants")
        for tn, trow in r["tenants"].items():
            tmissing = TENANT_KEYS - set(trow)
            if tmissing:
                errors.append(f"{tag}/{tn}: missing {sorted(tmissing)}")
                continue
            for row in LATENCY_ROWS:
                if LATENCY_KEYS - set(trow[row]):
                    errors.append(f"{tag}/{tn}: incomplete {row} row")
        for row in LATENCY_ROWS:
            if LATENCY_KEYS - set(r[row]):
                errors.append(f"{tag}: incomplete {row} row")
        if "latency_ms" in r or any("latency_ms" in t
                                    for t in r["tenants"].values()):
            errors.append(f"{tag}: deprecated combined latency_ms row "
                          "present — its one-release window is over")
        if r["completed"] != r["submitted"]:
            errors.append(f"{tag}: {r['submitted'] - r['completed']} "
                          "requests never finished (undrained queue)")
        if r["migration_bytes"] <= 0:
            errors.append(f"{tag}: migration_bytes must be nonzero — the "
                          "traffic bench moves real payload")
        for key in ("hit_rate", "hit_rate_steady"):
            if not 0.0 <= r[key] <= 1.0:
                errors.append(f"{tag}: {key} {r[key]} out of [0, 1]")
        _check_resources(tag, r["resources"], errors)
    if TRACE_KINDS <= set(rows) and all(
            "hit_rate_steady" in rows[k] for k in TRACE_KINDS):
        z = rows["zipf-hot"]["hit_rate_steady"]
        s = rows["scan-antagonist"]["hit_rate_steady"]
        if not z > s:
            errors.append(
                f"traffic: adaptivity signal lost — zipf-hot steady hit "
                f"rate {z:.3f} must exceed scan-antagonist {s:.3f}")


def _check_mass_ab(ab: dict, errors: list[str]) -> None:
    missing = MASS_AB_KEYS - set(ab)
    if missing:
        errors.append(f"mass_ab: missing keys {sorted(missing)}")
        return
    for arm in ("fill", "kernel"):
        amissing = MASS_AB_ARM_KEYS - set(ab[arm])
        if amissing:
            errors.append(f"mass_ab/{arm}: missing {sorted(amissing)}")
            return
        if ab[arm]["kv_mass_source"] != arm:
            errors.append(f"mass_ab/{arm}: arm records kv_mass_source "
                          f"{ab[arm]['kv_mass_source']!r}")
        for key in ("kv_hit", "kv_hit_steady"):
            if not 0.0 <= ab[arm][key] <= 1.0:
                errors.append(f"mass_ab/{arm}: {key} out of [0, 1]")
    if ab["fill"]["steps"] != ab["kernel"]["steps"] or \
            ab["fill"]["tokens"] != ab["kernel"]["tokens"]:
        errors.append("mass_ab: arms served different load — the A/B must "
                      "replay the identical trace")
    k = ab["kernel"]["kv_hit_steady"]
    f = ab["fill"]["kv_hit_steady"]
    if not k >= f:
        errors.append(
            f"mass_ab: hotness-fidelity gate lost — kernel-mass steady KV "
            f"hit rate {k:.3f} must be >= fill-proxy {f:.3f} "
            "(device-true hotness profiling worse than the host proxy)")


def _check_kv_reuse(kr: dict, errors: list[str]) -> None:
    """The cross-request KV reuse gates (DESIGN.md §12): reuse must never
    change tokens, substring matching must actually save prefill work and
    beat prefix matching (hole-skipping), and turning reuse on must not
    cost steady-state KV hit rate."""
    missing = KV_REUSE_KEYS - set(kr)
    if missing:
        errors.append(f"kv_reuse: missing keys {sorted(missing)}")
        return
    for arm in ("off", "prefix", "substring"):
        amissing = KV_REUSE_ARM_KEYS - set(kr[arm])
        if amissing:
            errors.append(f"kv_reuse/{arm}: missing {sorted(amissing)}")
            return
        if arm == "off":
            if kr[arm]["reuse"] is not None:
                errors.append("kv_reuse/off: baseline arm carries reuse "
                              "stats — the store was not disabled")
            continue
        st = kr[arm]["reuse"] or {}
        smissing = KV_REUSE_STAT_KEYS - set(st)
        if smissing:
            errors.append(f"kv_reuse/{arm}: reuse stats missing "
                          f"{sorted(smissing)}")
            return
        if not 0.0 <= st["hit_rate"] <= 1.0:
            errors.append(f"kv_reuse/{arm}: hit_rate {st['hit_rate']} "
                          "out of [0, 1]")
    if not kr["tokens_match"]:
        errors.append("kv_reuse: output tokens diverge across arms — KV "
                      "reuse changed what the model generated")
    if not kr["prefill_tokens_saved"] > 0:
        errors.append("kv_reuse: substring matching saved no prefill "
                      "tokens — the store never produced a hit")
    hs = kr["substring"]["reuse"]["hit_rate"]
    hp = kr["prefix"]["reuse"]["hit_rate"]
    if not hs > hp:
        errors.append(
            f"kv_reuse: substring page-hit rate {hs:.3f} must exceed "
            f"prefix {hp:.3f} — hole-skipping recovered nothing beyond "
            "the shared prefix")
    s, o = kr["substring"]["kv_hit_steady"], kr["off"]["kv_hit_steady"]
    if not s >= o:
        errors.append(
            f"kv_reuse: substring steady KV hit rate {s:.3f} fell below "
            f"reuse-off {o:.3f} — reuse degraded tiering behaviour")


def _check_disagg(d: dict, errors: list[str]) -> None:
    """The prefill/decode disaggregation gates (DESIGN.md §13): the split
    must never change tokens, the hand-off fabric must actually carry
    bytes both ways, and the decode worker's TPOT must stay flat under
    concurrent prefill while the unified arm measurably degrades."""
    missing = DISAGG_KEYS - set(d)
    if missing:
        errors.append(f"disagg: missing keys {sorted(missing)}")
        return
    for name in ("unified", "disagg"):
        arm = d[name]
        amissing = DISAGG_ARM_KEYS - set(arm)
        if amissing:
            errors.append(f"disagg/{name}: missing {sorted(amissing)}")
            return
        if HANDOFF_KEYS - set(arm["handoff"]):
            errors.append(f"disagg/{name}: incomplete handoff row")
            return
        for side in ("during", "quiet"):
            if arm["tpot_n"].get(side, 0) < 8:
                errors.append(
                    f"disagg/{name}: only {arm['tpot_n'].get(side, 0)} "
                    f"{side}-prefill decode gaps — the trace never "
                    "exercised the contention the A/B measures")
        _check_resources(f"disagg/{name}", arm["resources"], errors)
    if not d["tokens_match"]:
        errors.append("disagg: output tokens diverge between the unified "
                      "and disaggregated schedulers — bit-exactness lost")
    ho = d["disagg"]["handoff"]
    if not (ho["count"] > 0 and ho["bytes_out"] > 0 and ho["bytes_in"] > 0):
        errors.append(
            f"disagg: hand-off fabric idle (count={ho['count']}, "
            f"bytes_out={ho['bytes_out']}, bytes_in={ho['bytes_in']}) — "
            "requests never crossed the slow tier")
    if d["unified"]["handoff"]["count"] != 0:
        errors.append("disagg: unified arm recorded hand-offs — the "
                      "baseline ran the split scheduler")
    dd = d["disagg"]["tpot_degradation"]
    ud = d["unified"]["tpot_degradation"]
    if not dd <= DISAGG_MAX_DEGRADATION:
        errors.append(
            f"disagg: decode-lane TPOT degrades {dd:+.1%} with a "
            f"concurrent prefill on the dedicated lane (gate <= "
            f"{DISAGG_MAX_DEGRADATION:.0%}) — the split did not isolate "
            "the decode worker")
    if not ud >= UNIFIED_MIN_DEGRADATION:
        errors.append(
            f"disagg: unified-arm TPOT degradation {ud:+.1%} below the "
            f"{UNIFIED_MIN_DEGRADATION:.0%} floor — the trace carries no "
            "prefill contention, so the flatness gate proves nothing")
    if not dd < ud:
        errors.append(
            f"disagg: disagg degradation {dd:+.1%} not below unified "
            f"{ud:+.1%} — disaggregation bought nothing on this trace")


def _check_compress(c: dict, errors: list[str]) -> None:
    """The slow-tier codec gates (DESIGN.md §14): compression must pay in
    bytes without costing tokens — identical load across arms, fp32-arm
    bit-exactness, the int8 byte cut, hit-rate parity, bounded logit
    drift, and the zero1 compressed-collective parity + byte cut."""
    missing = COMPRESS_KEYS - set(c)
    if missing:
        errors.append(f"compress: missing keys {sorted(missing)}")
        return
    arms = c["arms"]
    if set(arms) != set(COMPRESS_ARMS):
        errors.append(f"compress: arms {sorted(arms)} != "
                      f"{sorted(COMPRESS_ARMS)}")
        return
    for name in COMPRESS_ARMS:
        arm = arms[name]
        amissing = COMPRESS_ARM_KEYS - set(arm)
        if amissing:
            errors.append(f"compress/{name}: missing {sorted(amissing)}")
            return
        if arm["codec"] != name:
            errors.append(f"compress/{name}: arm records codec "
                          f"{arm['codec']!r}")
        for res, h in arm["hit_steady"].items():
            if not 0.0 <= h <= 1.0:
                errors.append(f"compress/{name}: {res} hit_steady {h} "
                              "out of [0, 1]")
        _check_resources(f"compress/{name}", arm["resources"], errors)
    if len({(arms[a]["steps"], arms[a]["tokens"]) for a in COMPRESS_ARMS}) != 1:
        errors.append("compress: arms served different load — the A/B must "
                      "replay the identical trace under every codec")
    if not c["tokens_match_none_fp32"]:
        errors.append("compress: output tokens diverge between the none and "
                      "fp32 arms — a full-precision slow store changed what "
                      "the model generated")
    ratio = c["bytes_ratio_int8_fp32"]
    if not ratio <= c["bytes_ratio_bound"]:
        errors.append(
            f"compress: int8/fp32 migration-byte ratio {ratio:.3f} exceeds "
            f"{c['bytes_ratio_bound']} — the codec is not paying its way")
    if not arms["int8"]["migration_bytes"] > 0:
        errors.append("compress: int8 arm moved no migration bytes — the "
                      "byte-ratio gate proves nothing")
    eps = c["hit_eps"]
    for res, h8 in arms["int8"]["hit_steady"].items():
        hf = arms["fp32"]["hit_steady"].get(res, 0.0)
        if not h8 >= hf - eps:
            errors.append(
                f"compress: int8 steady hit rate on {res} {h8:.3f} fell "
                f"more than eps={eps} below fp32 {hf:.3f} — compression "
                "degraded tiering behaviour")
    p = c["probe"]
    pmissing = COMPRESS_PROBE_KEYS - set(p)
    if pmissing:
        errors.append(f"compress/probe: missing {sorted(pmissing)}")
        return
    if p["drift_fp32"] != 0.0 or not p["tokens_match_none_fp32"]:
        errors.append(
            f"compress/probe: fp32 logit drift {p['drift_fp32']} must be "
            "exactly 0 (bf16 -> fp32 -> bf16 is the identity) — the codec "
            "plumbing is not transparent")
    if not p["drift_int8"] <= p["drift_bound"]:
        errors.append(
            f"compress/probe: int8 logit drift {p['drift_int8']:.3f} "
            f"exceeds {p['drift_bound']} — quantization visibly moved the "
            "model")
    z = c["zero1"]
    zmissing = COMPRESS_ZERO1_KEYS - set(z)
    if zmissing:
        errors.append(f"compress/zero1: missing {sorted(zmissing)}")
        return
    if not z["update_drift"] <= z["drift_tolerance"]:
        errors.append(
            f"compress/zero1: param drift {z['update_drift']:.2e} exceeds "
            f"{z['drift_tolerance']} — the compressed collective lost "
            "fp32 parity")
    if not z["byte_ratio"] <= z["byte_ratio_bound"]:
        errors.append(
            f"compress/zero1: collective byte ratio {z['byte_ratio']:.3f} "
            f"exceeds {z['byte_ratio_bound']}")


def _check_overlap(o: dict, errors: list[str]) -> None:
    """The async-migration overlap gate (DESIGN.md §15): the double-buffered
    data plane must hide the epoch copies, not skip them — bit-exact tokens,
    byte-identical migration work per resource, decode stall cut to <= the
    declared fraction of the sync arm's, and nonzero achieved overlap on
    every resource that moved payload."""
    missing = OVERLAP_KEYS - set(o)
    if missing:
        errors.append(f"overlap: missing keys {sorted(missing)}")
        return
    for arm in ("sync", "async"):
        amissing = OVERLAP_ARM_KEYS - set(o[arm])
        if amissing:
            errors.append(f"overlap/{arm}: missing {sorted(amissing)}")
            return
        _check_resources(f"overlap/{arm}", o[arm]["resources"], errors)
    if not o["tokens_match"]:
        errors.append("overlap: async output tokens diverge from sync — "
                      "the stale committed epoch served different bytes")
    s, a = o["sync"], o["async"]
    for name in s["resources"]:
        sb = s["resources"][name]["migration_bytes"]
        ab = a["resources"].get(name, {}).get("migration_bytes")
        if sb != ab:
            errors.append(
                f"overlap/{name}: migration bytes diverge (sync {sb} vs "
                f"async {ab}) — overlap must hide the copies, not skip them")
    if not s["stall_s"] > 0:
        errors.append("overlap/sync: stall_s must be > 0 — the synchronous "
                      "arm's metered copy blocks are the A/B's baseline")
    elif not a["stall_s"] <= o["stall_ratio_bound"] * s["stall_s"]:
        errors.append(
            f"overlap: async stall {a['stall_s']:.3f}s exceeds "
            f"{o['stall_ratio_bound']} x sync {s['stall_s']:.3f}s — the "
            "async plane is blocking decode")
    for name, row in a["resources"].items():
        if row["migration_bytes"] and not row["overlap_bytes_per_decode_s"] > 0:
            errors.append(
                f"overlap/async/{name}: moved {row['migration_bytes']} bytes "
                "with zero overlap_bytes_per_decode_s — achieved-overlap "
                "metering is broken")
        if row["inflight_bytes"]:
            errors.append(
                f"overlap/async/{name}: inflight_bytes "
                f"{row['inflight_bytes']} after the finalize barrier — the "
                "bench failed to commit the tail epoch")


def _check_prefill(p: dict, errors: list[str]) -> None:
    """The chunked-prefill TTFT gate (DESIGN.md §11): a >= 512-token prompt
    served through the Scheduler must reach its first token in <= 1/4 the
    token-at-a-time wall when chunked (chunk >= page_t), with bit-exact
    output tokens — the prompt-length tail-latency fix, enforced in CI."""
    missing = PREFILL_KEYS - set(p)
    if missing:
        errors.append(f"prefill: missing keys {sorted(missing)}")
        return
    for arm in ("token", "chunked"):
        amissing = PREFILL_ARM_KEYS - set(p[arm])
        if amissing:
            errors.append(f"prefill/{arm}: missing {sorted(amissing)}")
            return
        if LATENCY_KEYS - set(p[arm]["tpot_ms"]):
            errors.append(f"prefill/{arm}: incomplete tpot_ms row")
        elif not p[arm]["tpot_ms"]["p50"] > 0:
            errors.append(f"prefill/{arm}: tpot_ms p50 must be > 0 — "
                          "decode gaps were never measured")
    if p["prompt_len"] < 512:
        errors.append(f"prefill: prompt_len {p['prompt_len']} < 512 — the "
                      "A/B must measure a long prompt")
    if p["chunk"] < p["page_t"]:
        errors.append(f"prefill: chunk {p['chunk']} < page_t {p['page_t']}")
    if not p["tokens_match"] or p["token"]["tokens"] != p["chunked"]["tokens"]:
        errors.append("prefill: chunked output tokens diverge from "
                      "token-at-a-time streaming — bit-exactness gate lost")
    t, c = p["token"]["ttft_ms"], p["chunked"]["ttft_ms"]
    if not c <= 0.25 * t:
        errors.append(
            f"prefill: chunked TTFT {c:.1f}ms must be <= 1/4 of "
            f"token-at-a-time {t:.1f}ms (ratio {c / max(t, 1e-9):.3f}) — "
            "the prompt-length tail-latency fix regressed")


def validate(path: str) -> list[str]:
    with open(path) as f:
        doc = json.load(f)
    errors: list[str] = []
    if not set(doc) <= {"quick", "cases", "traffic", "mass_ab", "prefill",
                        "kv_reuse", "disagg", "compress", "overlap"} or \
            not {"quick", "cases"} <= set(doc):
        errors.append(f"top-level keys {sorted(doc)} not in expected "
                      "['cases', 'quick'] (+ optional 'traffic', 'mass_ab', "
                      "'prefill', 'kv_reuse', 'disagg', 'compress', "
                      "'overlap')")
        return errors
    if not doc["cases"] and "traffic" not in doc:
        errors.append("no benchmark cases recorded")
    for case in doc["cases"]:
        arch = case.get("arch", "<missing arch>")
        missing = CASE_KEYS - set(case)
        if missing:
            errors.append(f"{arch}: missing case keys {sorted(missing)}")
            continue
        if case["migration_bytes"] <= 0:
            errors.append(f"{arch}: migration_bytes must be nonzero — the "
                          "serve bench is expected to move real payload")
        _check_resources(arch, case["resources"], errors)
    if doc["cases"] and "mass_ab" not in doc:
        errors.append("mass_ab section missing — serve_bench runs the "
                      "fill-vs-kernel fidelity A/B (DESIGN.md §10)")
    if doc["cases"] and "compress" not in doc:
        errors.append("compress section missing — serve_bench --compress "
                      "runs the slow-tier codec A/B (DESIGN.md §14)")
    if "compress" in doc:
        _check_compress(doc["compress"], errors)
    if "mass_ab" in doc:
        _check_mass_ab(doc["mass_ab"], errors)
    if "traffic" in doc:
        _check_traffic(doc["traffic"], errors)
        if "prefill" not in doc:
            errors.append("prefill section missing — traffic_bench runs the "
                          "chunked-prefill TTFT A/B (DESIGN.md §11)")
    if "prefill" in doc:
        _check_prefill(doc["prefill"], errors)
    if "kv_reuse" in doc:
        _check_kv_reuse(doc["kv_reuse"], errors)
    if "disagg" in doc:
        _check_disagg(doc["disagg"], errors)
    if "overlap" in doc:
        _check_overlap(doc["overlap"], errors)
    return errors


def main() -> int:
    default = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    path = sys.argv[1] if len(sys.argv) > 1 else default
    errors = validate(path)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    with open(path) as f:
        doc = json.load(f)
    n = len(doc["cases"])
    t = len(doc.get("traffic", {}).get("traces", []))
    ab = doc.get("mass_ab")
    gap = (f", mass A/B gap {ab['kernel']['kv_hit_steady'] - ab['fill']['kv_hit_steady']:+.3f}"
           if ab else "")
    pf = doc.get("prefill")
    ttft = f", prefill TTFT ratio {pf['ttft_ratio']:.3f}" if pf else ""
    kr = doc.get("kv_reuse")
    reuse = (f", kv_reuse saved {kr['prefill_tokens_saved']} tokens "
             f"(sub-pre gap {kr['hit_rate_gap']:+.3f})" if kr else "")
    dg = doc.get("disagg")
    disagg = (f", disagg TPOT {dg['disagg']['tpot_degradation']:+.1%} vs "
              f"unified {dg['unified']['tpot_degradation']:+.1%}"
              if dg else "")
    cp = doc.get("compress")
    compress = (f", int8/fp32 bytes {cp['bytes_ratio_int8_fp32']:.3f} "
                f"(drift {cp['probe']['drift_int8']:.3f}, zero1 "
                f"{cp['zero1']['byte_ratio']:.3f})" if cp else "")
    ov = doc.get("overlap")
    overlap = (f", overlap stall {ov['async']['stall_s']:.3f}s vs sync "
               f"{ov['sync']['stall_s']:.3f}s" if ov else "")
    print(f"BENCH_serve.json ok: {n} cases, {t} traffic traces{gap}{ttft}"
          f"{reuse}{disagg}{compress}{overlap}, schema + quota + "
          "conservation + adaptivity + fidelity + prefill + reuse + disagg "
          "+ compress + overlap checks pass")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
