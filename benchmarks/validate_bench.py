"""Validate BENCH_serve.json against the documented schema (CI gate).

Checks what benchmarks/README.md documents: every case and resource row
carries the expected keys, the serve bench actually moved migration bytes
(the data plane is live, not simulated), and no epoch exceeded its byte
quota.  Run after ``make bench-serve``:

    PYTHONPATH=src:. python benchmarks/validate_bench.py [path]
"""
from __future__ import annotations

import json
import os
import sys

CASE_KEYS = {
    "arch", "batch", "prompt_len", "n_tokens", "tokens_per_s", "wall_s",
    "migration_bytes", "migration_bytes_per_s", "resources",
}
RESOURCE_KEYS = {
    "name", "fast_reads", "slow_reads", "hit_rate", "promoted", "demoted",
    "ping_pong", "migration_bytes", "last_epoch_bytes", "quota_bytes",
    "migration_epochs", "flush_bytes",
}


def validate(path: str) -> list[str]:
    with open(path) as f:
        doc = json.load(f)
    errors = []
    if set(doc) != {"quick", "cases"}:
        errors.append(f"top-level keys {sorted(doc)} != ['cases', 'quick']")
        return errors
    if not doc["cases"]:
        errors.append("no benchmark cases recorded")
    for case in doc["cases"]:
        arch = case.get("arch", "<missing arch>")
        missing = CASE_KEYS - set(case)
        if missing:
            errors.append(f"{arch}: missing case keys {sorted(missing)}")
            continue
        if case["migration_bytes"] <= 0:
            errors.append(f"{arch}: migration_bytes must be nonzero — the "
                          "serve bench is expected to move real payload")
        for name, row in case["resources"].items():
            rmissing = RESOURCE_KEYS - set(row)
            if rmissing:
                errors.append(f"{arch}/{name}: missing keys "
                              f"{sorted(rmissing)}")
                continue
            if row["quota_bytes"] and row["last_epoch_bytes"] > row["quota_bytes"]:
                errors.append(
                    f"{arch}/{name}: last_epoch_bytes {row['last_epoch_bytes']}"
                    f" exceeds quota_bytes {row['quota_bytes']}")
            if not 0.0 <= row["hit_rate"] <= 1.0:
                errors.append(f"{arch}/{name}: hit_rate {row['hit_rate']} "
                              "out of [0, 1]")
    return errors


def main() -> int:
    default = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    path = sys.argv[1] if len(sys.argv) > 1 else default
    errors = validate(path)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    with open(path) as f:
        n = len(json.load(f)["cases"])
    print(f"BENCH_serve.json ok: {n} cases, schema + quota checks pass")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
