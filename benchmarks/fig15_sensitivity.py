"""Paper Fig. 15: sensitivity to migration interval, quota, sketch W and D.

Claims: short migration intervals win (NeoProf affords them); quota sweet
spot at moderate rates; wider sketches drive the error bound to 0 with
performance peaking near W=256K-equivalent; D=2 suffices.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import WORKLOADS, run_sim
from repro.core.sketch import SketchParams
from repro.core import sketch as sk

from benchmarks.common import BLOCK, FAST_RATIO, N_BLOCKS, N_PAGES, SIM_KW, Timer, emit


def _sim(wl="pagerank", seed=51, n_blocks=None, **over):
    kw = dict(SIM_KW)
    kw.update(over)
    stream = WORKLOADS[wl](n_pages=N_PAGES, block=BLOCK,
                           n_blocks=n_blocks, seed=seed)
    return run_sim("neomem", stream, n_pages=N_PAGES, fast_ratio=FAST_RATIO,
                   **kw)


def run(quick: bool = False):
    n_blocks = N_BLOCKS // 4 if quick else N_BLOCKS
    with Timer() as t:
        # (a) migration interval (blocks between promotion batches)
        for mi in (1, 4, 16):
            r = _sim(n_blocks=n_blocks, migration_interval=mi)
            emit(f"fig15a_migration_interval{mi}", t.s * 1e6,
                 f"runtime_ms={r.runtime*1e3:.2f} hit={r.hit_rate:.3f}")
        # (b) migration quota
        for q in (16, 64, 128, 512):
            r = _sim(n_blocks=n_blocks, quota_pages=q)
            emit(f"fig15b_quota{q}", 0.0,
                 f"runtime_ms={r.runtime*1e3:.2f} hit={r.hit_rate:.3f}")
        # (c) sketch width: error bound + performance
        for w_log in (10, 12, 14):
            w = 1 << w_log
            r = _sim(n_blocks=n_blocks, sketch_width=w)
            # standalone error-bound measurement at this width
            sp = SketchParams(width=w, depth=2)
            st = sk.sketch_init(sp)
            rng = np.random.default_rng(0)
            import jax.numpy as jnp
            for _ in range(8):
                st, _ = sk.sketch_update(
                    st, jnp.asarray(rng.integers(0, N_PAGES, 2048),
                                    jnp.int32), jnp.int32(1 << 30), sp)
            eb = int(sk.error_bound_from_hist(sk.sketch_histogram(st, sp), sp))
            emit(f"fig15c_width{w}", 0.0,
                 f"runtime_ms={r.runtime*1e3:.2f} hit={r.hit_rate:.3f} "
                 f"error_bound={eb}")
        # (d) sketch depth
        for d in (1, 2, 4):
            r = _sim(n_blocks=n_blocks, sketch_depth=d)
            emit(f"fig15d_depth{d}", 0.0,
                 f"runtime_ms={r.runtime*1e3:.2f} hit={r.hit_rate:.3f}")


if __name__ == "__main__":
    run()
