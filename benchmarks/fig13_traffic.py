"""Paper Fig. 13: slow-tier traffic + promotion counts per method.

Claim: NeoMem shows the lowest slow-tier traffic; its promotion count is far
below AutoNUMA's (accurate detection) and in PTE-scan's range.
"""
from __future__ import annotations

from repro.core.simulator import WORKLOADS, run_sim

from benchmarks.common import (BLOCK, FAST_RATIO, METHODS, N_BLOCKS, N_PAGES,
                               SIM_KW, Timer, emit)

WL = ["gups", "silo", "pagerank"]


def run(quick: bool = False):
    n_blocks = N_BLOCKS // 4 if quick else N_BLOCKS
    with Timer() as t:
        for wl in WL:
            rows = {}
            for m in METHODS:
                stream = WORKLOADS[wl](n_pages=N_PAGES, block=BLOCK,
                                       n_blocks=n_blocks, seed=31)
                rows[m] = run_sim(m, stream, n_pages=N_PAGES,
                                  fast_ratio=FAST_RATIO, **SIM_KW)
            base = max(rows["pebs"].slow_hits, 1)
            traffic = " ".join(f"{m}={rows[m].slow_hits/base:.2f}"
                               for m in METHODS)
            promos = " ".join(f"{m}={rows[m].promoted}" for m in METHODS)
            emit(f"fig13_{wl}_slow_traffic_norm_pebs", t.s * 1e6, traffic)
            emit(f"fig13_{wl}_promotions", 0.0, promos)


if __name__ == "__main__":
    run()
