"""Unit + property tests for the CM-sketch hot-page detector (paper §IV-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import sketch as sk
from repro.core.sketch import SketchParams

SP = SketchParams(width=1 << 12, depth=2)


def _stream(ids):
    return jnp.asarray(np.asarray(ids, np.int32))


class TestH3:
    def test_range(self):
        st_ = sk.sketch_init(SP)
        ids = jnp.arange(1000, dtype=jnp.int32)
        h = sk.h3_hash(ids, st_.seeds)
        assert h.shape == (SP.depth, 1000)
        assert int(h.min()) >= 0 and int(h.max()) < SP.width

    def test_deterministic(self):
        st_ = sk.sketch_init(SP)
        ids = jnp.asarray([3, 7, 3], jnp.int32)
        h = sk.h3_hash(ids, st_.seeds)
        assert int(h[0, 0]) == int(h[0, 2])

    def test_linear_property(self):
        """H3 is XOR-linear: h(a^b) == h(a)^h(b) (paper Eq. 5)."""
        st_ = sk.sketch_init(SP)
        a, b = jnp.int32(0b1010101), jnp.int32(0b0110011)
        ha = sk.h3_hash(a[None], st_.seeds)
        hb = sk.h3_hash(b[None], st_.seeds)
        hab = sk.h3_hash((a ^ b)[None], st_.seeds)
        np.testing.assert_array_equal(np.asarray(ha ^ hb), np.asarray(hab))


class TestSketchUpdate:
    def test_overestimate_property(self):
        """CM-sketch NEVER underestimates (Eq. 3 lower bound)."""
        st_ = sk.sketch_init(SP)
        rng = np.random.default_rng(0)
        ids = rng.integers(0, 1 << 16, 2048).astype(np.int32)
        st_, _ = sk.sketch_update(st_, _stream(ids), jnp.int32(1 << 30), SP)
        uniq, counts = np.unique(ids, return_counts=True)
        est = sk.sketch_query(st_, _stream(uniq), SP)
        assert np.all(np.asarray(est) >= counts)

    def test_hot_detection_and_filter(self):
        st_ = sk.sketch_init(SP)
        ids = np.concatenate([np.full(64, 42), np.arange(100, 228)]).astype(np.int32)
        st_, hot = sk.sketch_update(st_, _stream(ids), jnp.int32(32), SP)
        hot_ids = set(np.asarray(ids)[np.asarray(hot)].tolist())
        assert hot_ids == {42}
        # second block: filtered by hot bits
        st_, hot2 = sk.sketch_update(st_, _stream(np.full(16, 42, np.int32)),
                                     jnp.int32(32), SP)
        assert int(hot2.sum()) == 0

    def test_padding_ignored(self):
        st_ = sk.sketch_init(SP)
        ids = np.full(128, -1, np.int32)
        st2, hot = sk.sketch_update(st_, _stream(ids), jnp.int32(0), SP)
        assert int(hot.sum()) == 0
        assert int(st2.n_seen) == 0

    def test_clear_is_epoch_bump(self):
        st_ = sk.sketch_init(SP)
        st_, _ = sk.sketch_update(st_, _stream(np.full(10, 5, np.int32)),
                                  jnp.int32(100), SP)
        assert int(sk.sketch_query(st_, _stream([5]), SP)[0]) >= 10
        st_ = sk.sketch_clear(st_)
        assert int(sk.sketch_query(st_, _stream([5]), SP)[0]) == 0
        # and counters come back after re-touch
        st_, _ = sk.sketch_update(st_, _stream(np.full(3, 5, np.int32)),
                                  jnp.int32(100), SP)
        assert int(sk.sketch_query(st_, _stream([5]), SP)[0]) >= 3

    def test_counter_saturation(self):
        sp = SketchParams(width=256, depth=2, counter_bits=8)
        st_ = sk.sketch_init(sp)
        for _ in range(3):
            st_, _ = sk.sketch_update(
                st_, _stream(np.full(200, 9, np.int32)), jnp.int32(1 << 20), sp)
        assert int(sk.sketch_query(st_, _stream([9]), sp)[0]) == 255

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=256))
    def test_hypothesis_overestimate(self, ids):
        st_ = sk.sketch_init(SP)
        arr = np.asarray(ids, np.int32)
        st_, _ = sk.sketch_update(st_, _stream(arr), jnp.int32(1 << 30), SP)
        uniq, counts = np.unique(arr, return_counts=True)
        est = np.asarray(sk.sketch_query(st_, _stream(uniq), SP))
        assert np.all(est >= counts)


class TestHistogram:
    def test_hist_sums_to_width(self):
        st_ = sk.sketch_init(SP)
        rng = np.random.default_rng(1)
        st_, _ = sk.sketch_update(
            st_, _stream(rng.integers(0, 4096, 2048)), jnp.int32(1 << 30), SP)
        h = sk.sketch_histogram(st_, SP)
        assert int(h.sum()) == SP.width

    def test_error_bound_grows_with_load(self):
        sp = SketchParams(width=256, depth=2)
        st_ = sk.sketch_init(sp)
        rng = np.random.default_rng(2)
        e0 = int(sk.error_bound_from_hist(sk.sketch_histogram(st_, sp), sp))
        for _ in range(8):
            st_, _ = sk.sketch_update(
                st_, _stream(rng.integers(0, 1 << 20, 2048)),
                jnp.int32(1 << 30), sp)
        e1 = int(sk.error_bound_from_hist(sk.sketch_histogram(st_, sp), sp))
        assert e1 > e0

    def test_wide_sketch_zero_error(self):
        """Paper Fig.15-(c): W=512K drives the error bound to ~0; here the
        scaled-down version — width >> stream cardinality => bound ~ 0."""
        sp = SketchParams(width=1 << 14, depth=2)
        st_ = sk.sketch_init(sp)
        rng = np.random.default_rng(3)
        st_, _ = sk.sketch_update(
            st_, _stream(rng.integers(0, 64, 1024)), jnp.int32(1 << 30), sp)
        e = int(sk.error_bound_from_hist(sk.sketch_histogram(st_, sp), sp))
        assert e <= 1

    def test_quantile_monotone(self):
        st_ = sk.sketch_init(SP)
        rng = np.random.default_rng(4)
        st_, _ = sk.sketch_update(
            st_, _stream(rng.integers(0, 2048, 4096)), jnp.int32(1 << 30), SP)
        h = sk.sketch_histogram(st_, SP)
        qs = [int(sk.quantile_from_hist(h, q)) for q in (0.5, 0.9, 0.99, 0.999)]
        assert qs == sorted(qs)
