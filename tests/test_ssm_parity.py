"""Chunked-parallel vs step-recurrent parity for the SSM blocks.

The training paths (chunked SSD / chunked mLSTM / associative-scan sLSTM)
and the O(1)-state decode paths are independent implementations of the same
recurrences — they must agree step-for-step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as m2
from repro.models import xlstm as xl

B, S, D = 2, 32, 64


def _roll(decode_fn, init_cache, u):
    outs = []
    c = init_cache
    for t in range(u.shape[1]):
        o, c = decode_fn(u[:, t:t + 1], c)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_mamba2_chunked_vs_recurrent():
    key = jax.random.PRNGKey(0)
    p = m2.mamba2_init(key, D, d_state=16, expand=2, headdim=16)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32) * 0.5
    y_par = m2.mamba2_apply(p, u, headdim=16, d_state=16, chunk=8)
    cache = m2.mamba2_init_cache(B, p, headdim=16, d_state=16)
    y_seq = _roll(lambda ut, c: m2.mamba2_decode(p, ut, c, headdim=16,
                                                 d_state=16), cache, u)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_chunked_vs_recurrent():
    key = jax.random.PRNGKey(2)
    p = xl.mlstm_init(key, D, n_heads=4)
    u = jax.random.normal(jax.random.PRNGKey(3), (B, S, D), jnp.float32) * 0.5
    y_par = xl.mlstm_apply(p, u, n_heads=4, chunk=8)
    cache = xl.mlstm_init_cache(B, D, 4)
    y_seq = _roll(lambda ut, c: xl.mlstm_decode(p, ut, c, n_heads=4), cache, u)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_slstm_scan_vs_recurrent():
    key = jax.random.PRNGKey(4)
    p = xl.slstm_init(key, D)
    u = jax.random.normal(jax.random.PRNGKey(5), (B, S, D), jnp.float32) * 0.5
    y_par = xl.slstm_apply(p, u)
    cache = xl.slstm_init_cache(B, D)
    y_seq = _roll(lambda ut, c: xl.slstm_decode(p, ut, c), cache, u)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_mamba2_chunk_size_invariance(chunk):
    """SSD output must not depend on the chunking (algebraic identity)."""
    key = jax.random.PRNGKey(6)
    p = m2.mamba2_init(key, D, d_state=16, expand=2, headdim=16)
    u = jax.random.normal(jax.random.PRNGKey(7), (B, S, D), jnp.float32) * 0.5
    y_ref = m2.mamba2_apply(p, u, headdim=16, d_state=16, chunk=S)
    y = m2.mamba2_apply(p, u, headdim=16, d_state=16, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
