"""Content-addressed KV page store (DESIGN.md §12): the dual content/chain
hash scheme, refcount lifecycle + LRU eviction, hole-skipping substring
matching vs prefix matching, bit-exact cross-request reuse through the
scheduler, and preempt/resume of a lane holding shared (refcount > 1)
pages — no clobber, no double-free."""
import jax
import numpy as np
import pytest

from repro.cache import KVReuseStore, hash_pages
from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant

ARCH = "llama3.2-3b"
PAGE_T = 4
BASE_KW = dict(max_seq=48, paged=True, page_t=PAGE_T, hot_slots=5,
               migration_interval=4, resources=("embeddings",),
               embed_hot_slots=4, embed_rows_per_page=8)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config(ARCH)
    return cfg, tr.init_params(cfg, jax.random.PRNGKey(0))


def _prompt(seed, n=8):
    vocab = get_smoke_config(ARCH).vocab
    return (np.random.default_rng(seed).integers(0, vocab, n)
            .astype(np.int32))


# -- hash scheme --------------------------------------------------------------

def test_hash_pages_content_position_independent():
    """The same token span hashes to the same content bucket at any offset
    (the index key), while the chain hash tracks the causal prefix."""
    span = np.arange(PAGE_T, dtype=np.int32) + 7
    a = np.concatenate([span, np.full(PAGE_T, 3, np.int32), span])
    content, chain = hash_pages(a, PAGE_T)
    assert content.size == chain.size == 3
    assert content[0] == content[2]            # same span, offsets 0 and 2
    assert chain[0] != chain[2]                # different causal prefixes
    assert len(set(chain.tolist())) == 3


def test_hash_pages_chain_witnesses_full_prefix():
    """Perturbing one token in page 0 leaves later pages' CONTENT hashes
    untouched but rewrites every chain hash — the witness that forbids
    reusing a page whose causal prefix changed."""
    toks = _prompt(0, 4 * PAGE_T)
    c1, h1 = hash_pages(toks, PAGE_T)
    mut = toks.copy()
    mut[1] = (mut[1] + 1) % 251
    c2, h2 = hash_pages(mut, PAGE_T)
    assert c1[0] != c2[0]
    np.testing.assert_array_equal(c1[1:], c2[1:])
    assert all(h1[j] != h2[j] for j in range(4))
    # incomplete trailing pages are never hashed
    assert hash_pages(toks[:PAGE_T + 1], PAGE_T)[0].size == 1


# -- store bookkeeping --------------------------------------------------------

def _store(n_pages=8):
    return KVReuseStore(n_pages, base_gid=100, page_t=PAGE_T)


def test_match_excludes_final_prompt_page_and_diverged_chains():
    """The final prompt token's page must be scanned (it produces the
    first-token logits), and a diverged early page poisons every later
    page's chain — substring matching must NOT hand those out."""
    store = _store()
    stream = _prompt(1, 5 * PAGE_T)
    store.publish(stream, n_pages=5)
    res = store.match(stream, mode="substring")
    assert res.n_matchable == 4                # page 4 holds the last token
    assert sorted(res.pages) == [0, 1, 2, 3]
    mut = stream.copy()
    mut[0] = (mut[0] + 1) % 251                # diverge inside page 0
    res2 = store.match(mut, mode="substring")
    assert res2.pages == {}                    # chains all differ: zero hits


def test_refcount_blocks_eviction_and_release_frees():
    """Matched (referenced) pages are never reclaimed: a full pool rejects
    new publishes instead; releasing the refs makes them evictable again,
    and over-release raises (double-free guard)."""
    store = _store(n_pages=4)
    a = _prompt(2, 4 * PAGE_T + 1)
    store.publish(a, n_pages=4)
    res = store.match(a, mode="substring")     # acquires refs on pages 0-3
    held = list(res.pages.values())
    b = _prompt(3, 2 * PAGE_T)
    assert store.publish(b, n_pages=2) == []   # nothing reclaimable
    assert store.stats()["rejected"] == 2
    store.release(held)
    new = store.publish(b, n_pages=2)
    assert len(new) == 2                       # LRU-evicted a's front pages
    assert store.stats()["evicted"] == 2
    with pytest.raises(ValueError):
        store.release([held[0]])               # refcount already zero


def test_publish_eviction_of_same_content_bucket_keeps_index_sound():
    """REVIEW regression: publishing a page whose content hash collides
    with the LRU victim's bucket must not orphan the bucket — _alloc's
    eviction can delete ``index[content]`` mid-publish, and the new entry
    must land in a fresh bucket, stay reachable via lookup_page, and stay
    evictable (no KeyError on a later _evict)."""
    store = KVReuseStore(2, base_gid=100, page_t=1)
    store.publish([7], 1)                      # pool page A: content(7)@0
    store.publish([9, 7], 2)                   # (9)@0 + (7)@1: evicts A
    c, ch = hash_pages([9, 7], 1)
    assert store.lookup_page(c[1], ch[1], 1) is not None   # reachable
    store.publish([11], 1)                     # evicts (9)@0
    store.publish([13], 1)                     # evicts (7)@1 — was KeyError
    assert len(store.key_of) == 2
    assert store.stats()["evicted"] == 3
    for gid, (kc, kch, koff) in store.key_of.items():
        assert store.lookup_page(kc, kch, koff) == gid


def test_tokens_saved_counts_consumed_installs_only():
    """REVIEW regression: a match that is never installed (request
    preempted and abandoned) must not inflate tokens_saved — only
    note_consumed (driven by install_lane_pages) charges it; match-time
    counters stay lookup stats."""
    store = _store()
    stream = _prompt(7, 4 * PAGE_T + 1)
    store.publish(stream, n_pages=4)
    res = store.match(stream, mode="substring")
    assert len(res.pages) == 4
    assert store.stats()["page_hits"] == 4     # lookup stat: at match
    assert store.stats()["tokens_saved"] == 0  # nothing consumed yet
    store.note_consumed(3)
    assert store.stats()["tokens_saved"] == 3 * PAGE_T
    store.release(list(res.pages.values()))


def test_substring_recovers_tail_past_evicted_front():
    """LRU eviction punches front-of-history holes: prefix matching stops
    dead at the first hole, substring matching recovers the surviving
    interior (the MemGPT-style gap the agentic bench measures)."""
    store = _store(n_pages=8)
    a = _prompt(4, 6 * PAGE_T + 1)             # 6 matchable pages
    store.publish(a, n_pages=6)
    store.publish(_prompt(5, 2 * PAGE_T), n_pages=2)   # pool now full
    store.publish(_prompt(6, 2 * PAGE_T), n_pages=2)   # evicts a's pages 0-1
    pre = store.match(a, mode="prefix")
    assert pre.pages == {}                     # hole at page 0: nothing
    sub = store.match(a, mode="substring")
    assert sorted(sub.pages) == [2, 3, 4, 5]   # tail recovered
    store.release(list(sub.pages.values()))


# -- end-to-end through the scheduler ----------------------------------------

def _sched(cfg_params, reuse_pages, lanes=2, segments=None, patience=16,
           mode="substring", tenants=(("t", 1.0),)):
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        **BASE_KW, lanes=lanes, kv_segments=segments or lanes,
        reuse_pages=reuse_pages))
    sched = Scheduler(eng, [Tenant(n, w) for n, w in tenants],
                      SchedConfig(preempt_patience=patience,
                                  reuse_match=mode))
    return eng, sched


def test_reuse_bit_exact_with_hits_and_metered_reads(cfg_params):
    """Sequential requests sharing a system prefix: reuse must not change a
    single output token, must actually hit pages and save prefill tokens,
    and installed pages are charged to the admitting tenant's read meters
    at admission."""
    sys_p, u1, u2 = _prompt(10, 12), _prompt(11, 7), _prompt(12, 6)
    prompts = [np.concatenate([sys_p, u1]),
               np.concatenate([sys_p, u1, u2]),     # extends the first
               np.concatenate([sys_p, u2])]         # shares only sys_p

    def run(reuse_pages, mode="substring"):
        eng, sched = _sched(cfg_params, reuse_pages, mode=mode)
        outs = []
        for p in prompts:
            r = sched.submit("t", p, max_new=4)
            sched.run(max_steps=400)
            outs.append(list(r.out))
        return outs, eng, sched

    base, _, _ = run(0)
    for mode in ("prefix", "substring"):
        outs, eng, sched = run(16, mode)
        assert outs == base
        st = eng.reuse.stats()
        assert st["page_hits"] > 0 and st["tokens_saved"] > 0
        assert st["published"] > 0
        assert sum(st.values()) >= 0            # schema sanity
        ts = sched.tenant_stats["t"]
        assert ts.fast_reads + ts.slow_reads > 0


def test_reuse_requires_eligible_arch(cfg_params):
    """The store is gated to single-block attention stacks: recurrent
    archs (whose lane state is not pure paged KV) must refuse it."""
    cfg = get_smoke_config("xlstm-1.3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, ServeConfig(
            max_seq=32, paged=True, page_t=4, hot_slots=4,
            migration_interval=4, lanes=1, reuse_pages=8))


def test_preempt_resume_with_shared_refcount_pages(cfg_params):
    """A lane holding shared (refcount > 1) pool pages is preempted, the
    lane serves another request that references the SAME pool pages, and
    the original resumes bit-exactly: no clobbered payload, no double-free,
    and every reference is returned on finish."""
    cfg, params = cfg_params
    shared = _prompt(20, 16)                   # 3 matchable pages
    long_p = np.concatenate([shared, _prompt(21, 4)])

    ref_eng = ServeEngine(cfg, params, ServeConfig(
        **{**BASE_KW, "resources": ()}))

    def reference(prompt, n):
        return list(ref_eng.generate(np.asarray(prompt)[None],
                                     n_tokens=n)[0])

    eng, sched = _sched(cfg_params, reuse_pages=16, lanes=1, segments=2,
                        patience=4,
                        tenants=(("long", 1.0), ("short", 4.0)))
    seed_req = sched.submit("long", shared, max_new=4)   # publishes pages
    sched.run(max_steps=200)
    assert eng.reuse.stats()["published"] > 0

    rl = sched.submit("long", long_p, max_new=20)        # holds shared refs
    for _ in range(10):
        sched.step()
    rs = sched.submit("short", shared, max_new=4)        # same shared pages
    saw_shared = False
    for _ in range(400):
        if rs.state == rl.state == "finished":
            break
        saw_shared = saw_shared or any(v > 1 for v in eng.reuse.ref.values())
        sched.step()
    assert rl.preemptions >= 1                 # the lane really was taken
    assert saw_shared                          # both requests held one page
    assert rl.out == reference(long_p, 20)     # bit-exact across preemption
    assert rs.out == reference(shared, 4)
    assert seed_req.out == reference(shared, 4)
    assert sum(eng.reuse.ref.values()) == 0    # every ref returned


def test_resume_keeps_shared_pages_clean_across_flush(cfg_params):
    """REVIEW regression: resume_lane must re-seed the flush tracker's
    clean records for installed shared pages — otherwise the next
    _flush_kv_lanes sees every shared-mapped slot as dirty and forks the
    whole lane to private copies, silently dropping CoW sharing after
    every preempt/resume."""
    shared = _prompt(30, 16)
    long_p = np.concatenate([shared, _prompt(31, 4)])
    eng, sched = _sched(cfg_params, reuse_pages=16, lanes=1, segments=2)
    sched.submit("t", shared, max_new=4)       # publish the shared pages
    sched.run(max_steps=200)
    sched.submit("t", long_p, max_new=8)
    for _ in range(3):                         # admit + install the run
        sched.step()
    assert (eng._lane_pages[0] >= eng.reuse.base_gid).sum() > 0
    residual = eng.preempt_lane(0)
    eng.resume_lane(0, residual)
    mapped = eng._lane_pages[0].copy()
    flushed = dict(eng._kv_flushed)
    eng._flush_kv_lanes()                      # non-force: all slots clean
    np.testing.assert_array_equal(eng._lane_pages[0], mapped)  # no fork
    assert dict(eng._kv_flushed) == flushed    # no redundant flush traffic
    sched.run(max_steps=400)                   # drain; refs all come home
    assert sum(eng.reuse.ref.values()) == 0
