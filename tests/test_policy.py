"""Algorithm 1 (dynamic hotness threshold) behavioral tests."""
import numpy as np

from repro.core.policy import (PolicyParams, PolicyState, update_threshold,
                               quantile_from_hist_np)
from repro.core.sketch import SketchParams, hist_edges


def _hist_with_counts(values):
    """Histogram with given counter values (rest zeros to width)."""
    edges = hist_edges()
    h = np.zeros(64, np.int64)
    for v in values:
        b = np.searchsorted(edges, v, side="right") - 1
        h[min(b, 63)] += 1
    h[0] += 4096 - len(values)
    return h


def _step(policy, params, hist, bw=0.0, pp=0.0, migrated=0, err=0):
    return update_threshold(policy, params, hist, bw, pp, migrated, err)


def test_bandwidth_raises_p():
    params = PolicyParams()
    hist = _hist_with_counts([100] * 60 + [10] * 400)
    p0 = PolicyState.init(params)
    p_low = _step(p0, params, hist, bw=0.0)
    p_high = _step(p0, params, hist, bw=1.0)
    assert p_high.p >= p_low.p    # line 10: theta inversely prop. to B


def test_ping_pong_lowers_p():
    params = PolicyParams()
    hist = _hist_with_counts([100] * 60)
    p0 = PolicyState.init(params)
    p_quiet = _step(p0, params, hist, pp=0.0)
    p_noisy = _step(p0, params, hist, pp=2.0)
    assert p_noisy.p <= p_quiet.p  # line 10: theta prop. to P


def test_quota_halves_p():
    params = PolicyParams(m_quota_pages=100)
    hist = _hist_with_counts([100] * 60)
    p0 = PolicyState.init(params)
    p1 = _step(p0, params, hist, migrated=1000)
    assert p1.p == max(params.p_min, p0.p / 2)   # line 13


def test_error_bound_halves_p():
    params = PolicyParams()
    hist = _hist_with_counts([2] * 4000)   # all counters tiny
    p0 = PolicyState.init(params)
    p1 = _step(p0, params, hist, err=10_000)   # E >> Q_F(1-p)
    assert p1.p <= p0.p / 2 or p1.p == params.p_min   # lines 14-15


def test_p_bounded():
    params = PolicyParams()
    hist = _hist_with_counts([100] * 60)
    p = PolicyState.init(params)
    for _ in range(50):
        p = _step(p, params, hist, bw=1.0)     # push p up hard
    assert p.p <= params.p_max + 1e-12
    for _ in range(50):
        p = _step(p, params, hist, pp=10.0)    # push p down hard
    assert p.p >= params.p_min - 1e-12


def test_theta_follows_distribution():
    """theta = Q_F(1-p): hotter histogram => higher threshold."""
    params = PolicyParams()
    cold = _hist_with_counts([5] * 100)
    hot = _hist_with_counts([500] * 100)
    p0 = PolicyState.init(params)
    t_cold = _step(p0, params, cold).theta
    t_hot = _step(p0, params, hot).theta
    assert t_hot >= t_cold


def test_quantile_from_hist():
    hist = np.zeros(64, np.int64)
    hist[0] = 90   # 90 counters in bin [0,1)
    hist[10] = 10  # 10 counters at value ~10
    q50 = quantile_from_hist_np(hist, 0.5)
    q99 = quantile_from_hist_np(hist, 0.99)
    assert q50 <= q99
