"""Unit tests for the unified repro.tiering surface.

Covers: the TieredResource registry + stream encoders, the TieredMemoryState
pytree + pure observe/lookup, the multiplexed daemon's shared-quota split,
the ExpertCache single-spec regression (daemon and tier geometry must agree),
and the pinned 2Q eviction preference order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tiering as tm
from repro.core import tiering as tier_lib
from repro.core.adapters.expert_cache import ExpertCache, ExpertTierConfig
from repro.core.tiering import TierParams, tier_init


# ---------------------------------------------------------------------------
# registry + encoders
# ---------------------------------------------------------------------------

def test_registry_has_builtin_kinds():
    kinds = tm.resource_kinds()
    assert {"kv", "experts", "embeddings"} <= set(kinds)
    with pytest.raises(KeyError):
        tm.make_resource("no-such-kind", None)


def test_kv_encoder_masks_low_mass_pages():
    spec = tm.ResourceSpec("kv", n_pages=16, hot_slots=4)
    res = tm.make_resource("kv", spec, mass_threshold=0.25)
    mass = jnp.asarray([0.7, 0.2, 0.1, 0.0])
    ids = jnp.asarray([3, 5, 7, 9], jnp.int32)
    out = np.asarray(res.encode_stream(mass, ids))
    np.testing.assert_array_equal(out, [3, -1, -1, -1])


def test_expert_encoder_flattens_group_pages():
    spec = tm.ResourceSpec("experts", n_pages=2 * 4, hot_slots=2)
    res = tm.make_resource("experts", spec, n_experts=4)
    # (G=2, n_moe=1, B=1, S=2, k=1)
    streams = jnp.asarray([[[[[0], [3]]]], [[[[1], [2]]]]], jnp.int32)
    out = np.asarray(res.encode_stream(streams))
    np.testing.assert_array_equal(out, [0, 3, 4 + 1, 4 + 2])


def test_embed_encoder_maps_rows_to_pages():
    spec = tm.ResourceSpec("embeddings", n_pages=8, hot_slots=2)
    res = tm.make_resource("embeddings", spec, rows_per_page=64)
    out = np.asarray(res.encode_stream(jnp.asarray([0, 63, 64, 129], jnp.int32)))
    np.testing.assert_array_equal(out, [0, 0, 1, 2])


def test_encoder_subsamples_to_stream_cap():
    spec = tm.ResourceSpec("embeddings", n_pages=8, hot_slots=2, stream_cap=128)
    res = tm.make_resource("embeddings", spec)
    out = res.encode_stream(jnp.zeros((1000,), jnp.int32))
    assert out.shape[0] <= 128


# ---------------------------------------------------------------------------
# TieredMemory: pytree state, pure observe/lookup
# ---------------------------------------------------------------------------

def _small_mem(**kw):
    spec = tm.ResourceSpec("t", n_pages=64, hot_slots=8, quota_pages=4,
                           sketch_width=1 << 8, **kw)
    return tm.TieredMemory.from_spec(spec), spec


def test_state_is_a_pytree_of_arrays():
    mem, _ = _small_mem()
    state = mem.init()
    leaves = jax.tree.leaves(state)
    assert leaves and all(hasattr(x, "shape") for x in leaves)
    # round-trips through flatten/unflatten (checkpointable / jit-carryable)
    rebuilt = jax.tree.unflatten(jax.tree.structure(state), leaves)
    assert int(rebuilt.tick) == 0 and float(rebuilt.p) == float(state.p)


def test_observe_is_pure_and_jittable():
    mem, _ = _small_mem()
    s0 = mem.init()
    pages = jnp.asarray([1, 2, 2, 3, -1], jnp.int32)
    s1 = mem.observe(s0, pages)
    s2 = mem.observe(s0, pages)           # same input, same output
    assert int(s0.tier.slow_reads) == 0   # input state unchanged
    np.testing.assert_array_equal(np.asarray(s1.prof.sketch.counts),
                                  np.asarray(s2.prof.sketch.counts))
    # explicit jit over the facade's pure function
    jitted = jax.jit(lambda s, p: tm.observe(s, p, mem.pp))
    s3 = jitted(s0, pages)
    np.testing.assert_array_equal(np.asarray(s3.tier.fast_reads),
                                  np.asarray(s1.tier.fast_reads))


def test_lookup_reports_residency():
    mem, _ = _small_mem()
    state = mem.init()
    mem.enqueue(np.asarray([5, 9]))
    stats = tm.TierStats()
    state, event = mem.migrate(state, stats)
    assert event is not None and event.n_promoted == 2
    slots, hit = tm.lookup(state, jnp.asarray([5, 9, 11], jnp.int32))
    assert np.asarray(hit).tolist() == [True, True, False]
    assert (np.asarray(slots)[:2] >= 0).all()


# ---------------------------------------------------------------------------
# multiplexed daemon: quota split + independent stats
# ---------------------------------------------------------------------------

def test_split_quota_proportional_largest_remainder():
    shares = tm.split_quota(10, {"a": 30, "b": 10})
    assert shares == {"a": 8, "b": 2}      # 7.5/2.5 -> 8/2
    assert tm.split_quota(10, {"a": 3, "b": 2}) == {"a": 3, "b": 2}  # fits
    assert sum(tm.split_quota(7, {"a": 5, "b": 5, "c": 5}).values()) == 7


def test_split_quota_caps_unservable_backlog():
    """A huge backlog one resource can't promote anyway must not draw budget
    away from a resource that can use it."""
    shares = tm.split_quota(128, {"kv": 1000, "experts": 100},
                            caps={"kv": 64, "experts": 64})
    assert shares == {"kv": 64, "experts": 64}
    shares = tm.split_quota(96, {"kv": 1000, "experts": 32},
                            caps={"kv": 64, "experts": 64})
    assert shares == {"kv": 64, "experts": 32}
    # still proportional when the capped demand exceeds the budget
    shares = tm.split_quota(64, {"kv": 64, "experts": 64},
                            caps={"kv": 64, "experts": 64})
    assert shares == {"kv": 32, "experts": 32}


def test_multiplexed_daemon_independent_resources():
    daemon = tm.NeoMemDaemon(tm.DaemonParams(
        migration_interval=1, threshold_update_period=4, clear_interval=16))
    specs = {
        "embeddings": tm.ResourceSpec("embeddings", n_pages=128, hot_slots=16,
                                      quota_pages=8, sketch_width=1 << 10),
        "experts": tm.ResourceSpec("experts", n_pages=32, hot_slots=8,
                                   quota_pages=8, sketch_width=1 << 10),
    }
    emb = daemon.register(tm.make_resource("embeddings", specs["embeddings"]))
    exp = daemon.register(tm.make_resource("experts", specs["experts"],
                                           n_experts=16))
    rng = np.random.default_rng(0)
    for _ in range(24):
        toks = (rng.zipf(1.5, 512) % (128 * 64)).astype(np.int32)
        daemon.observe("embeddings", jnp.asarray(toks))
        # experts 0..3 hot in both groups: (G=2, 1, B=2, S=8, k=2)
        idx = rng.choice(4, size=(2, 1, 2, 8, 2)).astype(np.int32)
        daemon.observe("experts", jnp.asarray(idx))
        daemon.tick()
    assert set(daemon.stats()) == {"embeddings", "experts"}
    # both resources promoted under the shared budget and count stats apart
    assert emb.stats.promoted + emb.stats.migrated_this_period > 0
    assert exp.stats.promoted + exp.stats.migrated_this_period > 0
    assert exp.hit_rate() > 0.5           # 4 hot experts x 2 groups fit in 8
    assert emb.hit_rate() != exp.hit_rate()
    # the hot experts became resident
    resident = set(np.flatnonzero(np.asarray(exp.state.tier.page_slot) >= 0))
    hot = {g * 16 + e for g in range(2) for e in range(4)}
    assert len(resident & hot) >= 6


def test_shared_budget_caps_total_promotions_per_interval():
    daemon = tm.NeoMemDaemon(tm.DaemonParams(
        migration_interval=1, threshold_update_period=64, clear_interval=64,
        quota_pages=8))   # explicit shared budget < sum of per-resource quotas
    a = daemon.register(tm.make_resource("embeddings", tm.ResourceSpec(
        "embeddings", n_pages=256, hot_slots=64, quota_pages=8,
        sketch_width=1 << 10)))
    b = daemon.register(tm.make_resource("embeddings", tm.ResourceSpec(
        "b", n_pages=256, hot_slots=64, quota_pages=8,
        sketch_width=1 << 10)))
    # force demand directly through the pending queues
    a.mem.enqueue(np.arange(20))
    b.mem.enqueue(np.arange(20))
    daemon.tick()
    total = (a.stats.migrated_this_period + b.stats.migrated_this_period)
    assert total <= 8
    assert a.stats.migrated_this_period > 0
    assert b.stats.migrated_this_period > 0


def test_duplicate_registration_rejected():
    daemon = tm.NeoMemDaemon()
    spec = tm.ResourceSpec("embeddings", n_pages=8, hot_slots=2)
    daemon.register(tm.make_resource("embeddings", spec))
    with pytest.raises(ValueError):
        daemon.register(tm.make_resource("embeddings", spec))


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_expert_cache_single_spec_for_tier_and_daemon():
    """Regression: one ResourceSpec must flow to BOTH the tier and daemon
    (the old ExpertCache built two separate TierParams)."""
    cfg = ExpertTierConfig(n_groups=3, n_experts=8, hot_slots=2,
                           quota_pages=16)
    cache = ExpertCache(cfg)
    spec_tp = cache.spec.tier_params()
    assert cache.daemon.tp == spec_tp                  # daemon geometry
    assert cache.tier.page_slot.shape[0] == spec_tp.num_pages
    assert cache.tier.slot_page.shape[0] == spec_tp.num_slots
    assert cache.handle.mem.quota == cfg.quota_pages   # promotion batch width
    assert spec_tp.num_pages == 3 * 8
    assert spec_tp.num_slots == 3 * 2


def test_victim_rank_prefers_2q_order():
    """Pin the 2Q eviction preference:
    free < A1-unref < A1-ref < Am-unref < Am-ref, ties by last_touch."""
    tp = TierParams(num_pages=16, num_slots=6, quota_pages=4)
    ts = tier_init(tp)
    # slot: 0 free | 1 A1-unref | 2 A1-ref | 3 Am-unref | 4 Am-ref | 5 A1-unref(older)
    ts = ts._replace(
        slot_page=jnp.asarray([-1, 1, 2, 3, 4, 5], jnp.int32),
        active=jnp.asarray([False, False, False, True, True, False]),
        referenced=jnp.asarray([False, False, True, False, True, False]),
        last_touch=jnp.asarray([0, 7, 3, 3, 3, 2], jnp.int32),
    )
    rank = np.asarray(tier_lib._victim_rank(ts))
    order = np.argsort(rank, kind="stable").tolist()
    #             free, older A1-unref, newer A1-unref, A1-ref, Am-unref, Am-ref
    assert order == [0, 5, 1, 2, 3, 4]
    # behavioral check: a promotion takes the free slot first, then slot 5
    ts2, promoted, victims = tier_lib.promote(
        ts, jnp.asarray([9, 10, -1, -1], jnp.int32), 4)
    v = np.asarray(victims)[:2].tolist()
    assert v == [0, 5], v
