"""Codec subsystem tests (tiering/codec.py, DESIGN.md §14).

Property tests for the shared symmetric-int8 core (round-trip error bound,
zero-row guard, outlier rows, error-feedback accumulation), the tier-store
integration (int8 slow stores served within one quantum, wire-verbatim
copy_rows, codec="none" bit-exactness with the pre-codec path), and the
zero1 ``compress_collective`` consumer (fp32 parity + collective byte cut).

The round-trip property runs under hypothesis when available
(requirements-dev.txt; CI) and falls back to a seeded sweep locally.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.tiering as tm
from repro.optim import zero1
from repro.optim.optimizers import OptConfig
from repro.tiering import codec

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # not installed in every env; CI has it
    HAVE_HYPOTHESIS = False


def _spec(**kw):
    base = dict(name="embeddings", n_pages=32, hot_slots=6, quota_pages=4,
                sketch_width=1 << 8, row_shape=(2, 3), row_dtype="bfloat16")
    base.update(kw)
    return tm.ResourceSpec(**base)


def _check_roundtrip(rows: np.ndarray) -> None:
    """The codec contract: per-row error <= scale/2, scale = max|row|/127."""
    x = jnp.asarray(rows, jnp.float32)
    payload, scale = codec.encode_rows("int8", x)
    assert payload.dtype == jnp.int8 and scale.shape == (x.shape[0],)
    deq = np.asarray(codec.decode_rows(payload, scale, jnp.float32))
    err = np.max(np.abs(deq - rows), axis=tuple(range(1, rows.ndim)))
    bound = np.asarray(scale) / 2.0
    assert np.all(err <= bound + 1e-7), (err, bound)


# ---------------------------------------------------------------------------
# int8 core properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8),
           st.floats(1e-4, 1e4))
    def test_roundtrip_bound_property(seed, n_rows, mag):
        rows = np.random.default_rng(seed).normal(
            scale=mag, size=(n_rows, 5)).astype(np.float32)
        _check_roundtrip(rows)
else:
    def test_roundtrip_bound_property():
        for seed, mag in [(0, 1.0), (1, 1e-3), (2, 1e3), (3, 40.0)]:
            rows = np.random.default_rng(seed).normal(
                scale=mag, size=(7, 5)).astype(np.float32)
            _check_roundtrip(rows)


def test_all_zero_row_quantizes_exactly():
    """The 0/0 guard: an all-zero row gets scale 1 and decodes to zeros."""
    rows = jnp.zeros((3, 4), jnp.float32)
    q, scale = codec.quantize_int8(rows, axes=(1,))
    assert np.all(np.asarray(scale) == 1.0)
    np.testing.assert_array_equal(
        np.asarray(codec.dequantize_int8(q, scale, jnp.float32)), 0.0)


def test_outlier_row_error_bounded_by_its_own_scale():
    """Per-ROW scales: one outlier row widens only its own quantum, and
    even there the error stays <= scale/2 (= outlier / 254)."""
    rows = np.full((4, 8), 0.01, np.float32)
    rows[2, 3] = 1000.0
    _check_roundtrip(rows)
    _, scale = codec.encode_rows("int8", jnp.asarray(rows))
    s = np.asarray(scale)
    assert s[2] == pytest.approx(1000.0 / 127.0)
    assert np.all(s[[0, 1, 3]] == pytest.approx(0.01 / 127.0))


def test_error_feedback_accumulation_unbiased():
    """n repeats of quantize(delta + residual) sum to n*delta within one
    quantum — the EF contract zero1's compressed collective relies on."""
    rng = np.random.default_rng(5)
    delta = jnp.asarray(rng.normal(size=(2, 256)) * 0.1, jnp.float32)
    flat = delta.reshape(-1)
    ef = jnp.zeros_like(flat)
    total = jnp.zeros_like(flat)
    n = 25
    for _ in range(n):
        applied, ef, _ = zero1.compress_delta(flat, ef, n_shards=2)
        total = total + applied
    err = float(jnp.max(jnp.abs(total - n * flat)))
    quantum = float(jnp.max(codec.symmetric_scale(delta.reshape(2, -1),
                                                  axes=(1,))))
    assert err <= quantum * 1.01 + 1e-6


def test_fp32_codec_is_identity_for_bf16():
    rows = jnp.asarray(np.random.default_rng(0).normal(size=(6, 4)),
                       jnp.bfloat16)
    payload, scale = codec.encode_rows("fp32", rows)
    assert scale is None and payload.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(codec.decode_rows(payload, None, jnp.bfloat16)),
        np.asarray(rows))


def test_wire_row_bytes_schedule():
    assert codec.wire_row_bytes("none", (2, 3), "bfloat16") == 12
    assert codec.wire_row_bytes("fp32", (2, 3), "bfloat16") == 24
    assert codec.wire_row_bytes("int8", (2, 3), "bfloat16") == 6 + 4
    with pytest.raises(KeyError):
        codec.wire_row_bytes("zstd", (2, 3), "bfloat16")


# ---------------------------------------------------------------------------
# tier-store integration
# ---------------------------------------------------------------------------

def _bound_mem(codec_name: str):
    spec = _spec(slow_codec=codec_name)
    mem = tm.TieredMemory.from_spec(spec)
    data = jnp.asarray(
        np.random.default_rng(1).normal(size=(spec.n_pages,) + spec.row_shape),
        jnp.bfloat16)
    mem.bind_data(data)
    return spec, mem, data


def test_int8_store_serves_within_one_quantum():
    """Slow-fallback reads, promoted fast-tier reads, and the in-jit
    lookup_rows path all decode within scale/2 per element."""
    spec, mem, data = _bound_mem("int8")
    state, stats = mem.init(), tm.TierStats(name="embeddings")
    scale = np.asarray(mem.buffers.scale)
    ids = np.array([3, 9, 21])
    # one int8 quantum plus the bf16 half-ulp the fast dtype re-rounds into
    bound = (scale[ids].reshape(-1, 1, 1) / 2.0
             + np.abs(np.asarray(data[ids], np.float32)) * 2.0 ** -8 + 1e-7)

    for reader in (lambda: mem.read_rows(state, ids),
                   lambda: mem.lookup_rows(state, jnp.asarray(ids))):
        err = np.abs(np.asarray(reader(), np.float32)
                     - np.asarray(data[ids], np.float32))
        assert np.all(err <= bound)

    mem.enqueue(ids.tolist())
    state, event = mem.migrate(state, stats)
    assert mem.apply_migration(event, stats) > 0
    _, hit = tm.lookup(state, jnp.asarray(ids))
    assert np.all(np.asarray(hit))
    # the fast tier holds the DECODED copy (native dtype, one-time decode)
    assert mem.buffers.fast.dtype == jnp.bfloat16
    err = np.abs(np.asarray(mem.read_rows(state, ids), np.float32)
                 - np.asarray(data[ids], np.float32))
    assert np.all(err <= bound)


def test_int8_wire_bytes_metered_not_native():
    """Quota and migration counters meter the compressed wire bytes."""
    spec, mem, _ = _bound_mem("int8")
    assert spec.wire_row_bytes == codec.wire_row_bytes(
        "int8", spec.row_shape, spec.row_dtype)
    assert mem.row_bytes == spec.wire_row_bytes
    assert spec.quota_bytes == 2 * spec.quota_pages * spec.wire_row_bytes
    state, stats = mem.init(), tm.TierStats(name="embeddings")
    mem.enqueue([1, 2, 3])
    state, event = mem.migrate(state, stats)
    moved = mem.apply_migration(event, stats)
    assert moved == 3 * spec.wire_row_bytes
    assert stats.max_epoch_bytes <= spec.quota_bytes


def test_copy_rows_preserves_wire_format():
    """The reuse-store publish verb duplicates payload AND scale verbatim:
    dst pages decode bit-identically to src pages."""
    spec, mem, _ = _bound_mem("int8")
    state = mem.init()
    src, dst = np.array([4, 7]), np.array([30, 31])
    mem.copy_rows(state, src, dst)
    np.testing.assert_array_equal(np.asarray(mem.buffers.slow[dst]),
                                  np.asarray(mem.buffers.slow[src]))
    np.testing.assert_array_equal(np.asarray(mem.buffers.scale[dst]),
                                  np.asarray(mem.buffers.scale[src]))
    np.testing.assert_array_equal(
        np.asarray(mem.read_rows(state, dst)),
        np.asarray(mem.read_rows(state, src)))


def test_write_rows_reencodes_demoted_payload():
    """Owner refresh on an int8 store re-quantizes: the slow copy decodes
    to the NEW rows within one quantum of the new per-row scale."""
    spec, mem, _ = _bound_mem("int8")
    state = mem.init()
    ids = np.array([11, 12])
    new = jnp.asarray(np.random.default_rng(2).normal(
        size=(2,) + spec.row_shape) * 3.0, jnp.bfloat16)
    mem.write_rows(state, ids, new)
    scale = np.asarray(mem.buffers.scale)[ids].reshape(-1, 1, 1)
    err = np.abs(np.asarray(mem.read_rows(state, ids), np.float32)
                 - np.asarray(new, np.float32))
    # reads come back in the fast dtype (bf16): one int8 quantum plus the
    # bf16 half-ulp of the decoded value
    bound = scale / 2.0 + np.abs(np.asarray(new, np.float32)) * 2.0 ** -8
    assert np.all(err <= bound + 1e-7)


def test_codec_none_matches_pre_codec_path_bitwise():
    """codec="none" is byte-for-byte the old data path: same buffers, same
    reads, no scale vector, native wire bytes."""
    spec_n, mem_n, data = _bound_mem("none")
    assert mem_n.buffers.scale is None
    assert mem_n.buffers.slow.dtype == data.dtype
    assert spec_n.wire_row_bytes == spec_n.row_bytes
    state = mem_n.init()
    ids = np.arange(spec_n.n_pages)
    np.testing.assert_array_equal(np.asarray(mem_n.read_rows(state, ids)),
                                  np.asarray(data))
    view = mem_n.tier_view(state)
    assert view["scale"] is None


# ---------------------------------------------------------------------------
# the zero1 consumer
# ---------------------------------------------------------------------------

def test_zero1_compressed_collective_parity_and_bytes():
    """compress_collective tracks the fp32 trajectory within EF tolerance,
    keeps m/v bitwise identical, and cuts the gather's wire bytes ~4x."""
    cfg = OptConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                    total_steps=100)
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.normal(size=(16, 24)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(48,)), jnp.float32)}
    st_f, spec = zero1.zero1_init(params, None)
    st_c, _ = zero1.zero1_init(params, None, compress_collective=True)
    assert "ef" in st_c and st_c["ef"].shape == (spec.padded,)
    pf, pc = params, params
    for _ in range(5):
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.normal(size=p.shape) * 0.1,
                                  jnp.float32), params)
        pf, st_f, om_f = zero1.zero1_update(cfg, pf, grads, st_f, spec, None)
        pc, st_c, om_c = zero1.zero1_update(cfg, pc, grads, st_c, spec, None,
                                            compress_collective=True)
    # m/v/step never see the codec — quantization is strictly post-update
    np.testing.assert_array_equal(np.asarray(st_f["m"]), np.asarray(st_c["m"]))
    np.testing.assert_array_equal(np.asarray(st_f["v"]), np.asarray(st_c["v"]))
    drift = max(float(jnp.max(jnp.abs(pf[k] - pc[k]))) for k in params)
    assert drift <= 1e-3
    assert om_f["collective_bytes"] == 4 * spec.padded
    assert om_c["collective_bytes"] / om_f["collective_bytes"] <= 0.30


def test_zero1_toggle_off_threads_ef_through():
    params = {"w": jnp.ones((8, 8), jnp.float32)}
    st, spec = zero1.zero1_init(params, None, compress_collective=True)
    grads = {"w": jnp.full((8, 8), 0.1, jnp.float32)}
    cfg = OptConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                    total_steps=10)
    _, st2, _ = zero1.zero1_update(cfg, params, grads, st, spec, None,
                                   compress_collective=False)
    np.testing.assert_array_equal(np.asarray(st2["ef"]), np.asarray(st["ef"]))
