"""Continuous-batching scheduler: lane decode parity, admission under a full
KV ring, preemption + resume bit-exactness, the two-tenant starvation guard,
and the weighted quota split both layers share (DESIGN.md §9)."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant
from repro.tiering.daemon import split_quota

ARCH = "llama3.2-3b"
BASE_KW = dict(max_seq=48, paged=True, page_t=4, hot_slots=5,
               migration_interval=4, resources=("embeddings",),
               embed_hot_slots=4, embed_rows_per_page=8)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config(ARCH)
    return cfg, tr.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reference(cfg_params):
    """Single-request engine: the ground truth every scheduled request's
    output must reproduce bit-for-bit."""
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        **{**BASE_KW, "resources": ()}))

    def generate(prompt, n):
        return list(eng.generate(np.asarray(prompt)[None], n_tokens=n)[0])
    return generate


def _sched(cfg_params, tenants, lanes=2, segments=None, patience=16,
           **kw):
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        **BASE_KW, lanes=lanes, kv_segments=segments or lanes, **kw))
    return Scheduler(eng, tenants, SchedConfig(preempt_patience=patience))


def _prompt(seed, n=8):
    cfg_vocab = get_smoke_config(ARCH).vocab
    return (np.random.default_rng(seed).integers(0, cfg_vocab, n)
            .astype(np.int32))


# -- split_quota weights ------------------------------------------------------

def test_split_quota_weights_default_matches_demand_proportional():
    d = {"a": 30, "b": 10}
    assert split_quota(20, d) == split_quota(20, d, weights={"a": 1, "b": 1})
    assert split_quota(20, d, weights={"a": 1.0, "b": 1.0}) == {"a": 15, "b": 5}


def test_split_quota_weights_shift_shares():
    d = {"a": 30, "b": 30}
    even = split_quota(20, d)
    assert even == {"a": 10, "b": 10}
    heavy = split_quota(20, d, weights={"a": 3.0, "b": 1.0})
    assert heavy == {"a": 15, "b": 5}


def test_split_quota_weight_zero_isolated_under_contention():
    d = {"a": 30, "b": 30}
    shares = split_quota(20, d, weights={"a": 1.0, "b": 0.0})
    assert shares == {"a": 20, "b": 0}
    # no contention: everyone gets their (capped) demand regardless
    assert split_quota(100, d, weights={"a": 1.0, "b": 0.0}) == d


def test_split_quota_clamps_and_redistributes():
    # a's weighted share would exceed its own demand; surplus goes to b
    shares = split_quota(20, {"a": 5, "b": 30}, weights={"a": 10.0, "b": 1.0})
    assert shares == {"a": 5, "b": 15}
    # caps bound demand before weighting; a clamped share frees budget for b
    shares = split_quota(10, {"a": 50, "b": 50}, caps={"a": 4, "b": 50},
                         weights={"a": 50.0, "b": 1.0})
    assert shares == {"a": 4, "b": 6}


# -- scheduler lifecycle ------------------------------------------------------

def test_scheduled_output_matches_dedicated_engine(cfg_params, reference):
    """Two concurrent requests through the lane substrate reproduce the
    single-request engine token-for-token (continuous batching is exact)."""
    sched = _sched(cfg_params, [Tenant("a"), Tenant("b")], lanes=2)
    ra = sched.submit("a", _prompt(1), max_new=8)
    rb = sched.submit("b", _prompt(2, n=6), max_new=10)
    sched.run(max_steps=200)
    assert ra.out == reference(ra.prompt, 8)
    assert rb.out == reference(rb.prompt, 10)


def test_admission_queues_when_ring_full(cfg_params):
    """More requests than lanes/KV segments: later arrivals must queue and
    still complete once capacity frees (no drop, no deadlock)."""
    sched = _sched(cfg_params, [Tenant("a")], lanes=2, segments=2)
    reqs = [sched.submit("a", _prompt(10 + i), max_new=6) for i in range(5)]
    sched.step()
    assert sum(r.state == "running" for r in reqs) == 2
    assert sum(r.state == "queued" for r in reqs) == 3
    assert sched.queued_peak >= 3
    sched.run(max_steps=400)
    assert all(r.state == "finished" for r in reqs)
    # the queued ones were admitted strictly later than they arrived
    assert all(r.admitted_step > r.arrival_step for r in reqs[2:])


def test_preempt_resume_bit_exact(cfg_params, reference):
    """A preempted request (pages evicted to the KV slow tier, another
    request served in its lane meanwhile) resumes bit-exactly."""
    sched = _sched(cfg_params, [Tenant("long"), Tenant("short", weight=4.0)],
                   lanes=1, segments=2, patience=4)
    rl = sched.submit("long", _prompt(3), max_new=24)
    for _ in range(10):
        sched.step()
    rs = sched.submit("short", _prompt(4, n=5), max_new=4)
    sched.run(max_steps=400)
    assert rl.preemptions >= 1                 # it was actually evicted
    assert rs.state == rl.state == "finished"
    assert rl.out == reference(rl.prompt, 24)  # bit-exact across preemption
    assert rs.out == reference(rs.prompt, 4)


def test_two_tenant_starvation_guard(cfg_params):
    """A flooding tenant cannot starve a lighter one: the queue head of a
    lane-less tenant is admitted within the patience bound (by preemption),
    while the heavy tenant keeps the rest of the machine."""
    sched = _sched(cfg_params, [Tenant("hog", weight=1.0),
                                Tenant("light", weight=1.0)],
                   lanes=2, segments=4, patience=6)
    hogs = [sched.submit("hog", _prompt(20 + i), max_new=30)
            for i in range(6)]
    for _ in range(8):
        sched.step()
    t0 = sched.step_count
    light = sched.submit("light", _prompt(40, n=4), max_new=4)
    while light.state != "finished" and sched.step_count < t0 + 120:
        sched.step()
    assert light.state == "finished"
    # admitted within patience (+1 step of slack for the admission pass)
    assert light.admitted_step - t0 <= sched.scfg.preempt_patience + 1
    assert sched.preemptions >= 1
    # the hog was paused, not killed: everything still drains
    sched.run(max_steps=2000)
    assert all(r.state == "finished" for r in hogs)


def test_report_and_per_tenant_stats(cfg_params):
    sched = _sched(cfg_params, [Tenant("a", 2.0), Tenant("b")], lanes=2)
    sched.submit("a", _prompt(5), max_new=5)
    sched.submit("b", _prompt(6), max_new=5)
    sched.run(max_steps=200)
    rep = sched.report()
    assert rep["completed"] == rep["submitted"] == 2
    assert rep["tokens"] == 10
    assert set(rep["tenants"]) == {"a", "b"}
    for row in rep["tenants"].values():
        assert row["completed"] == 1 and row["tokens"] == 5
        assert 0.0 <= row["kv_hit_rate"] <= 1.0
        assert row["ttft_ms"]["n"] == 1
        assert row["tpot_ms"]["n"] == 4
        assert "latency_ms" not in row      # combined row removed
    # per-tenant accounting actually saw KV traffic
    assert any(s.fast_reads + s.slow_reads > 0
               for s in sched.tenant_stats.values())
    assert set(rep["resources"]) == {"kv", "embeddings"}


def test_submit_validation(cfg_params):
    sched = _sched(cfg_params, [Tenant("a")], lanes=1)
    with pytest.raises(KeyError):
        sched.submit("nobody", _prompt(0), max_new=2)
    with pytest.raises(ValueError):            # longer than a KV segment
        sched.submit("a", _prompt(0, n=40), max_new=20)
    with pytest.raises(ValueError):
        sched.submit("a", np.zeros(0, np.int32), max_new=2)


# -- sampling (temperature / top-p over the lane substrate) -------------------

def _sampled(cfg_params, temp, seed, lanes=2, top_p=0.9):
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        **BASE_KW, lanes=lanes, kv_segments=max(lanes, 2)))
    sched = Scheduler(eng, [Tenant("a"), Tenant("b")],
                      SchedConfig(temperature=temp, top_p=top_p, seed=seed))
    ra = sched.submit("a", _prompt(31), max_new=8)
    rb = sched.submit("b", _prompt(32, n=6), max_new=8)
    sched.run(max_steps=400)
    return ra.out, rb.out


def test_sampling_replayable_per_seed(cfg_params):
    """temperature>0 draws are a pure function of (seed, rid, token index):
    same seed replays bit-identically, different seed diverges, and greedy
    (temperature=0) stays the argmax path."""
    s1 = _sampled(cfg_params, 0.8, seed=5)
    s2 = _sampled(cfg_params, 0.8, seed=5)
    assert s1 == s2
    s3 = _sampled(cfg_params, 0.8, seed=6)
    assert s1 != s3
    g = _sampled(cfg_params, 0.0, seed=5)
    assert g == _sampled(cfg_params, 0.0, seed=99)   # greedy ignores the seed
    assert s1 != g


def test_sampling_lane_invariant(cfg_params):
    """The per-request key is identity-derived, so the SAME requests sampled
    on a different lane layout (2 lanes vs 1 lane, i.e. concurrent vs
    sequential service) emit the same tokens."""
    wide = _sampled(cfg_params, 0.7, seed=11, lanes=2)
    narrow = _sampled(cfg_params, 0.7, seed=11, lanes=1)
    assert wide == narrow


def test_sample_tokens_top_p_masks_tail():
    """Nucleus filtering keeps the minimal top-p prefix: with a sharply
    peaked distribution and tiny top_p only the argmax can ever be drawn."""
    import jax
    import jax.numpy as jnp
    from repro.models import decode as dec
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 4)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4))
    toks = dec.sample_tokens(logits, keys, temperature=1.0, top_p=0.05)
    assert (np.asarray(toks) == 1).all()
    greedy = dec.sample_tokens(logits, keys, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(greedy), 1)


# -- lifecycle fuzzer (DESIGN.md §13) -----------------------------------------
#
# Randomized arrive / admit / chunk / hand-off / preempt / resume / finish
# interleavings over both scheduler modes, with the full state-machine
# invariant set checked after EVERY step.  The same driver runs under two
# harnesses: a hypothesis property (CI, shrinking counterexamples) and a
# seeded numpy sweep (always on, hypothesis not required locally).

def _check_invariants(sched, reqs):
    """Every submitted request lives in EXACTLY one scheduler container,
    lanes are single-occupancy per pool, and the KV segment ledger neither
    leaks nor double-books."""
    where: dict[int, str] = {}

    def seen(req, place):
        assert req.rid not in where, \
            f"rid {req.rid} in both {where[req.rid]} and {place}"
        where[req.rid] = place

    for r in sched.queue:
        assert r.state in ("queued", "preempted"), r.state
        seen(r, "queue")
    for pool, lanes in (("decode", sched.lanes),
                        ("prefill", sched.pre_lanes)):
        for ln, r in enumerate(lanes):
            if r is None:
                continue
            assert r.lane == ln, f"{pool} lane {ln} holds r.lane={r.lane}"
            assert r.state == ("running" if pool == "decode" else "prefill")
            seen(r, f"{pool}:{ln}")
    for r in sched.handoff:
        assert r.state == "handoff" and r.lane == -1
        seen(r, "handoff")
    for r in sched.finished:
        assert r.state == "finished"
        seen(r, "finished")
    assert set(where) == {r.rid for r in reqs}, "request lost or invented"
    # segment ledger: free list has no dupes; every admitted-but-unfinished
    # request holds a segment no one else (and no free slot) claims
    n_seg = sched.eng.scfg.kv_segments or sched.n_lanes
    free = sched.free_segments
    assert len(set(free)) == len(free), "free segment duplicated"
    held = [r.segment for r in reqs
            if r.state in ("running", "prefill", "handoff", "preempted")]
    assert len(set(held)) == len(held), "segment double-booked"
    assert not set(held) & set(free), "held segment also on the free list"
    assert set(held) | set(free) <= set(range(n_seg))


def _fuzz_lifecycle(cfg_params, seed, prefill_lanes, reuse_pages, chunk):
    """One fuzz episode: a seeded random submit/step script, invariants
    after every step, then drain to quiescence and check nothing leaked."""
    cfg, params = cfg_params
    rng = np.random.default_rng(seed)
    lanes = int(rng.integers(1, 3))
    segments = lanes + prefill_lanes + int(rng.integers(1, 3))
    eng = ServeEngine(cfg, params, ServeConfig(
        **BASE_KW, lanes=lanes, kv_segments=segments,
        reuse_pages=reuse_pages))
    sched = Scheduler(eng, [Tenant("a"), Tenant("b", weight=2.0)],
                      SchedConfig(preempt_patience=int(rng.integers(2, 7)),
                                  prefill_chunk=chunk,
                                  prefill_lanes=prefill_lanes,
                                  temperature=float(rng.choice([0.0, 0.8])),
                                  seed=seed))
    reqs = []
    for _ in range(60):
        if len(reqs) < 8 and rng.random() < 0.35:
            reqs.append(sched.submit(
                "a" if rng.random() < 0.5 else "b",
                rng.integers(0, cfg.vocab, int(rng.integers(2, 14)))
                   .astype(np.int32),
                max_new=int(rng.integers(1, 7))))
        sched.step()
        _check_invariants(sched, reqs)
        if not sched.active and len(reqs) >= 6:
            break
    while sched.active:
        sched.step()
        _check_invariants(sched, reqs)
        assert sched.step_count < 2000, "fuzz episode failed to drain"
    # quiesce: everything finished, every shared-page claim released
    assert all(r.state == "finished" for r in reqs)
    assert all(len(r.out) == r.max_new for r in reqs)
    if eng.reuse is not None:
        assert eng.reuse.stats()["shared_refs"] == 0, \
            "reuse refcounts did not drain at quiesce"
    rep = sched.report()
    assert rep["completed"] == rep["submitted"] == len(reqs)


_FUZZ_GRID = [
    # (prefill_lanes, reuse_pages, chunk): unified/chunked/disagg x reuse
    (0, 0, 0), (0, 0, 4), (0, 8, 4), (1, 0, 4), (1, 8, 4), (1, 0, 6),
]


@pytest.mark.parametrize("pre,reuse,chunk", _FUZZ_GRID)
def test_lifecycle_fuzz_seeded(cfg_params, pre, reuse, chunk):
    """The always-on sweep: fixed seeds over the mode grid."""
    for seed in (3, 11):
        _fuzz_lifecycle(cfg_params, seed, pre, reuse, chunk)


def test_lifecycle_fuzz_hypothesis(cfg_params):
    """The shrinking harness: hypothesis drives the same episode driver
    over seeds and modes (CI tier; skipped when hypothesis is absent)."""
    hyp = pytest.importorskip(
        "hypothesis", reason="property fuzzer needs hypothesis "
        "(pip install -r requirements-dev.txt)")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=10, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(seed=st.integers(0, 2**16),
               mode=st.sampled_from(_FUZZ_GRID))
    def prop(seed, mode):
        pre, reuse, chunk = mode
        _fuzz_lifecycle(cfg_params, seed, pre, reuse, chunk)

    prop()


def test_reset_lane_restores_init_state_xlstm():
    """A reused lane must serve like a fresh engine even for NON-ZERO init
    state: the m/sLSTM stabilizer inits to -1e30, so a zeroing reset would
    skew the next request's first normalizations (recurrent-arch parity)."""
    cfg = get_smoke_config("xlstm-1.3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    lane_kw = dict(max_seq=32, paged=True, page_t=4, hot_slots=4,
                   migration_interval=4)
    eng = ServeEngine(cfg, params, ServeConfig(**lane_kw, lanes=1))
    sched = Scheduler(eng, [Tenant("a")])
    pa = ((np.arange(7) * 5 + 2) % cfg.vocab).astype(np.int32)
    pb = ((np.arange(6) * 11 + 3) % cfg.vocab).astype(np.int32)
    sched.submit("a", pa, max_new=4)
    rb = sched.submit("a", pb, max_new=6)       # admitted into the reused lane
    sched.run(max_steps=100)
    ref = ServeEngine(cfg, params, ServeConfig(**lane_kw))
    assert rb.out == list(ref.generate(pb[None], n_tokens=6)[0])
