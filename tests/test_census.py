"""Roofline HLO census: trip-count-aware FLOPs/collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.census import census, parse_hlo


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_multiplication():
    """XLA cost_analysis counts scan bodies once; the census must not."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    txt = _compile(f, (256, 256), (256, 256))
    c = census(txt)
    expected = 10 * 2 * 256 ** 3
    assert abs(c["flops_per_device"] - expected) / expected < 0.05


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    txt = _compile(f, (128, 128), (128, 128))
    c = census(txt)
    expected = 12 * 2 * 128 ** 3
    assert abs(c["flops_per_device"] - expected) / expected < 0.05


def test_single_dot_exact():
    txt = _compile(lambda a, b: a @ b, (64, 32), (32, 16))
    c = census(txt)
    assert c["flops_per_device"] == 2 * 64 * 32 * 16


def test_no_collectives_single_device():
    txt = _compile(lambda a, b: a @ b, (64, 64), (64, 64))
    c = census(txt)
    assert c["collective_bytes_per_device"] == 0


def test_parse_handles_tuple_types():
    """Tuple-typed collective results must still parse (regression: the
    all-to-all byte count read 0 before the tuple-type fix)."""
    fake = """ENTRY %main (p0: f32[8,4]) -> f32[8,4] {
  %p0 = f32[8,4]{1,0} parameter(0)
  ROOT %aa = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(%p0, %p0)
}
"""
    c = census(fake)
    assert c["collectives"]["all-to-all"]["bytes"] == 2 * 8 * 4 * 4
