"""Prefill/decode disaggregation over the slow-tier hand-off fabric
(DESIGN.md §13): split-pool vs unified bit-exactness, mid-prefill
preemption + resume parity, the consumer-side residency gate, per-worker
virtual-clock / hand-off telemetry, and the TierStats conservation laws
the fabric's force-flushes must respect."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import SchedConfig, Scheduler, Tenant

ARCH = "llama3.2-3b"
BASE_KW = dict(max_seq=48, paged=True, page_t=4, hot_slots=5,
               migration_interval=4, resources=("embeddings",),
               embed_hot_slots=4, embed_rows_per_page=8)
CHUNK = 4


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config(ARCH)
    return cfg, tr.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reference(cfg_params):
    """Single-request engine: the ground truth every disaggregated
    request's output must reproduce bit-for-bit."""
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        **{**BASE_KW, "resources": ()}))

    def generate(prompt, n):
        return list(eng.generate(np.asarray(prompt)[None], n_tokens=n)[0])
    return generate


def _sched(cfg_params, prefill_lanes, lanes=2, temp=0.0, reuse=0,
           patience=16, chunk=CHUNK, segments=None):
    cfg, params = cfg_params
    segments = segments or (lanes + prefill_lanes + 2)
    eng = ServeEngine(cfg, params, ServeConfig(
        **BASE_KW, lanes=lanes, kv_segments=segments, reuse_pages=reuse))
    return Scheduler(eng, [Tenant("a"), Tenant("b")], SchedConfig(
        preempt_patience=patience, prefill_chunk=chunk,
        prefill_lanes=prefill_lanes, temperature=temp, seed=7))


def _prompt(seed, n=8):
    vocab = get_smoke_config(ARCH).vocab
    return (np.random.default_rng(seed).integers(0, vocab, n)
            .astype(np.int32))


_WORK = [("a", 1, 18, 5), ("b", 2, 6, 6), ("a", 3, 11, 4),
         ("b", 4, 21, 3), ("a", 5, 9, 5)]


def _serve(sched):
    reqs = [sched.submit(t, _prompt(s, n), max_new=m)
            for t, s, n, m in _WORK]
    sched.run(max_steps=2000)
    return {r.rid: list(r.out) for r in reqs}, reqs


# -- the tentpole: split pools reproduce the unified scheduler ---------------

@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_disagg_bit_exact_vs_unified(cfg_params, temp):
    """The same request set through the unified scheduler and through the
    split prefill-worker/decode-worker pools: token-for-token identical.
    The hand-off fabric moved real bytes both ways; unified never hands
    off."""
    uni = _sched(cfg_params, prefill_lanes=0, temp=temp)
    dis = _sched(cfg_params, prefill_lanes=1, temp=temp)
    out_u, _ = _serve(uni)
    out_d, _ = _serve(dis)
    assert out_u == out_d
    assert uni.handoffs == 0 and uni.handoff_bytes_out == 0
    assert dis.handoffs == len(_WORK)
    assert dis.handoff_bytes_out > 0 and dis.handoff_bytes_in > 0


def test_disagg_matches_reference_engine(cfg_params, reference):
    """Stronger ground truth: every disaggregated request reproduces the
    dedicated single-request engine (greedy)."""
    sched = _sched(cfg_params, prefill_lanes=1)
    _, reqs = _serve(sched)
    for r in reqs:
        assert r.out == reference(r.prompt, r.max_new), r.rid


# -- satellite: mid-prefill preemption + resume ------------------------------

def test_mid_prefill_preempt_resume_parity(cfg_params, reference):
    """A request preempted BETWEEN prefill chunks on the prefill worker
    (pages already flushed to its slow segment) resumes on the pool and
    still hands off / decodes token-for-token with the uninterrupted
    run."""
    sched = _sched(cfg_params, prefill_lanes=1)
    ra = sched.submit("a", _prompt(30, 20), max_new=6)   # 5 chunks of 4
    for _ in range(50):
        sched.step()
        if ra.state == "prefill" and 0 < ra.pos < ra.n_prompt:
            break
    assert ra.state == "prefill" and ra.prefilling, "never mid-prefill"
    sched._preempt(ra)
    assert ra.state == "preempted" and ra.prefilling
    assert sched.pre_lanes[0] is None
    sched.run(max_steps=2000)
    assert ra.state == "finished" and ra.preemptions >= 1
    assert ra.out == reference(ra.prompt, 6)


# -- the consumer-side residency gate ----------------------------------------

def test_handoff_residency_gate(cfg_params, reference):
    """Decode admission waits on the write witness: a hand-off whose
    segment has an unflushed page is not admissible, and installing it
    anyway raises.  Once the page is witnessed the request drains
    normally."""
    from repro.tiering import segment_page_ids
    sched = _sched(cfg_params, prefill_lanes=1)
    ra = sched.submit("a", _prompt(31, 14), max_new=4)
    for _ in range(50):
        if sched.handoff:
            break
        sched.step()
    assert sched.handoff, "request never reached the hand-off state"
    res = ra.residual
    eng = sched.eng
    gids = segment_page_ids(res["segment"], res["pos"], eng.scfg.page_t,
                            eng.pages_per_seq, table=res.get("pages"))
    assert eng.segment_resident(res)          # producer flushed everything
    mem = eng.daemon["kv"].mem
    mem.written[int(gids[-1])] = False        # simulate an in-flight flush
    assert not eng.segment_resident(res)
    with pytest.raises(RuntimeError, match="not fully resident"):
        eng.install_handoff(0, res)
    before = sched.step_count
    sched.step()                              # gate holds: no admission
    assert ra.state == "handoff" and sched.step_count == before + 1
    mem.written[int(gids[-1])] = True         # flush lands
    sched.run(max_steps=2000)
    assert ra.out == reference(ra.prompt, 4)


def test_disagg_requires_chunked_prefill(cfg_params):
    with pytest.raises(ValueError, match="prefill_chunk"):
        _sched(cfg_params, prefill_lanes=1, chunk=0)


# -- satellite: TierStats conservation laws ----------------------------------

def _check_conservation(resources):
    """Every metered read is fast or slow — none lost, none invented —
    and the per-epoch migration budget held for EVERY epoch."""
    for name, row in resources.items():
        reads = row["fast_reads"] + row["slow_reads"]
        expect = row["fast_reads"] / reads if reads else 0.0
        assert abs(row["hit_rate"] - expect) < 1e-9, name
        assert row["last_epoch_bytes"] <= row["max_epoch_bytes"], name
        if row["quota_bytes"]:
            assert row["max_epoch_bytes"] <= row["quota_bytes"], name


@pytest.mark.parametrize("pre", [0, 1])
def test_tier_stats_conservation(cfg_params, pre):
    """Both scheduler modes respect the conservation laws — the disagg
    arm's hand-off force-flushes and placement-table pulls included."""
    sched = _sched(cfg_params, prefill_lanes=pre)
    _serve(sched)
    rep = sched.report()
    _check_conservation(rep["resources"])
    for stats in sched.tenant_stats.values():
        _check_conservation({stats.name: stats.as_row()})
    if pre:
        assert rep["resources"]["kv"]["flush_bytes"] > 0
        assert rep["handoff"]["bytes_out"] > 0


# -- reuse interplay ---------------------------------------------------------

def test_disagg_reuse_bit_exact_and_refs_drain(cfg_params):
    """The content-addressed store works across the split: admission
    matching happens on the prefill worker, publishes on the decode
    worker, outputs stay bit-exact vs unified+reuse, and every shared-page
    claim drains by quiescence."""
    uni = _sched(cfg_params, prefill_lanes=0, reuse=8)
    dis = _sched(cfg_params, prefill_lanes=1, reuse=8)
    out_u, _ = _serve(uni)
    out_d, _ = _serve(dis)
    assert out_u == out_d
    st = dis.eng.reuse.stats()
    assert st["lookups"] > 0 and st["shared_refs"] == 0


# -- telemetry ---------------------------------------------------------------

def test_report_mode_clock_and_handoff_schema(cfg_params):
    uni = _sched(cfg_params, prefill_lanes=0)
    dis = _sched(cfg_params, prefill_lanes=1)
    _serve(uni)
    _serve(dis)
    ru, rd = uni.report(), dis.report()
    assert ru["mode"] == "unified" and ru["prefill_lanes"] == 0
    assert rd["mode"] == "disagg" and rd["prefill_lanes"] == 1
    for rep in (ru, rd):
        assert set(rep["clock"]) == {"prefill_s", "handoff_s", "decode_s"}
        assert set(rep["handoff"]) == {"count", "bytes_out", "bytes_in",
                                       "depth_peak"}
    assert ru["handoff"]["count"] == 0 and ru["clock"]["prefill_s"] == 0.0
    assert rd["handoff"]["count"] == len(_WORK)
    assert rd["handoff"]["depth_peak"] >= 1
    assert rd["clock"]["prefill_s"] > 0 and rd["clock"]["decode_s"] > 0
    # every emitted token carries the (virtual clock, step) stamps the
    # disagg A/B's gap classifier keys on
    for r in dis.finished:
        assert len(r.token_clock) == len(r.token_steps) == len(r.out)
    assert len(dis.prefill_busy) == dis.step_count
