"""Workload traces: seeded replayability, structural-load parity across
kinds, the access-pattern contrasts the traffic benchmark relies on, and
the bursty MMPP arrival process."""
import numpy as np
import pytest

from repro.workloads import TRACE_KINDS, make_trace


def _arrival_key(a):
    return (a.step, a.tenant, len(a.tokens), a.max_new)


def test_replayable_same_seed():
    t1 = make_trace("zipf-hot", n_steps=80, vocab=256, seed=7)
    t2 = make_trace("zipf-hot", n_steps=80, vocab=256, seed=7)
    assert len(t1.arrivals) == len(t2.arrivals) > 0
    for a, b in zip(t1.arrivals, t2.arrivals):
        assert _arrival_key(a) == _arrival_key(b)
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_different_seed_differs():
    t1 = make_trace("zipf-hot", n_steps=80, vocab=256, seed=0)
    t2 = make_trace("zipf-hot", n_steps=80, vocab=256, seed=1)
    assert [_arrival_key(a) for a in t1.arrivals] \
        != [_arrival_key(a) for a in t2.arrivals]


def test_kinds_and_bounds():
    for kind in TRACE_KINDS:
        t = make_trace(kind, n_steps=60, vocab=128, seed=3)
        assert t.kind == kind and t.arrivals
        tenants = {a.tenant for a in t.arrivals}
        assert len(tenants) >= 2
        for a in t.arrivals:
            assert 0 <= a.step < t.n_steps
            assert a.max_new >= 1
            assert (a.tokens >= 0).all() and (a.tokens < t.vocab).all()
    with pytest.raises(KeyError):
        make_trace("nope")


def test_structural_load_identical_across_kinds():
    """Same seed => same arrival steps / tenants / lengths for EVERY kind —
    hit-rate deltas between traces measure token content, not load."""
    keys = {kind: [_arrival_key(a)
                   for a in make_trace(kind, n_steps=100, seed=11).arrivals]
            for kind in TRACE_KINDS}
    assert keys["zipf-hot"] == keys["diurnal-shift"] == keys["scan-antagonist"]


def _tenant_token_hist(trace, tenant, vocab):
    h = np.zeros(vocab, np.int64)
    for a in trace.arrivals:
        if a.tenant == tenant:
            np.add.at(h, a.tokens, 1)
    return h


def test_zipf_head_vs_scan_sweep():
    """zipf-hot concentrates mass in a small head; the scan antagonist
    spreads it across the sweep — the contrast behind the adaptivity gap."""
    vocab = 256
    zipf = make_trace("zipf-hot", n_steps=150, vocab=vocab, seed=5)
    scan = make_trace("scan-antagonist", n_steps=150, vocab=vocab, seed=5)
    antagonist = zipf.tenants[1].name
    hz = _tenant_token_hist(zipf, antagonist, vocab)
    hs = _tenant_token_hist(scan, antagonist, vocab)
    assert hz.sum() == hs.sum() > 0          # identical structural load
    top = 32
    frac_z = np.sort(hz)[::-1][:top].sum() / hz.sum()
    frac_s = np.sort(hs)[::-1][:top].sum() / hs.sum()
    assert frac_z > 2 * frac_s, (frac_z, frac_s)


def test_diurnal_hot_set_drifts():
    vocab = 256
    t = make_trace("diurnal-shift", n_steps=128, vocab=vocab, seed=9,
                   shift_period=64)
    early = np.zeros(vocab, np.int64)
    late = np.zeros(vocab, np.int64)
    for a in t.arrivals:
        np.add.at(early if a.step < 64 else late, a.tokens, 1)
    top_early = set(np.argsort(early)[::-1][:8])
    top_late = set(np.argsort(late)[::-1][:8])
    assert top_early != top_late             # the head rotated


# -- MMPP arrivals ------------------------------------------------------------

def test_mmpp_structural_load_identical_across_kinds():
    """The modulation chain comes from the shared structural stream, so the
    per-seed load guarantee holds under MMPP exactly as under Bernoulli."""
    keys = {kind: [_arrival_key(a)
                   for a in make_trace(kind, n_steps=120, seed=13,
                                       arrival="mmpp").arrivals]
            for kind in TRACE_KINDS}
    assert keys["zipf-hot"] == keys["diurnal-shift"] == keys["scan-antagonist"]
    assert all(make_trace(k, n_steps=20, seed=0, arrival="mmpp").arrival
               == "mmpp" for k in TRACE_KINDS)


def test_mmpp_burstier_same_mean():
    """MMPP preserves the mean offered load but concentrates it in bursts:
    windowed arrival counts are over-dispersed (Fano factor well above the
    Bernoulli baseline) while total arrivals stay within a few percent."""
    def fano(trace, w=20):
        c = np.zeros(trace.n_steps)
        for a in trace.arrivals:
            c[a.step] += 1
        wins = c[: trace.n_steps // w * w].reshape(-1, w).sum(1)
        return wins.var() / max(wins.mean(), 1e-9)

    seeds = range(5)
    bern = [make_trace("zipf-hot", n_steps=1000, seed=s) for s in seeds]
    mmpp = [make_trace("zipf-hot", n_steps=1000, seed=s, arrival="mmpp")
            for s in seeds]
    f_b = np.mean([fano(t) for t in bern])
    f_m = np.mean([fano(t) for t in mmpp])
    assert f_m > 1.3 * f_b, (f_b, f_m)
    n_b = np.mean([len(t.arrivals) for t in bern])
    n_m = np.mean([len(t.arrivals) for t in mmpp])
    assert abs(n_m - n_b) / n_b < 0.15, (n_b, n_m)


def test_mmpp_replayable_and_validated():
    t1 = make_trace("zipf-hot", n_steps=80, seed=7, arrival="mmpp")
    t2 = make_trace("zipf-hot", n_steps=80, seed=7, arrival="mmpp")
    assert [_arrival_key(a) for a in t1.arrivals] \
        == [_arrival_key(a) for a in t2.arrivals]
    # a different process is a different trace (same seed)
    t3 = make_trace("zipf-hot", n_steps=80, seed=7)
    assert [_arrival_key(a) for a in t1.arrivals] \
        != [_arrival_key(a) for a in t3.arrivals]
    with pytest.raises(KeyError):
        make_trace("zipf-hot", arrival="poisson")


def test_prod_mixture_bimodal_replayable_and_capped():
    """The production prompt-length mixture (DESIGN.md §14 bench workloads):
    a 2-component lognormal — most prompts short-interactive, a heavy tail
    of long-document prompts — deterministic per seed and always fitting
    the KV segment budget (prompt + output reservation < max_total)."""
    t1 = make_trace("prod-mixture", n_steps=200, vocab=128, seed=7)
    t2 = make_trace("prod-mixture", n_steps=200, vocab=128, seed=7)
    assert [_arrival_key(a) for a in t1.arrivals] == \
        [_arrival_key(a) for a in t2.arrivals]
    lens = np.array([len(a.tokens) for a in t1.arrivals])
    assert len(lens) > 30
    # both mixture components land: a short-interactive majority and a
    # nonempty long-document tail well past the short mode
    assert 0.4 <= float((lens <= 12).mean()) <= 0.95
    assert int((lens >= 18).sum()) > 0
    for a in t1.arrivals:
        assert 1 <= len(a.tokens)
        assert len(a.tokens) + a.max_new < 56       # the max_total cap
