"""ServeEngine: paged-vs-dense decode parity under tiering, and KV + expert +
embedding resources multiplexed on one daemon with independent stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine


def _engine(arch, scfg, seed=0):
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, ServeEngine(cfg, params, scfg)


def test_paged_dense_decode_parity_with_tiering():
    """With every page resident (hot slots cover the sequence) the paged
    fast-tier decode must reproduce dense decode token-for-token, even with
    embedding tiering observing/ticking alongside."""
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    prompt = (np.arange(2 * 12).reshape(2, 12) * 7) % cfg.vocab
    dense = ServeEngine(cfg, params, ServeConfig(max_seq=64))
    out_dense = dense.generate(prompt, n_tokens=8)
    paged = ServeEngine(cfg, params, ServeConfig(
        max_seq=64, paged=True, page_t=4, hot_slots=16, migration_interval=4,
        resources=("embeddings",), embed_hot_slots=4))
    out_paged = paged.generate(prompt, n_tokens=8)
    np.testing.assert_array_equal(out_dense, out_paged)
    # tiering was actually live during the run
    assert paged.daemon["embeddings"].hit_rate() > 0
    assert paged.daemon["kv"].hit_rate() > 0


def test_multi_resource_single_daemon():
    """KV + experts + embeddings tick on ONE multiplexed daemon, each with
    its own hit-rate accounting."""
    cfg, eng = _engine("kimi-k2-1t-a32b", ServeConfig(
        max_seq=128, paged=True, page_t=8, hot_slots=4, migration_interval=2,
        resources=("experts", "embeddings"),
        expert_hot_slots=2, embed_hot_slots=2))
    assert set(eng.daemon.resources) == {"kv", "experts", "embeddings"}
    prompt = np.arange(2 * 16).reshape(2, 16) % cfg.vocab
    out = eng.generate(prompt, n_tokens=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    stats = eng.tier_stats()
    assert set(stats) == {"kv", "experts", "embeddings"}
    # every resource observed traffic and accounts its hit rate independently
    for name, h in eng.daemon.resources.items():
        total = (h.stats.fast_reads + h.stats.slow_reads
                 + int(h.state.tier.fast_reads) + int(h.state.tier.slow_reads))
        assert total > 0, name
        assert 0.0 <= stats[name]["hit_rate"] <= 1.0
    rates = {n: round(s["hit_rate"], 6) for n, s in stats.items()}
    assert len(set(rates.values())) > 1, rates   # not one shared counter


def test_resource_validation():
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):   # kv requires the paged cache
        ServeEngine(cfg, params, ServeConfig(paged=False, resources=("kv",)))
    with pytest.raises(ValueError):   # dense arch has no experts to tier
        ServeEngine(cfg, params, ServeConfig(resources=("experts",)))


def test_decode_step_surfaces_router_streams():
    """decode_step(return_streams=True) exposes the (G, n_moe, B, 1, k)
    token->expert stream the expert resource encodes."""
    from repro.models import decode as dec
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    cache = dec.init_cache(cfg, 2, 16)
    logits, cache2, streams = dec.decode_step(
        cfg, params, cache, jnp.zeros((2, 1), jnp.int32), return_streams=True)
    router = streams["router"]
    assert router is not None
    g, n_moe, b, s, k = router.shape
    assert (g, b, s, k) == (cfg.n_groups, 2, 1, cfg.moe.top_k)
    assert (np.asarray(router) >= 0).all()
    assert (np.asarray(router) < cfg.moe.n_experts).all()
    # default signature unchanged
    logits2, _ = dec.decode_step(cfg, params, cache,
                                 jnp.zeros((2, 1), jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=1e-5, atol=1e-5)
