"""Chunked prefill (DESIGN.md §11): bit-exactness vs token-at-a-time
streaming (logits AND slow-segment bytes), decode-lane isolation while
another lane chunk-prefills, the TTFT/TPOT latency split, and the
single-pass dense prefill regression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tr
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.sched import Request, SchedConfig, Scheduler, Tenant

ARCH = "llama3.2-3b"
PAGE_T = 4
LANE_KW = dict(max_seq=48, paged=True, page_t=PAGE_T, hot_slots=8,
               migration_interval=4, resources=("embeddings",),
               embed_hot_slots=4, embed_rows_per_page=8, lanes=2,
               kv_segments=2)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config(ARCH)
    return cfg, tr.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg_params, **kw):
    cfg, params = cfg_params
    return ServeEngine(cfg, params, ServeConfig(**{**LANE_KW, **kw}))


def _prompt(seed, n):
    vocab = get_smoke_config(ARCH).vocab
    return (np.random.default_rng(seed).integers(0, vocab, n)
            .astype(np.int32))


def _stream_lane(eng, lane, tokens, segment):
    """Token-at-a-time reference: one advance_lanes call per prompt token,
    only ``lane`` active — the legacy prefill loop."""
    eng.start_lanes()
    active = np.zeros(eng.scfg.lanes, bool)
    active[lane] = True
    segs = np.full(eng.scfg.lanes, -1, np.int32)
    segs[lane] = segment
    toks = np.zeros(eng.scfg.lanes, np.int32)
    logits = None
    for t in tokens:
        toks[lane] = t
        logits = eng.advance_lanes(toks, active, segs)
    return logits[lane].astype(np.float32)


def _segment_bytes(eng, lane, segment):
    """The lane's slow-store segment contents after a full forced flush."""
    eng._flush_kv_lanes(lanes=[lane], force=True)
    buf = eng.daemon["kv"].mem.buffers
    pps = eng.pages_per_seq
    return np.asarray(buf.slow[segment * pps:(segment + 1) * pps]
                      .astype(jnp.float32))


# -- bit-exactness: chunked vs token-at-a-time --------------------------------

@pytest.mark.parametrize("chunk", [1, PAGE_T, 4 * PAGE_T, 7])
def test_prefill_lane_bit_exact_vs_streaming(cfg_params, chunk):
    """prefill_lane(chunk) reproduces the streaming loop bit-for-bit: the
    last prompt position's logits AND the slow-segment page bytes, for
    chunk in {1, page_t, 4*page_t, a ragged tail}."""
    prompt = _prompt(3, 18)      # 18 tokens: ragged against chunk=7 and 16
    ref = _engine(cfg_params)
    ref_logits = _stream_lane(ref, 0, prompt, segment=1)
    ref_bytes = _segment_bytes(ref, 0, segment=1)

    eng = _engine(cfg_params)
    eng.start_lanes()
    logits = eng.prefill_lane(0, prompt, segment=1, chunk=chunk)
    np.testing.assert_array_equal(logits.astype(np.float32), ref_logits)
    np.testing.assert_array_equal(_segment_bytes(eng, 0, segment=1),
                                  ref_bytes)
    # per-lane position advanced by the full prompt, other lane frozen
    np.testing.assert_array_equal(np.asarray(eng.cache["pos"]),
                                  [len(prompt), 0])


def test_chunked_prefill_does_not_perturb_decode_lane(cfg_params):
    """Interleaving another lane's chunk writes between decode steps leaves
    the decoding lane's output stream untouched (no stop-the-world, no
    cross-lane contamination)."""
    prompt_a = _prompt(5, 6)
    long_b = _prompt(6, 20)

    def run(interleave):
        eng = _engine(cfg_params)
        eng.start_lanes()
        # lane 0: stream its prompt, then decode greedily
        active = np.array([True, False])
        segs = np.array([0, -1], np.int32)
        toks = np.zeros(2, np.int32)
        logits = None
        for t in prompt_a:
            toks[0] = t
            logits = eng.advance_lanes(toks, active, segs)
        out = []
        for i in range(6):
            if interleave and i == 2:       # chunk-prefill lane 1 mid-decode
                eng.prefill_lane(1, long_b, segment=1, chunk=8)
            toks[0] = int(np.argmax(logits[0]))
            out.append(toks[0])
            logits = eng.advance_lanes(toks, active, segs)
        return out

    assert run(interleave=True) == run(interleave=False)


def test_scheduler_chunked_matches_streaming(cfg_params):
    """End-to-end through the Scheduler: chunked admission emits the same
    tokens as token-at-a-time, in fewer engine steps, and stamps TTFT when
    the last chunk lands."""
    def run(chunk):
        eng = _engine(cfg_params)
        sched = Scheduler(eng, [Tenant("a"), Tenant("b")],
                          SchedConfig(prefill_chunk=chunk))
        ra = sched.submit("a", _prompt(7, 20), max_new=6)
        rb = sched.submit("b", _prompt(8, 5), max_new=8)
        sched.run(max_steps=200)
        return ra, rb, sched

    ra_s, rb_s, sched_s = run(chunk=0)
    ra_c, rb_c, sched_c = run(chunk=8)
    assert ra_c.out == ra_s.out
    assert rb_c.out == rb_s.out             # short prompt: streaming fallback
    assert sched_c.step_count < sched_s.step_count
    assert len(ra_c.token_times) == 6 and ra_c.token_times[0] > 0
    # the long prompt consumed 20 tokens in ceil(20/8)=3 scheduler steps
    assert ra_c.out and ra_s.out


def test_prefill_lane_validation(cfg_params):
    eng = _engine(cfg_params)
    eng.start_lanes()
    with pytest.raises(ValueError):
        eng.prefill_lane(0, np.zeros(0, np.int32), segment=0)
    cfg, params = cfg_params
    dense = ServeEngine(cfg, params, ServeConfig(max_seq=32))
    with pytest.raises(ValueError):
        dense.prefill_lane(0, _prompt(0, 4), segment=0)


# -- TTFT / TPOT split --------------------------------------------------------

def test_latency_split_synthetic_timestamps():
    """ttft_ms is arrival->first-token, tpot_ms is inter-token gaps — with
    synthetic stamps the two distributions are recovered exactly, and the
    deprecated combined row still mixes them (old schema, one release)."""
    r1 = Request(rid=0, tenant="a", prompt=np.zeros(4, np.int32), max_new=3,
                 arrival_time=10.0, token_times=[10.5, 10.52, 10.54])
    r2 = Request(rid=1, tenant="a", prompt=np.zeros(4, np.int32), max_new=2,
                 arrival_time=20.0, token_times=[20.1, 20.14])
    rows = Scheduler._latency_rows([r1, r2])
    np.testing.assert_allclose(rows["ttft_ms"]["p50"], 300.0, atol=1e-6)
    np.testing.assert_allclose(rows["ttft_ms"]["mean"], 300.0, atol=1e-6)
    assert rows["ttft_ms"]["n"] == 2
    np.testing.assert_allclose(rows["tpot_ms"]["mean"],
                               (20 + 20 + 40) / 3, atol=1e-6)
    assert rows["tpot_ms"]["n"] == 3
    # the deprecated combined latency_ms row is gone (one-release window)
    assert "latency_ms" not in rows
    empty = Scheduler._latency_rows([])
    assert empty["ttft_ms"]["n"] == empty["tpot_ms"]["n"] == 0


def test_report_carries_split_rows(cfg_params):
    eng = _engine(cfg_params)
    sched = Scheduler(eng, [Tenant("a")], SchedConfig(prefill_chunk=8))
    sched.submit("a", _prompt(9, 12), max_new=4)
    sched.run(max_steps=100)
    rep = sched.report()
    for row in [rep, rep["tenants"]["a"]]:
        assert row["ttft_ms"]["n"] == 1
        assert row["tpot_ms"]["n"] == 3
        assert "latency_ms" not in row
        assert row["tpot_ms"]["p99"] > 0


# -- dense prefill: single pass ----------------------------------------------

def test_dense_prefill_runs_prompt_exactly_once(cfg_params):
    """The dense path must NOT re-run the prompt through per-token decode
    steps after the prefill scan (the old double-run), and must feed each
    observation stream exactly one batch for the whole prompt."""
    cfg, params = cfg_params
    eng = ServeEngine(cfg, params, ServeConfig(
        max_seq=32, resources=("embeddings",), embed_hot_slots=4,
        embed_rows_per_page=8))
    step_calls = []
    orig = eng._decode
    eng._decode = lambda *a: (step_calls.append(1), orig(*a))[1]
    observed = []
    h = eng.daemon["embeddings"]
    orig_obs = h.observe
    h.observe = lambda *a, **k: (observed.append(a), orig_obs(*a, **k))[1]

    prompt = (np.arange(2 * 10).reshape(2, 10) * 3 % cfg.vocab).astype(np.int32)
    first = eng.prefill(prompt)
    assert not step_calls                   # no per-token decode replay
    assert len(observed) == 1               # one masked observation batch
    assert int(np.asarray(eng.cache["pos"])) == 10
    assert eng.step_count == 10             # daemon cadence still advanced
    # the cache is genuinely filled: decode continues coherently
    nxt = eng.step(first)
    assert nxt.shape == (2,)
    assert len(step_calls) == 1


def test_dense_prefill_matches_paged(cfg_params):
    """Single-pass dense prefill + decode still reproduces the paged engine
    (the long-standing parity gate, now with no prompt double-run)."""
    cfg, params = cfg_params
    prompt = (np.arange(2 * 12).reshape(2, 12) * 7 % cfg.vocab).astype(np.int32)
    dense = ServeEngine(cfg, params, ServeConfig(max_seq=64))
    paged = ServeEngine(cfg, params, ServeConfig(
        max_seq=64, paged=True, page_t=4, hot_slots=16, migration_interval=4))
    np.testing.assert_array_equal(dense.generate(prompt, n_tokens=8),
                                  paged.generate(prompt, n_tokens=8))
