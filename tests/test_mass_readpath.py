"""The profiler→data-plane loop closed on device (DESIGN.md §10):

* kernel-exported per-page softmax stats match the dense reference
  (denominators AND normalized mass; full-page/dense, partial-page,
  MLA-style, soft-capped);
* the jittable ``lookup_rows`` fast path is bit-exact with the host
  ``read_rows`` verb, including the slow-fallback mask;
* the serve engine's in-jit tiered reads (embeddings, experts) and the
  kernel-mass "kv" stream leave decode output bit-identical while serving
  through the placement table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import ops as pa_ops
from repro.kernels.paged_attn import ref as pa_ref

# ---------------------------------------------------------------------------
# kernel page-stats export vs the dense reference
# ---------------------------------------------------------------------------


def _case(b, h, hkv, dk, dv, p, t, seed=0, full=False):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(keys[0], (b, h, dk), jnp.float32)
    kp = jax.random.normal(keys[1], (b, p, t, hkv, dk), jnp.float32)
    vp = jax.random.normal(keys[2], (b, p, t, hkv, dv), jnp.float32)
    if full:
        lens = jnp.full((b, p), t, jnp.int32)
    else:
        lens = jax.random.randint(keys[3], (b, p), 0, t + 1)
        lens = lens.at[:, 0].set(jnp.maximum(lens[:, 0], 1))
    return q, kp, vp, lens


@pytest.mark.parametrize("b,h,hkv,dk,dv,p,t,softcap,full", [
    (2, 8, 2, 64, 64, 4, 16, 0.0, True),     # dense: every page full
    (2, 8, 2, 64, 64, 4, 16, 0.0, False),    # paged: partial/empty pages
    (1, 4, 4, 32, 32, 8, 32, 30.0, False),   # soft-capped logits
    (3, 8, 1, 576 // 8, 64, 2, 8, 0.0, False),   # MLA-style dk != dv
])
def test_kernel_l_matches_ref_denominator(b, h, hkv, dk, dv, p, t, softcap,
                                          full):
    """The kernel's running (m, l) equal the dense softmax max/denominator."""
    q, kp, vp, lens = _case(b, h, hkv, dk, dv, p, t, seed=b + p, full=full)
    m, l, _, pm, pl_ = pa_ops.paged_attention_local_stats(
        q, kp, vp, lens, softcap=softcap, return_page_stats=True)
    m_ref, l_ref = pa_ref.softmax_denominator_ref(q, kp, lens,
                                                  softcap=softcap)
    np.testing.assert_allclose(np.asarray(m[..., 0]), np.asarray(m_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l[..., 0]), np.asarray(l_ref),
                               rtol=1e-5, atol=1e-6)
    # the page partials reconstruct the SAME denominator: l = Σ_p pl·e^{pm-m}
    l_re = jnp.sum(pl_ * jnp.exp(pm - jnp.swapaxes(m, 1, 2)), axis=1)
    np.testing.assert_allclose(np.asarray(l_re), np.asarray(l_ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("softcap,full", [(0.0, True), (0.0, False),
                                          (30.0, False)])
def test_kernel_page_mass_matches_ref(softcap, full):
    q, kp, vp, lens = _case(2, 8, 2, 64, 64, 5, 16, seed=7, full=full)
    out, mass = pa_ops.paged_attention(q, kp, vp, lens, softcap=softcap,
                                       return_mass=True)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(pa_ref.paged_attention_ref(q, kp, vp, lens,
                                              softcap=softcap)),
        rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(
        np.asarray(mass),
        np.asarray(pa_ref.page_mass_ref(q, kp, lens, softcap=softcap)),
        rtol=1e-5, atol=1e-6)
    # a softmax share: valid pages sum to 1, empty pages contribute 0
    np.testing.assert_allclose(np.asarray(mass).sum(-1), 1.0, rtol=1e-5)
    empty = np.asarray(lens) == 0
    assert (np.asarray(mass)[empty] == 0.0).all()


def test_default_raw_signature_unchanged():
    """Existing 3-tuple consumers (sharded decode, seed tests) still work."""
    q, kp, vp, lens = _case(1, 4, 2, 32, 32, 3, 8)
    out = pa_ops.paged_attention_local_stats(q, kp, vp, lens)
    assert len(out) == 3
    o = pa_ops.paged_attention(q, kp, vp, lens)
    assert o.shape == q.shape


# ---------------------------------------------------------------------------
# lookup_rows: the in-jit read fast path vs the host verb
# ---------------------------------------------------------------------------


def _tiered_memory(n_pages=32, n_slots=6, seed=0):
    from repro import tiering as tm
    spec = tm.ResourceSpec("t", n_pages=n_pages, hot_slots=n_slots,
                           quota_pages=n_slots, row_shape=(3, 4),
                           row_dtype="float32")
    mem = tm.TieredMemory.from_spec(spec)
    state = mem.init()
    rows = jax.random.normal(jax.random.PRNGKey(seed),
                             (n_pages, 3, 4), jnp.float32)
    mem.bind_data(rows)
    # promote a few pages so the fast tier actually serves hits
    mem.enqueue(np.asarray([3, 7, 11, 19], np.int64))
    stats = tm.TierStats(name="t")
    state, event = mem.migrate(state, stats)
    mem.apply_migration(event, stats)
    return mem, state, rows


def test_lookup_rows_matches_host_read_rows():
    """jitted lookup_rows == host read_rows bit-for-bit, across hits,
    misses, and the all-hit / all-miss partitions the host verb special-
    cases."""
    from repro.tiering import migrate as migrate_lib
    mem, state, _ = _tiered_memory()
    jitted = jax.jit(lambda fast, slow, table, ids:
                     migrate_lib.lookup_rows(fast, slow, table, ids))
    for ids in ([3, 7, 11, 19],          # all fast-tier hits
                [0, 1, 2, 30],           # all slow fallback
                [3, 0, 11, 30, 7, 5]):   # mixed
        ids = jnp.asarray(ids, jnp.int32)
        got = jitted(mem.buffers.fast, mem.buffers.slow,
                     state.tier.page_slot, ids)
        want = mem.read_rows(state, ids)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lookup_rows_slow_fallback_mask_and_nd_ids():
    """The fallback mask is the placement table itself: resident pages come
    from the fast buffer, everything else from the slow store — verified
    against the raw buffers, with an N-D id batch (the expert-read shape)."""
    from repro.tiering import migrate as migrate_lib
    mem, state, rows = _tiered_memory()
    table = np.asarray(state.tier.page_slot)
    ids = jnp.asarray([[3, 0], [30, 11], [7, 2]], jnp.int32)   # (3, 2)
    got = np.asarray(jax.jit(migrate_lib.lookup_rows, static_argnums=())(
        mem.buffers.fast, mem.buffers.slow, state.tier.page_slot, ids))
    assert got.shape == (3, 2, 3, 4)
    fast = np.asarray(mem.buffers.fast)
    slow = np.asarray(mem.buffers.slow)
    for i in range(3):
        for j in range(2):
            pid = int(ids[i, j])
            want = fast[table[pid]] if table[pid] >= 0 else slow[pid]
            np.testing.assert_array_equal(got[i, j], want)
    # resident pages really did serve from the fast buffer (hit mask live)
    assert table[3] >= 0 and table[11] >= 0 and table[0] < 0


def test_handle_tier_view_roundtrip():
    """ResourceHandle.tier_view feeds the same arrays lookup_rows needs."""
    from repro import tiering as tm
    from repro.tiering import migrate as migrate_lib
    mem, state, _ = _tiered_memory()
    view = mem.tier_view(state)
    assert set(view) == {"fast", "slow", "page_slot", "scale"}
    assert view["scale"] is None          # "none" codec stores no scales
    ids = jnp.asarray([3, 30], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(migrate_lib.lookup_rows(view["fast"], view["slow"],
                                           view["page_slot"], ids,
                                           scale=view["scale"])),
        np.asarray(mem.lookup_rows(state, ids)))


# ---------------------------------------------------------------------------
# serve engine: in-jit tiered reads + kernel mass stream
# ---------------------------------------------------------------------------


def _engine(arch, seed=0, **kw):
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, ServeEngine(cfg, params, ServeConfig(**kw))


KW = dict(max_seq=64, paged=True, page_t=4, hot_slots=16,
          migration_interval=4, resources=("embeddings",),
          embed_hot_slots=4, embed_rows_per_page=8)


def test_injit_embedding_reads_bit_exact():
    """Serving embeddings through the placement table inside the jitted
    step is bit-identical to the dense table gather — tiers are inclusive,
    so residency can only change WHERE a row is read, never its value."""
    prompt = (np.arange(2 * 10).reshape(2, 10) * 5) % 256
    _, on = _engine("llama3.2-3b", **KW)
    out_on = on.generate(prompt, n_tokens=8)
    _, off = _engine("llama3.2-3b", **KW, jit_tier_reads=False)
    out_off = off.generate(prompt, n_tokens=8)
    np.testing.assert_array_equal(out_on, out_off)
    # the in-jit path really served through the tier (placement live)
    assert on.daemon["embeddings"].hit_rate() > 0


def test_injit_expert_reads_serve_moe_arch():
    """MoE serving with expert rows gathered in-jit through the placement
    table: same tokens as the dense-dispatch engine, expert tier live."""
    prompt = np.arange(2 * 12).reshape(2, 12) % 256
    kw = dict(max_seq=128, paged=True, page_t=8, hot_slots=4,
              migration_interval=2, resources=("experts",),
              expert_hot_slots=2)
    _, on = _engine("kimi-k2-1t-a32b", **kw)
    out_on = on.generate(prompt, n_tokens=6)
    _, off = _engine("kimi-k2-1t-a32b", **kw, jit_tier_reads=False)
    out_off = off.generate(prompt, n_tokens=6)
    np.testing.assert_array_equal(out_on, out_off)
    assert on.daemon["experts"].hit_rate() > 0


def test_moe_tiered_dispatch_matches_ep():
    """moe_apply_tiered (payload-row gather) == moe_apply_ep (dense-weight
    dispatch) for the same routing, with every page in the slow tier."""
    from repro.configs.registry import get_smoke_config
    from repro.models import moe as moe_lib
    from repro.models import transformer as tr
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    ffn = params["blocks"][cfg.pattern.index("moe")]["ffn"]
    g, e = ffn["w_in"].shape[:2]
    payload = jnp.concatenate(
        [ffn[k].reshape(g * e, -1) for k in ("w_gate", "w_in", "w_out")], -1)
    tier = {"fast": jnp.zeros((4,) + payload.shape[1:], payload.dtype),
            "slow": payload,
            "page_slot": jnp.full((g * e,), -1, jnp.int32)}
    p0 = {k: v[0] for k, v in ffn.items()}
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model),
                          jnp.bfloat16)
    y_t, idx_t, _ = moe_lib.moe_apply_tiered(p0, x, cfg.moe.top_k,
                                             tier=tier,
                                             group_id=jnp.int32(0))
    y_e, idx_e, _ = moe_lib.moe_apply_ep(p0, x, cfg.moe.top_k)
    np.testing.assert_array_equal(np.asarray(idx_t), np.asarray(idx_e))
    np.testing.assert_allclose(np.asarray(y_t, np.float32),
                               np.asarray(y_e, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_kv_kernel_mass_stream_observed():
    """The "kv" resource observes the decode kernel's softmax mass: the
    stream is live (profiler sees traffic), output tokens are identical to
    the fill-proxy engine (the stream changes PLACEMENT, never logits)."""
    prompt = (np.arange(2 * 10).reshape(2, 10) * 3) % 256
    _, kern = _engine("llama3.2-3b", **KW, kv_mass_source="kernel")
    out_k = kern.generate(prompt, n_tokens=8)
    assert kern._last_kv_mass is not None
    m = np.asarray(kern._last_kv_mass)
    assert m.shape == (2, KW["hot_slots"])
    np.testing.assert_allclose(m.sum(-1), 1.0, rtol=1e-4)
    _, fill = _engine("llama3.2-3b", **KW, kv_mass_source="fill")
    out_f = fill.generate(prompt, n_tokens=8)
    np.testing.assert_array_equal(out_k, out_f)
    assert kern.daemon["kv"].hit_rate() > 0
    with pytest.raises(ValueError):
        _engine("llama3.2-3b", **KW, kv_mass_source="bogus")


def test_lane_mode_kernel_mass_masks_inactive_lanes():
    """Lane mode: the kernel mass stream is masked exactly like the gid
    stream — an inactive lane's pages never reach the profiler."""
    from repro.serve.sched import Scheduler, Tenant
    _, eng = _engine("llama3.2-3b", **{**KW, "hot_slots": 5},
                     lanes=2, kv_segments=2)
    sched = Scheduler(eng, [Tenant("a")])
    sched.submit("a", (np.arange(6) * 7 + 1) % 256, max_new=4)
    for _ in range(6):
        sched.step()
    assert eng._last_kv_mass is not None
    # lane 1 never ran a request: its segment-mapped gids are all -1
    sv = eng._kv_lane_stream()
    assert sv is not None
    _, gids = sv
    assert (gids[1] == -1).all()
    assert eng.daemon["kv"].hit_rate() >= 0.0   # stream digested cleanly
