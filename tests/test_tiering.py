"""TieredStore: promotion / 2Q demotion / ping-pong + pytree invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import tiering
from repro.core.tiering import TierParams, tier_init


def _promote(ts, pages, k=8):
    arr = np.full((k,), -1, np.int32)
    arr[:len(pages)] = pages
    return tiering.promote(ts, jnp.asarray(arr), k)


def _check_invariants(ts):
    page_slot = np.asarray(ts.page_slot)
    slot_page = np.asarray(ts.slot_page)
    # bijection: page -> slot -> page
    for p in np.nonzero(page_slot >= 0)[0]:
        assert slot_page[page_slot[p]] == p, (p, page_slot[p])
    for s in np.nonzero(slot_page >= 0)[0]:
        assert page_slot[slot_page[s]] == s, (s, slot_page[s])


def test_promote_fill_and_evict():
    ts = tier_init(TierParams(num_pages=100, num_slots=4, quota_pages=8))
    ts, pr, vs = _promote(ts, [1, 2, 3])
    assert set(np.asarray(pr)[:3].tolist()) == {1, 2, 3}
    _check_invariants(ts)
    ts = tiering.touch(ts, jnp.asarray([1, 2], jnp.int32))
    ts, pr, vs = _promote(ts, [4, 5])       # fills slot 4, evicts 1
    _check_invariants(ts)
    page_slot = np.asarray(ts.page_slot)
    assert (page_slot[[1, 2, 3, 4, 5]] >= 0).sum() == 4  # one got evicted
    assert int(ts.demoted_cnt) == 1


def test_2q_prefers_unreferenced_inactive():
    ts = tier_init(TierParams(num_pages=100, num_slots=2, quota_pages=4))
    ts, _, _ = _promote(ts, [10, 11], k=4)
    # touch 10 twice: graduates to active list
    ts = tiering.touch(ts, jnp.asarray([10], jnp.int32))
    ts = tiering.touch(ts, jnp.asarray([10], jnp.int32))
    ts, pr, vs = _promote(ts, [12], k=4)
    # victim must be 11 (inactive), not 10 (active & referenced)
    assert np.asarray(ts.page_slot)[10] >= 0
    assert np.asarray(ts.page_slot)[11] == -1
    _check_invariants(ts)


def test_ping_pong_flag():
    ts = tier_init(TierParams(num_pages=50, num_slots=1, quota_pages=4))
    ts, _, _ = _promote(ts, [5], k=4)
    ts, _, _ = _promote(ts, [6], k=4)      # evicts 5 -> PG_demoted[5]
    ts, _, _ = _promote(ts, [5], k=4)      # 5 comes back -> ping-pong
    ts, stats = tiering.drain_period_stats(ts)
    assert int(stats["ping_pong"]) == 1


def test_touch_counts_hits_misses():
    ts = tier_init(TierParams(num_pages=50, num_slots=4, quota_pages=8))
    ts, _, _ = _promote(ts, [1, 2])
    ts = tiering.touch(ts, jnp.asarray([1, 2, 30, 31, -1], jnp.int32))
    ts, stats = tiering.drain_period_stats(ts)
    assert int(stats["fast_reads"]) == 2
    assert int(stats["slow_reads"]) == 2   # -1 is padding


def test_duplicate_hot_pages_deduped():
    ts = tier_init(TierParams(num_pages=50, num_slots=8, quota_pages=8))
    ts, pr, _ = _promote(ts, [7, 7, 7, 8])
    assert int((np.asarray(pr) == 7).sum()) == 1
    _check_invariants(ts)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.lists(st.integers(0, 49), min_size=0, max_size=6),
                min_size=1, max_size=8))
def test_hypothesis_invariants_random_schedule(batches):
    ts = tier_init(TierParams(num_pages=50, num_slots=5, quota_pages=8))
    for pages in batches:
        ts, _, _ = _promote(ts, pages)
        ts = tiering.touch(ts, jnp.asarray(
            np.asarray(pages + [0], np.int32)))
    _check_invariants(ts)
    # resident count never exceeds slots
    assert int((np.asarray(ts.page_slot) >= 0).sum()) <= 5
