"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.sketch import SketchParams, sketch_init
from repro.kernels.cms_hist import ops as hops
from repro.kernels.neoprof_update import neoprof_update as ku
from repro.kernels.neoprof_update import ops as kops
from repro.kernels.neoprof_update import ref as kref
from repro.kernels.paged_attn import ops as pa_ops
from repro.kernels.paged_attn.ref import paged_attention_ref


@pytest.mark.parametrize("width,depth,s", [
    (1 << 10, 2, 128), (1 << 12, 2, 256), (1 << 12, 3, 512), (1 << 14, 2, 1024),
])
def test_neoprof_update_matches_ref(width, depth, s):
    sp = SketchParams(width=width, depth=depth)
    st = sketch_init(sp, jax.random.PRNGKey(depth))
    rng = np.random.default_rng(width + s)
    ids = rng.integers(-1, 1 << 18, s).astype(np.int32)   # includes padding
    args = (st.counts, st.epochs.astype(jnp.int32), st.hot.astype(jnp.int32),
            jnp.asarray(ids), st.seeds, st.cur_epoch.astype(jnp.int32),
            sp.counter_max)
    outk = ku.sketch_update_pallas(*args, depth=depth, width=width,
                                   interpret=True)
    outr = kref.update_ref(*args)
    for a, b, name in zip(outk, outr, ["counts", "epochs", "est", "hot_before"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_mark_hot_matches_ref():
    sp = SketchParams(width=1 << 12, depth=2)
    st = sketch_init(sp)
    rng = np.random.default_rng(9)
    ids = rng.integers(0, 1 << 18, 256).astype(np.int32)
    is_hot = (rng.random(256) < 0.3).astype(np.int32)
    outk = ku.sketch_mark_hot_pallas(st.hot.astype(jnp.int32),
                                     jnp.asarray(ids), jnp.asarray(is_hot),
                                     st.seeds, depth=2, width=sp.width,
                                     interpret=True)
    outr = kref.mark_hot_ref(st.hot.astype(jnp.int32), jnp.asarray(ids),
                             jnp.asarray(is_hot), st.seeds)
    np.testing.assert_array_equal(np.asarray(outk), np.asarray(outr))


def test_kernel_ops_path_equals_core():
    """Full kernel wrapper == pure-jax sketch_update (state + newly_hot)."""
    sp = SketchParams(width=1 << 12, depth=2)
    st = sketch_init(sp)
    rng = np.random.default_rng(3)
    ids = jnp.asarray(np.concatenate([
        np.full(40, 77), rng.integers(0, 4000, 216)]).astype(np.int32))
    st_k, hot_k = kops.sketch_update(st, ids, jnp.int32(20), sp, interpret=True)
    st_c, hot_c = sk.sketch_update(st, ids, jnp.int32(20), sp)
    np.testing.assert_array_equal(np.asarray(hot_k), np.asarray(hot_c))
    np.testing.assert_array_equal(np.asarray(st_k.counts), np.asarray(st_c.counts))


def test_hist_kernel_matches_core():
    sp = SketchParams(width=1 << 12, depth=2)
    st = sketch_init(sp)
    rng = np.random.default_rng(5)
    st, _ = sk.sketch_update(st, jnp.asarray(rng.integers(0, 1 << 16, 4096),
                                             jnp.int32), jnp.int32(1 << 30), sp)
    hk = hops.sketch_histogram(st, sp, interpret=True)
    hc = sk.sketch_histogram(st, sp)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hc))


@pytest.mark.parametrize("b,h,hkv,dk,dv,p,t,softcap", [
    (2, 8, 2, 64, 64, 4, 16, 0.0),
    (1, 4, 4, 32, 32, 8, 32, 30.0),
    (3, 8, 1, 576 // 8, 64, 2, 8, 0.0),     # MLA-style dk != dv
    (2, 16, 8, 128, 128, 4, 64, 0.0),
])
def test_paged_attention_matches_ref(b, h, hkv, dk, dv, p, t, softcap):
    keys = jax.random.split(jax.random.PRNGKey(b * h + p), 4)
    q = jax.random.normal(keys[0], (b, h, dk), jnp.float32)
    kp = jax.random.normal(keys[1], (b, p, t, hkv, dk), jnp.float32)
    vp = jax.random.normal(keys[2], (b, p, t, hkv, dv), jnp.float32)
    lens = jax.random.randint(keys[3], (b, p), 0, t + 1)
    # ensure at least one valid token per batch row
    lens = lens.at[:, 0].set(jnp.maximum(lens[:, 0], 1))
    o_k = pa_ops.paged_attention(q, kp, vp, lens, softcap=softcap,
                                 interpret=True)
    o_r = paged_attention_ref(q, kp, vp, lens, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=3e-5, atol=3e-5)


def test_paged_attention_bf16():
    b, h, hkv, d, p, t = 2, 8, 2, 64, 4, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, h, d), jnp.bfloat16)
    kp = jax.random.normal(keys[1], (b, p, t, hkv, d), jnp.bfloat16)
    vp = jax.random.normal(keys[2], (b, p, t, hkv, d), jnp.bfloat16)
    lens = jnp.full((b, p), t, jnp.int32)
    o_k = pa_ops.paged_attention(q, kp, vp, lens, interpret=True)
    o_r = paged_attention_ref(q, kp, vp, lens)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), rtol=3e-2, atol=3e-2)
