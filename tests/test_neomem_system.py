"""End-to-end NeoMem behaviour: daemon loop, adapters, simulator claims.

These are the paper-validation tests: NeoMem must beat the baselines on
skewed workloads, converge after hot-set shifts, and cost ~nothing to
profile — the scaled-down versions of the paper's §VI results.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (NeoProfCommands, NeoProfParams, SketchParams,
                        TierParams, neoprof_init, neoprof_observe, tier_init)
from repro.core.adapters.embed_cache import EmbedCache, EmbedTierConfig
from repro.core.adapters.expert_cache import ExpertCache, ExpertTierConfig
from repro.core.daemon import DaemonParams, NeoMemDaemon
from repro.core.simulator import WORKLOADS, MemModel, run_sim


def test_daemon_promotes_hot_pages():
    pp = NeoProfParams(sketch=SketchParams(width=1 << 12))
    tp = TierParams(num_pages=1024, num_slots=64, quota_pages=32)
    daemon = NeoMemDaemon(pp, tp, DaemonParams(
        migration_interval=1, threshold_update_period=4, clear_interval=16))
    prof, tier = neoprof_init(pp), tier_init(tp)
    prof = daemon.cmd.set_threshold(prof, 8)
    rng = np.random.default_rng(0)
    for step in range(32):
        hot = rng.integers(900, 916, 192)       # 16 hot pages
        cold = rng.integers(0, 900, 64)
        prof = neoprof_observe(prof, jnp.asarray(
            np.concatenate([hot, cold]).astype(np.int32)), pp)
        prof, tier = daemon.tick(prof, tier)
    resident = np.asarray(tier.slot_page)
    resident = set(resident[resident >= 0].tolist())
    hot_resident = len(resident & set(range(900, 916)))
    assert hot_resident >= 12, f"only {hot_resident}/16 hot pages resident"


def test_expert_cache_tracks_router_stream():
    cfg = ExpertTierConfig(n_groups=4, n_experts=16, hot_slots=4,
                           quota_pages=16)
    cache = ExpertCache(cfg)
    cache.prof = cache.daemon.cmd.set_threshold(cache.prof, 4)
    rng = np.random.default_rng(1)
    for _ in range(16):
        # skewed router: experts 0..3 hot in every group
        idx = rng.choice(4, size=(4, 1, 2, 16, 2)).astype(np.int32)
        cache.observe_step(jnp.asarray(idx))
        cache.tick()
    res = cache.residency()
    hot_pages = {g * 16 + e for g in range(4) for e in range(4)}
    resident = set(np.nonzero(res >= 0)[0].tolist())
    assert len(resident & hot_pages) >= 8


def test_embed_cache_hit_rate_improves():
    cfg = EmbedTierConfig(vocab=8192, hot_slots=32, quota_pages=16)
    cache = EmbedCache(cfg)
    cache.prof = cache.daemon.cmd.set_threshold(cache.prof, 4)
    rng = np.random.default_rng(2)
    early = late = None
    for step in range(24):
        toks = rng.zipf(1.5, 512) % 8192
        cache.observe_tokens(jnp.asarray(toks.astype(np.int32)))
        cache.tick()
        if step == 4:
            early = cache.hit_rate()
    late = cache.hit_rate()
    assert late > early


@pytest.mark.slow
def test_neomem_beats_baselines_on_gups():
    """Paper Fig. 11 (scaled): NeoMem >= every baseline on skewed GUPS."""
    res = {}
    for method in ["neomem", "first-touch", "pte-scan", "pebs", "tpp"]:
        stream = WORKLOADS["gups"](n_pages=4096, block=2048, n_blocks=120,
                                   seed=3)
        res[method] = run_sim(method, stream, n_pages=4096, fast_ratio=1 / 3,
                              quota_pages=128, sketch_width=1 << 12)
    for m in ["first-touch", "pte-scan", "pebs", "tpp"]:
        assert res["neomem"].runtime < res[m].runtime * 1.02, (
            m, res[m].runtime, res["neomem"].runtime)
    assert res["neomem"].hit_rate > res["first-touch"].hit_rate


@pytest.mark.slow
def test_convergence_after_hotset_shift():
    """Paper Fig. 16 (scaled): hit rate recovers after the hot set moves."""
    stream = WORKLOADS["gups"](n_pages=4096, block=2048, n_blocks=160,
                               seed=4, shift_at=80)
    r = run_sim("neomem", stream, n_pages=4096, fast_ratio=1 / 3,
                quota_pages=128, sketch_width=1 << 12, collect_trace=True,
                threshold_update_period=4)
    hits = [t["hit_rate"] for t in r.trace]
    pre = hits[len(hits) // 2 - 1]           # just before shift
    post = hits[-1]                           # end of run
    assert post > 0.5 * pre, (pre, post)


def test_profiling_overhead_negligible():
    """Paper §VI-D: NeoProf profiling adds ~0 modeled CPU overhead."""
    stream1 = WORKLOADS["gups"](n_pages=2048, block=1024, n_blocks=40, seed=5)
    r = run_sim("neomem", stream1, n_pages=2048, quota_pages=64,
                sketch_width=1 << 12, migration_interval=4)
    assert r.overhead_time < 0.01 * r.runtime
