"""Migration data plane (DESIGN.md §8): promotions move real bytes.

Covers the ISSUE-3 acceptance surface: bit-exact fast-tier serving after
promotion, demotion write-back round-trips, byte metering that respects the
per-epoch quota, the CPU logical-split fallback (this CI), the legacy shim
forwarding + deprecation warnings, and the BENCH_serve.json schema checker.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro.tiering as tm
from repro.dist import host_offload as ho
from repro.tiering import migrate as migrate_lib


def _spec(**kw):
    base = dict(name="embeddings", n_pages=64, hot_slots=8, quota_pages=4,
                sketch_width=1 << 8, row_shape=(3,), row_dtype="float32")
    base.update(kw)
    return tm.ResourceSpec(**base)


def _rows(n_pages, row_shape=(3,)):
    n = int(np.prod((n_pages,) + row_shape))
    return jnp.arange(n, dtype=jnp.float32).reshape((n_pages,) + row_shape)


# ---------------------------------------------------------------------------
# TieredMemory verbs
# ---------------------------------------------------------------------------

def test_promoted_rows_served_bit_exact_from_fast_tier():
    """After a promotion epoch, read_rows returns the fast-tier copy and it
    equals the slow-tier source bit-for-bit; unpromoted pages fall back."""
    spec = _spec()
    mem = tm.TieredMemory.from_spec(spec)
    data = _rows(spec.n_pages)
    mem.bind_data(data)
    state, stats = mem.init(), tm.TierStats(name="embeddings")
    mem.enqueue([5, 17, 40])
    state, event = mem.migrate(state, stats)
    assert mem.apply_migration(event, stats) > 0
    ids = np.array([5, 17, 40, 2])
    slots, hit = tm.lookup(state, jnp.asarray(ids))
    assert list(np.asarray(hit)) == [True, True, True, False]
    got = np.asarray(mem.read_rows(state, ids))
    np.testing.assert_array_equal(got, np.asarray(data[ids]))
    # the hit rows really came from the fast buffer, not the slow store
    fast = np.asarray(mem.buffers.fast)
    np.testing.assert_array_equal(fast[np.asarray(slots[:3])],
                                  np.asarray(data[ids[:3]]))


def test_demotion_round_trip_writes_back_dirty_rows():
    """A fast-tier row mutated in place survives eviction: the write-back
    lands in the slow store and is served from there afterwards."""
    spec = _spec(n_pages=16, hot_slots=2, quota_pages=2)
    mem = tm.TieredMemory.from_spec(spec)
    mem.bind_data(_rows(16))
    state, stats = mem.init(), tm.TierStats()
    mem.enqueue([3, 7])
    state, event = mem.migrate(state, stats)
    mem.apply_migration(event, stats)
    # dirty page 3's fast copy (the owner mutating its payload)
    slot3 = int(np.asarray(state.tier.page_slot)[3])
    dirty = jnp.full(spec.row_shape, -99.0, jnp.float32)
    mem.buffers = mem.buffers._replace(
        fast=mem.buffers.fast.at[slot3].set(dirty))
    # promote two new pages -> both slots evicted, page 3 written back
    mem.enqueue([9, 12])
    state, event = mem.migrate(state, stats)
    mem.apply_migration(event, stats)
    assert int(np.asarray(state.tier.page_slot)[3]) == -1   # demoted
    got = np.asarray(mem.read_rows(state, np.array([3])))[0]
    np.testing.assert_array_equal(got, np.asarray(dirty))


def test_epoch_bytes_never_exceed_quota_under_pressure():
    """Heavy sustained demand: every epoch's moved bytes stay within the
    2 * quota_pages * row_bytes budget, and lifetime totals accumulate."""
    spec = _spec(n_pages=256, hot_slots=16, quota_pages=4)
    mem = tm.TieredMemory.from_spec(spec)
    mem.bind_data(_rows(256))
    state, stats = mem.init(), tm.TierStats()
    rng = np.random.default_rng(0)
    total = 0
    for _ in range(20):
        mem.enqueue(rng.integers(0, 256, size=64))
        state, event = mem.migrate(state, stats)
        moved = mem.apply_migration(event, stats)
        assert moved <= spec.quota_bytes
        assert stats.last_epoch_bytes == moved
        total += moved
    assert stats.migration_bytes == total > 0
    assert stats.quota_bytes == spec.quota_bytes
    assert stats.migration_epochs > 0
    # an epoch with nothing to move reports 0, not the previous epoch's bytes
    mem._pending = mem._pending[:0]      # drain the queue -> empty epoch
    state, event = mem.migrate(state, stats)
    assert event is None and stats.last_epoch_bytes == 0


def test_cpu_fallback_is_logical_split():
    """On backends without memory kinds (this CI) the slow store is a plain
    device array — the data path runs unchanged, placement is bookkeeping."""
    assert not ho.supports_memory_kinds()   # CPU backend in CI
    buffers = migrate_lib.init_buffers(_rows(8, (2,)), num_slots=2)
    assert buffers.fast.shape == (2, 2) and buffers.slow.shape == (8, 2)
    out, n_up, n_down = migrate_lib.migrate(
        buffers, jnp.array([4, -1]), jnp.array([0, -1]), jnp.array([-1, -1]))
    assert (n_up, n_down) == (1, 0)
    np.testing.assert_array_equal(np.asarray(out.fast[0]),
                                  np.asarray(buffers.slow[4]))


def test_bind_data_validates_geometry_against_spec():
    mem = tm.TieredMemory.from_spec(_spec(n_pages=64, row_shape=(3,)))
    with pytest.raises(ValueError):        # wrong page count
        mem.bind_data(jnp.zeros((32, 3), jnp.float32))
    with pytest.raises(ValueError):        # wrong row shape
        mem.bind_data(jnp.zeros((64, 5), jnp.float32))
    with pytest.raises(ValueError):        # wrong dtype
        mem.bind_data(jnp.zeros((64, 3), jnp.bfloat16))
    with pytest.raises(ValueError):        # no payload bound
        mem.read_rows(mem.init(), np.array([0]))


def test_spec_byte_accounting():
    spec = _spec(quota_pages=8, row_shape=(4, 2), row_dtype="bfloat16")
    assert spec.row_bytes == 4 * 2 * 2
    assert spec.quota_bytes == 2 * 8 * spec.row_bytes
    assert tm.ResourceSpec("x", n_pages=4, hot_slots=2).row_bytes == 0


# ---------------------------------------------------------------------------
# multiplexed daemon + write_slow
# ---------------------------------------------------------------------------

def test_daemon_meters_bytes_per_resource():
    daemon = tm.NeoMemDaemon(tm.DaemonParams(
        migration_interval=1, threshold_update_period=64, clear_interval=64))
    a = daemon.register(tm.make_resource("embeddings", _spec()))
    b = daemon.register(tm.make_resource("embeddings", _spec(
        name="b", row_shape=(7,))))
    a.bind_data(_rows(64, (3,)))
    b.bind_data(_rows(64, (7,)))
    a.mem.enqueue([1, 2, 3])
    b.mem.enqueue([4, 5])
    daemon.tick()
    assert a.stats.migration_bytes == 3 * 3 * 4      # 3 rows of (3,) f32 up
    assert b.stats.migration_bytes == 2 * 7 * 4
    np.testing.assert_array_equal(np.asarray(b.read_rows(np.array([4]))[0]),
                                  np.asarray(_rows(64, (7,))[4]))


def test_write_rows_refreshes_both_tiers_and_meters():
    h = tm.NeoMemDaemon().register(tm.make_resource("embeddings", _spec()))
    h.bind_data(jnp.zeros((64, 3), jnp.float32))
    rows = jnp.stack([jnp.full((3,), 1.5), jnp.full((3,), 2.5)])
    h.write_rows(np.array([10, -1]), rows)           # -1 lane dropped
    got = np.asarray(h.read_rows(np.array([10, 11])))
    np.testing.assert_array_equal(got[0], np.full(3, 1.5))
    np.testing.assert_array_equal(got[1], np.zeros(3))
    assert h.stats.flush_bytes == 1 * 3 * 4          # one (3,) f32 row
    # promoted pages stay coherent: a write after promotion refreshes the
    # fast copy too, so the served (fast-tier) row is never stale
    h.mem.enqueue([10])
    h.state, event = h.mem.migrate(h.state, h.stats)
    h.mem.apply_migration(event, h.stats)
    h.write_rows(np.array([10]), jnp.full((1, 3), 9.0))
    slots, hit = h.lookup(jnp.asarray([10]))
    assert bool(np.asarray(hit)[0])                  # served from fast tier
    np.testing.assert_array_equal(
        np.asarray(h.read_rows(np.array([10])))[0], np.full(3, 9.0))
    np.testing.assert_array_equal(
        np.asarray(h.mem.buffers.fast[int(np.asarray(slots)[0])]),
        np.full(3, 9.0))


def test_write_pages_matches_write_rows():
    """The fused bulk page-write verb (the chunked-prefill flush,
    DESIGN.md §11) lands byte-identical rows to the per-page write_rows
    path it batches: same [K|V] concat, -1 ids dropped, same metering."""
    G, L, S, T, H, D = 2, 2, 3, 4, 1, 3
    kw = dict(name="kv-pages", n_pages=16, row_shape=(G, T, H, 2 * D))
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(G, L, S, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(G, L, S, T, H, D)), jnp.float32)
    ids = np.array([3, -1, 7, 0, 12, -1], np.int32)      # (L*S,) slot map

    a = tm.NeoMemDaemon().register(tm.make_resource("embeddings", _spec(**kw)))
    a.bind_data(jnp.zeros((16, G, T, H, 2 * D), jnp.float32))
    a.write_pages(ids, k, v)

    b = tm.NeoMemDaemon().register(tm.make_resource("embeddings", _spec(**kw)))
    b.bind_data(jnp.zeros((16, G, T, H, 2 * D), jnp.float32))
    rows = np.moveaxis(np.asarray(jnp.concatenate([k, v], axis=-1)), 0, 2)
    b.write_rows(ids, jnp.asarray(rows.reshape((L * S,) + rows.shape[2:])))

    np.testing.assert_array_equal(np.asarray(a.mem.buffers.slow),
                                  np.asarray(b.mem.buffers.slow))
    assert a.stats.flush_bytes == b.stats.flush_bytes > 0
    # page 7 sits at (lane 0, slot 2): it round-trips bit-exactly
    got = np.asarray(a.read_rows(np.array([7])))[0]
    np.testing.assert_array_equal(
        got, np.asarray(jnp.concatenate([k, v], axis=-1))[:, 0, 2])


# ---------------------------------------------------------------------------
# legacy shims: forwarding + deprecation
# ---------------------------------------------------------------------------

def test_legacy_adapters_warn_and_forward_data_plane():
    from repro.core.adapters.embed_cache import EmbedCache, EmbedTierConfig
    with pytest.warns(DeprecationWarning, match="repro.tiering.NeoMemDaemon"):
        cache = EmbedCache(EmbedTierConfig(vocab=256, hot_slots=4,
                                           rows_per_page=64, quota_pages=4))
    data = _rows(4, (64, 8))
    cache.bind_data(data)
    cache.handle.mem.enqueue([2])
    cache.tick()
    assert cache.migration_bytes > 0
    np.testing.assert_array_equal(np.asarray(cache.read_rows(np.array([2]))),
                                  np.asarray(data[2:3]))


def test_legacy_daemon_warns():
    from repro.core.daemon import DaemonParams, NeoMemDaemon
    from repro.core.neoprof import NeoProfParams
    from repro.core.sketch import SketchParams
    from repro.core.tiering import TierParams
    with pytest.warns(DeprecationWarning, match="deprecation shim"):
        NeoMemDaemon(NeoProfParams(sketch=SketchParams(width=1 << 8)),
                     TierParams(num_pages=16, num_slots=4, quota_pages=4),
                     DaemonParams(quota_pages=4))


def test_other_legacy_adapters_warn():
    from repro.core.adapters.expert_cache import (ExpertCache,
                                                  ExpertTierConfig)
    from repro.core.adapters.kv_tier import KVTier, KVTierConfig
    with pytest.warns(DeprecationWarning):
        ExpertCache(ExpertTierConfig(n_groups=2, n_experts=4, hot_slots=2))
    with pytest.warns(DeprecationWarning):
        KVTier(KVTierConfig(n_pages_total=16, hot_slots=4))


# ---------------------------------------------------------------------------
# BENCH_serve.json schema checker
# ---------------------------------------------------------------------------

def _bench_doc(tmp_path, mutate=None):
    import json
    row = {"name": "embeddings", "fast_reads": 10, "slow_reads": 2,
           "hit_rate": 10 / 12, "promoted": 4, "demoted": 1, "ping_pong": 0,
           "migration_bytes": 1024, "last_epoch_bytes": 256,
           "max_epoch_bytes": 256, "quota_bytes": 512,
           "migration_epochs": 4, "flush_bytes": 0, "inflight_bytes": 0,
           "stall_s": 0.2, "overlap_bytes_per_decode_s": 340.0}
    case = {"arch": "a", "batch": 2, "prompt_len": 8, "n_tokens": 4,
            "compile_s": 0.5, "tokens_per_s": 1.0, "wall_s": 8.0,
            "migration_bytes": 1024, "migration_bytes_per_s": 128.0,
            "resources": {"embeddings": row}}

    def ab_arm(source, steady):
        return {"kv_mass_source": source, "steps": 100, "tokens": 50,
                "wall_s": 4.0, "kv_hit": steady, "kv_hit_steady": steady,
                "kv_promoted": 8, "migration_bytes": 2048}
    mass_ab = {"arch": "a", "trace": "zipf-hot", "arrival": "mmpp",
               "lanes": 4, "seed": 0, "trace_steps": 100,
               "fill": ab_arm("fill", 0.4), "kernel": ab_arm("kernel", 0.45)}

    def pf_arm(chunk, ttft):
        return {"chunk": chunk, "compile_s": 2.0, "steps": 600,
                "ttft_ms": ttft,
                "tpot_ms": {"p50": 5.0, "p99": 6.0, "mean": 5.2, "n": 3},
                "tokens": [1, 2, 3, 4]}
    prefill = {"arch": "a", "prompt_len": 512, "max_new": 4, "page_t": 16,
               "chunk": 64, "lanes": 2, "seed": 0, "tokens_match": True,
               "ttft_ratio": 0.05, "token": pf_arm(0, 4000.0),
               "chunked": pf_arm(64, 200.0)}
    def reuse_arm(mode, pool, hit, steady):
        stats = None
        if mode != "off":
            stats = {"pool_pages": pool, "indexed": 30, "free": 2,
                     "shared_refs": 5, "lookups": 40, "matchable": 200,
                     "page_hits": int(200 * hit), "hit_rate": hit,
                     "tokens_saved": int(200 * hit) * 4, "published": 60,
                     "evicted": 20, "rejected": 1,
                     "shared_mass_share": 0.3}
        return {"mode": mode, "reuse_pages": pool, "steps": 240,
                "completed": 24, "tokens": 96, "compile_s": 3.0,
                "wall_s": 9.0, "kv_hit_steady": steady,
                "ttft_ms": {"p50": 30.0, "p99": 60.0, "mean": 35.0, "n": 24},
                "reuse": stats}
    kv_reuse = {"arch": "a", "trace": "agentic", "seed": 0,
                "trace_steps": 224, "turns": 24, "lanes": 4, "page_t": 4,
                "reuse_pages": 32, "prefill_chunk": 8,
                "tenants": {"agent-a": 1.0, "agent-b": 1.0},
                "tokens_match": True, "prefill_tokens_saved": 776,
                "hit_rate_gap": 0.04,
                "off": reuse_arm("off", 0, 0.0, 0.13),
                "prefix": reuse_arm("prefix", 32, 0.63, 0.13),
                "substring": reuse_arm("substring", 32, 0.67, 0.136)}
    def comp_arm(codec, wire, hit):
        return {"codec": codec, "steps": 240, "tokens": 96, "wall_s": 9.0,
                "hit_steady": {"embeddings": hit, "kv": 0.4},
                "wire_row_bytes": {"embeddings": wire, "kv": wire * 2},
                "migration_bytes": wire * 100, "max_epoch_bytes": wire * 8,
                "quota_bytes": wire * 16,
                "resources": {"embeddings": dict(row)}}
    compress = {"arch": "a", "trace": "zipf-hot", "arrival": "mmpp",
                "lanes": 4, "seed": 0, "trace_steps": 160, "quick": True,
                "arms": {"none": comp_arm("none", 1024, 0.72),
                         "fp32": comp_arm("fp32", 2048, 0.72),
                         "int8": comp_arm("int8", 516, 0.73)},
                "bytes_ratio_int8_fp32": 516 / 2048,
                "bytes_ratio_bound": 0.35, "hit_eps": 0.02,
                "tokens_match_none_fp32": True,
                "probe": {"prompt_len": 12, "n_steps": 8,
                          "tokens_match_none_fp32": True,
                          "drift_fp32": 0.0, "drift_int8": 0.19,
                          "drift_bound": 0.25},
                "zero1": {"steps": 6, "padded": 1632, "bytes_fp32": 39168,
                          "bytes_int8": 9840, "byte_ratio": 9840 / 39168,
                          "byte_ratio_bound": 0.30, "update_drift": 4e-5,
                          "drift_tolerance": 1e-3}}
    def ov_arm(mode, stall):
        return {"mode": mode, "steps": 16, "compile_s": 2.0, "wall_s": 4.0,
                "tokens_per_s": 8.0, "stall_s": stall,
                "migration_bytes": 1024,
                "resources": {"embeddings": dict(row)}}
    overlap = {"arch": "a", "batch": 2, "prompt_len": 12, "n_tokens": 16,
               "tokens_match": True, "stall_ratio_bound": 0.25,
               "sync": ov_arm("sync", 0.4), "async": ov_arm("async", 0.0)}
    doc = {"quick": True, "cases": [case], "mass_ab": mass_ab,
           "prefill": prefill, "kv_reuse": kv_reuse, "compress": compress,
           "overlap": overlap}
    if mutate:
        mutate(doc)
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(doc))
    return str(p)


def test_validate_bench_accepts_documented_schema(tmp_path):
    from benchmarks.validate_bench import validate
    assert validate(_bench_doc(tmp_path)) == []


def test_validate_bench_rejects_violations(tmp_path):
    from benchmarks.validate_bench import validate

    def no_bytes(doc):
        doc["cases"][0]["migration_bytes"] = 0
    assert any("nonzero" in e for e in validate(_bench_doc(tmp_path, no_bytes)))

    def over_quota(doc):
        doc["cases"][0]["resources"]["embeddings"]["max_epoch_bytes"] = 9999
    assert any("exceeds quota" in e
               for e in validate(_bench_doc(tmp_path, over_quota)))

    def max_epoch_lost(doc):
        doc["cases"][0]["resources"]["embeddings"]["last_epoch_bytes"] = 300
    assert any("epoch maximum" in e
               for e in validate(_bench_doc(tmp_path, max_epoch_lost)))

    def reads_lost(doc):
        doc["cases"][0]["resources"]["embeddings"]["hit_rate"] = 0.8
    assert any("read conservation" in e
               for e in validate(_bench_doc(tmp_path, reads_lost)))

    def missing_key(doc):
        del doc["cases"][0]["resources"]["embeddings"]["quota_bytes"]
    assert any("missing keys" in e
               for e in validate(_bench_doc(tmp_path, missing_key)))

    def no_mass_ab(doc):
        del doc["mass_ab"]
    assert any("mass_ab" in e for e in validate(_bench_doc(tmp_path,
                                                           no_mass_ab)))

    def fidelity_lost(doc):
        doc["mass_ab"]["kernel"]["kv_hit_steady"] = 0.30
    assert any("fidelity gate" in e
               for e in validate(_bench_doc(tmp_path, fidelity_lost)))

    def uneven_load(doc):
        doc["mass_ab"]["kernel"]["tokens"] = 49
    assert any("identical trace" in e
               for e in validate(_bench_doc(tmp_path, uneven_load)))

    def slow_chunked(doc):
        doc["prefill"]["chunked"]["ttft_ms"] = 3000.0
    assert any("1/4" in e for e in validate(_bench_doc(tmp_path,
                                                       slow_chunked)))

    def tokens_diverge(doc):
        doc["prefill"]["chunked"]["tokens"] = [9, 9, 9, 9]
    assert any("bit-exactness" in e
               for e in validate(_bench_doc(tmp_path, tokens_diverge)))

    def tpot_hidden(doc):
        doc["prefill"]["token"]["tpot_ms"]["p50"] = 0.0
    assert any("tpot_ms p50" in e
               for e in validate(_bench_doc(tmp_path, tpot_hidden)))

    def short_prompt(doc):
        doc["prefill"]["prompt_len"] = 64
    assert any("512" in e for e in validate(_bench_doc(tmp_path,
                                                       short_prompt)))

    def reuse_tokens_diverge(doc):
        doc["kv_reuse"]["tokens_match"] = False
    assert any("KV reuse changed" in e
               for e in validate(_bench_doc(tmp_path, reuse_tokens_diverge)))

    def reuse_no_savings(doc):
        doc["kv_reuse"]["prefill_tokens_saved"] = 0
    assert any("saved no prefill" in e
               for e in validate(_bench_doc(tmp_path, reuse_no_savings)))

    def hole_gap_lost(doc):
        doc["kv_reuse"]["substring"]["reuse"]["hit_rate"] = 0.63
    assert any("hole-skipping" in e
               for e in validate(_bench_doc(tmp_path, hole_gap_lost)))

    def reuse_degrades_tiering(doc):
        doc["kv_reuse"]["substring"]["kv_hit_steady"] = 0.05
    assert any("degraded tiering" in e
               for e in validate(_bench_doc(tmp_path,
                                            reuse_degrades_tiering)))

    def off_arm_has_stats(doc):
        doc["kv_reuse"]["off"]["reuse"] = \
            doc["kv_reuse"]["prefix"]["reuse"]
    assert any("store was not disabled" in e
               for e in validate(_bench_doc(tmp_path, off_arm_has_stats)))

    def reuse_stat_missing(doc):
        del doc["kv_reuse"]["substring"]["reuse"]["tokens_saved"]
    assert any("reuse stats missing" in e
               for e in validate(_bench_doc(tmp_path, reuse_stat_missing)))

    def no_compress(doc):
        del doc["compress"]
    assert any("compress section missing" in e
               for e in validate(_bench_doc(tmp_path, no_compress)))

    def byte_ratio_blown(doc):
        doc["compress"]["bytes_ratio_int8_fp32"] = 0.5
    assert any("not paying its way" in e
               for e in validate(_bench_doc(tmp_path, byte_ratio_blown)))

    def fp_arm_not_identity(doc):
        doc["compress"]["probe"]["drift_fp32"] = 0.01
    assert any("not transparent" in e
               for e in validate(_bench_doc(tmp_path, fp_arm_not_identity)))

    def int8_drift_blown(doc):
        doc["compress"]["probe"]["drift_int8"] = 0.9
    assert any("visibly moved" in e
               for e in validate(_bench_doc(tmp_path, int8_drift_blown)))

    def compress_tokens_diverge(doc):
        doc["compress"]["tokens_match_none_fp32"] = False
    assert any("full-precision slow store changed" in e
               for e in validate(_bench_doc(tmp_path,
                                            compress_tokens_diverge)))

    def compress_hit_degraded(doc):
        doc["compress"]["arms"]["int8"]["hit_steady"]["embeddings"] = 0.5
    assert any("degraded tiering behaviour" in e
               for e in validate(_bench_doc(tmp_path, compress_hit_degraded)))

    def zero1_parity_lost(doc):
        doc["compress"]["zero1"]["update_drift"] = 0.1
    assert any("lost fp32 parity" in e
               for e in validate(_bench_doc(tmp_path, zero1_parity_lost)))

    def compress_uneven_load(doc):
        doc["compress"]["arms"]["int8"]["tokens"] = 95
    assert any("every codec" in e
               for e in validate(_bench_doc(tmp_path, compress_uneven_load)))


    def overlap_tokens_diverge(doc):
        doc["overlap"]["tokens_match"] = False
    assert any("served different bytes" in e
               for e in validate(_bench_doc(tmp_path, overlap_tokens_diverge)))

    def overlap_bytes_skipped(doc):
        doc["overlap"]["async"]["resources"]["embeddings"][
            "migration_bytes"] = 512
    assert any("not skip them" in e
               for e in validate(_bench_doc(tmp_path, overlap_bytes_skipped)))

    def overlap_stall_blown(doc):
        doc["overlap"]["async"]["stall_s"] = 0.2   # > 0.25 * sync 0.4
    assert any("blocking decode" in e
               for e in validate(_bench_doc(tmp_path, overlap_stall_blown)))

    def overlap_no_baseline(doc):
        doc["overlap"]["sync"]["stall_s"] = 0.0
    assert any("baseline" in e
               for e in validate(_bench_doc(tmp_path, overlap_no_baseline)))

    def overlap_not_achieved(doc):
        doc["overlap"]["async"]["resources"]["embeddings"][
            "overlap_bytes_per_decode_s"] = 0.0
    assert any("metering is broken" in e
               for e in validate(_bench_doc(tmp_path, overlap_not_achieved)))

    def overlap_tail_uncommitted(doc):
        doc["overlap"]["async"]["resources"]["embeddings"][
            "inflight_bytes"] = 128
    assert any("finalize barrier" in e
               for e in validate(_bench_doc(tmp_path,
                                            overlap_tail_uncommitted)))

    def inflight_not_folded(doc):
        doc["cases"][0]["resources"]["embeddings"]["inflight_bytes"] = 400
    assert any("failed to fold" in e
               for e in validate(_bench_doc(tmp_path, inflight_not_folded)))


# ---------------------------------------------------------------------------
# serve engine end-to-end (CPU fallback path in CI)
# ---------------------------------------------------------------------------

def test_serve_engine_moves_real_bytes_and_serves_parity():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(
        max_seq=64, paged=True, page_t=4, hot_slots=8, migration_interval=4,
        resources=("embeddings",), embed_hot_slots=4))
    prompt = (np.arange(2 * 12).reshape(2, 12) * 7) % cfg.vocab
    eng.generate(prompt, n_tokens=8)
    stats = eng.tier_stats()
    for name in ("kv", "embeddings"):
        assert stats[name]["migration_bytes"] > 0, name
        assert stats[name]["last_epoch_bytes"] <= stats[name]["quota_bytes"]
    # embedding lookups match the live table bit-for-bit, hit or miss
    ids = np.array([0, 1, 2, 3])
    got = np.asarray(eng.read_rows("embeddings", ids))
    want = np.asarray(eng._embed_payload(tm.EMBED_ROWS_PER_PAGE)[ids])
    np.testing.assert_array_equal(got, want)
    # promoted KV pages carry the flushed page payload (nonzero, right shape)
    kv = np.asarray(eng.read_rows("kv", np.array([0])).astype(jnp.float32))
    assert kv.shape == (1,) + eng._kv_row_shape()
    assert np.abs(kv).sum() > 0
