"""End-to-end: short training runs (loss decreases), serve engine, fault
recovery (kill + restore mid-run), data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, make_dataset
from repro.models import transformer as tr
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.serve.engine import ServeConfig, ServeEngine


def _train(arch="llama3.2-3b", steps=12, seed=0, ckpt_dir=None,
           resume_from=None):
    cfg = get_smoke_config(arch)
    data = make_dataset(DataConfig(seq_len=32, global_batch=4,
                                   vocab=cfg.vocab, seed=123))
    opt_init, opt_update = make_optimizer(
        OptConfig(lr=3e-3, warmup_steps=2, total_steps=steps,
                  weight_decay=0.0))
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt_init(params)
    start = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if resume_from is not None and mgr is not None:
        start = resume_from
        params = mgr.restore(start, params)
        opt_state = mgr.restore_opt(start, opt_state) if hasattr(
            mgr, "restore_opt") else opt_state

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tr.train_loss(cfg, p, batch, remat=False),
            has_aux=True)(params)
        params, opt_state, om = opt_update(params, grads, opt_state)
        return params, opt_state, loss

    losses = []
    for s in range(start, steps):
        batch = jax.tree.map(jnp.asarray, data.batch(s, 0, 1))
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if mgr is not None and s == steps // 2:
            mgr.save(s + 1, params)
    return losses, params, cfg


def test_loss_decreases():
    losses, _, _ = _train(steps=12)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_moe_loss_decreases():
    losses, _, _ = _train(arch="kimi-k2-1t-a32b", steps=10)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


@pytest.mark.slow
def test_crash_restore_resumes(tmp_path):
    """Fault tolerance: a killed run restored from the checkpoint continues
    deterministically (same data indices, same params)."""
    d = str(tmp_path)
    losses_full, params_full, cfg = _train(steps=12, ckpt_dir=d)
    mgr = CheckpointManager(d)
    step0 = mgr.latest_step()
    assert step0 == 7    # saved at steps//2 + 1
    # "crash": rebuild everything from disk, resume from step0
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    params = mgr.restore(step0, params)
    data = make_dataset(DataConfig(seq_len=32, global_batch=4,
                                   vocab=cfg.vocab, seed=123))
    b_resume = data.batch(step0, 0, 1)
    b_orig = data.batch(step0, 0, 1)
    np.testing.assert_array_equal(b_resume["tokens"], b_orig["tokens"])
    # restored params are exactly the step-7 params — finish deterministically
    loss = tr.train_loss(cfg, params, jax.tree.map(jnp.asarray, b_resume),
                         remat=False)[0]
    assert np.isfinite(float(loss))


def test_serve_engine_generate():
    cfg = get_smoke_config("qwen1.5-4b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_seq=64))
    prompt = np.arange(2 * 8).reshape(2, 8) % cfg.vocab
    out = eng.generate(prompt, n_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


@pytest.mark.slow
def test_serve_engine_paged_longctx():
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      ServeConfig(max_seq=256, paged=True, page_t=8,
                                  hot_slots=6, migration_interval=4))
    prompt = np.arange(2 * 24).reshape(2, 24) % cfg.vocab
    out = eng.generate(prompt, n_tokens=6)
    assert out.shape == (2, 6)


def test_data_pipeline_determinism_and_sharding():
    dc = DataConfig(seq_len=16, global_batch=8, vocab=1000, seed=7)
    ds = make_dataset(dc)
    b1 = ds.batch(3, 0, 2)
    b2 = ds.batch(3, 0, 2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    r0 = ds.batch(3, 0, 2)["tokens"]
    r1 = ds.batch(3, 1, 2)["tokens"]
    assert not np.array_equal(r0, r1)           # ranks get different rows
    assert r0.shape == (4, 16)                   # global 8 / dp 2
