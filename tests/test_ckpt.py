"""Checkpoint manager: atomic save/restore, GC, elastic restore, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jnp.arange(10, dtype=jnp.int32),
                  "s": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(100, t)
    like = jax.tree.map(jnp.zeros_like, t)
    r = mgr.restore(100, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]          # keep=2 garbage-collected the rest


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(7), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    r = mgr.restore(7, jax.tree.map(jnp.zeros_like, _tree(7)))
    np.testing.assert_array_equal(np.asarray(r["b"]["w"]), np.arange(10))


def test_no_partial_commit(tmp_path):
    """tmp_ dirs never count as checkpoints (atomic rename contract)."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "tmp_step_0000000009")
    assert mgr.latest_step() is None


def test_elastic_restore_single_device(tmp_path):
    """A checkpoint restores under a different sharding (here: the 1-device
    'mesh') — the elastic-remesh path exercised at CPU scale; the 512-dev
    variant runs in the dry-run environment."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(5, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sh, t)
    r = mgr.restore(5, jax.tree.map(jnp.zeros_like, t), shardings=shardings)
    np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(t["a"]))
