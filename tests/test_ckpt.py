"""Checkpoint manager: atomic save/restore, GC, elastic restore, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jnp.arange(10, dtype=jnp.int32),
                  "s": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(100, t)
    like = jax.tree.map(jnp.zeros_like, t)
    r = mgr.restore(100, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]          # keep=2 garbage-collected the rest


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(7), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    r = mgr.restore(7, jax.tree.map(jnp.zeros_like, _tree(7)))
    np.testing.assert_array_equal(np.asarray(r["b"]["w"]), np.arange(10))


def test_no_partial_commit(tmp_path):
    """tmp_ dirs never count as checkpoints (atomic rename contract)."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "tmp_step_0000000009")
    assert mgr.latest_step() is None


def test_elastic_restore_single_device(tmp_path):
    """A checkpoint restores under a different sharding (here: the 1-device
    'mesh') — the elastic-remesh path exercised at CPU scale; the 512-dev
    variant runs in the dry-run environment."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(5, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sh, t)
    r = mgr.restore(5, jax.tree.map(jnp.zeros_like, t), shardings=shardings)
    np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(t["a"]))


# -- TieredMemoryState checkpointing (DESIGN.md §6, ROADMAP item) -------------

def _warm_daemon(stream_seed=0):
    """A small embeddings-tiered daemon with bound payload (the SAME table
    every time — a restarted server rebinds identical params), warmed by a
    seed-dependent skewed stream so the placement map holds promotions."""
    import repro.tiering as tm
    daemon = tm.NeoMemDaemon()
    spec = tm.ResourceSpec("embeddings", n_pages=32, hot_slots=4,
                           quota_pages=8, row_shape=(8, 16),
                           row_dtype="float32")
    h = daemon.register(tm.make_resource("embeddings", spec, rows_per_page=8))
    table = jax.random.normal(jax.random.PRNGKey(0), (32, 8, 16))
    h.bind_data(table)
    rng = np.random.default_rng(stream_seed)
    for _ in range(32):
        toks = (rng.zipf(1.5, size=64) % 32) * 8   # hot head of row pages
        h.observe(jnp.asarray(toks, jnp.int32))
        daemon.tick()
    return daemon, h, table


def test_tiering_state_roundtrip(tmp_path):
    """TieredMemoryState is a pure pytree: save through CheckpointManager,
    restore into a FRESH daemon, and the placement map + profiling state
    come back bit-exact, with fast buffers refilled for resident pages."""
    daemon, h, table = _warm_daemon()
    promoted = np.flatnonzero(np.asarray(h.state.tier.page_slot) >= 0)
    assert promoted.size > 0                     # the warmup actually promoted
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, daemon.state_dict())

    daemon2, h2, _ = _warm_daemon(stream_seed=99)   # differently-warmed server
    daemon2.load_state(mgr.restore(3, daemon2.state_dict()))
    for a, b in zip(jax.tree.leaves(daemon.state_dict()),
                    jax.tree.leaves(daemon2.state_dict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # warm placement: the restored map serves promoted pages from the fast
    # tier, and read_rows returns the right payload (refill_fast coherence)
    ids = jnp.asarray(promoted[:4], jnp.int32)
    _, hit = h2.lookup(ids)
    assert bool(np.asarray(hit).all())
    np.testing.assert_allclose(
        np.asarray(h2.read_rows(ids)),
        np.asarray(jnp.asarray(table, jnp.float32)[promoted[:4]]),
        rtol=1e-6)


def test_tiering_state_load_validates_geometry(tmp_path):
    import repro.tiering as tm
    daemon, _, _ = _warm_daemon()
    with pytest.raises(KeyError):
        daemon.load_state({"nope": daemon.state_dict()["embeddings"]})
    other = tm.NeoMemDaemon()
    spec = tm.ResourceSpec("embeddings", n_pages=16, hot_slots=2,
                           quota_pages=4)
    other.register(tm.make_resource("embeddings", spec))
    with pytest.raises(ValueError):              # 16-page map into 32-page tier
        daemon.load_state(other.state_dict())


def test_serve_engine_warm_restart(tmp_path):
    """A restarted ServeEngine resumes with the warm placement map: after
    load_tiering, hit rates and read_rows match the pre-restart server."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_seq=32, resources=("embeddings",),
                       embed_hot_slots=4, embed_rows_per_page=8,
                       migration_interval=4)
    eng = ServeEngine(cfg, params, scfg)
    prompt = (np.arange(2 * 10).reshape(2, 10) * 3) % 64   # skewed vocab use
    eng.generate(prompt, n_tokens=8)
    mgr = CheckpointManager(str(tmp_path))
    eng.save_tiering(mgr, step=1)

    eng2 = ServeEngine(cfg, params, scfg)                  # the restart
    h2 = eng2.daemon["embeddings"]
    assert int(np.sum(np.asarray(h2.state.tier.page_slot) >= 0)) == 0
    eng2.load_tiering(mgr, step=1)
    h1 = eng.daemon["embeddings"]
    np.testing.assert_array_equal(np.asarray(h1.state.tier.page_slot),
                                  np.asarray(h2.state.tier.page_slot))
    resident = np.flatnonzero(np.asarray(h2.state.tier.page_slot) >= 0)
    assert resident.size > 0
    ids = jnp.asarray(resident[:2], jnp.int32)
    np.testing.assert_array_equal(np.asarray(eng.read_rows("embeddings", ids)),
                                  np.asarray(eng2.read_rows("embeddings", ids)))


def test_restore_clears_stale_pending(tmp_path):
    """The pending FIFO belongs to the pre-restore stream: after
    load_state, a tick with no new observations must not promote stale
    backlog into the freshly restored placement map."""
    daemon, h, _ = _warm_daemon()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, daemon.state_dict())
    daemon2, h2, _ = _warm_daemon(stream_seed=99)
    h2.mem.enqueue(np.arange(20))                # pre-restore backlog
    daemon2.load_state(mgr.restore(1, daemon2.state_dict()))
    assert len(h2.mem._pending) == 0
    before = np.asarray(h2.state.tier.page_slot).copy()
    daemon2.tick()                               # no observations since restore
    np.testing.assert_array_equal(before, np.asarray(h2.state.tier.page_slot))
