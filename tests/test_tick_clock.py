"""TickClock: the daemon-cadence counter's interval-boundary arithmetic.

Pins the off-by-one class of bug the inline `_maybe_tick` arithmetic was
prone to: a chunk advance that lands exactly ON an interval boundary owes
that boundary's tick exactly once, and any partition of the same step
stream into advances must produce the same total tick count.
"""
import pytest

from repro.serve.clock import TickClock


def test_unit_steps_tick_every_interval():
    c = TickClock(4)
    ticks = [c.advance() for _ in range(12)]
    assert ticks == [0, 0, 0, 1] * 3
    assert c.steps == 12


def test_chunk_equal_to_interval_ticks_once():
    """The interval-boundary chunk length: n == interval owes exactly 1."""
    c = TickClock(8)
    assert c.advance(8) == 1
    assert c.advance(8) == 1
    assert c.steps == 16


def test_chunk_spanning_multiple_boundaries():
    c = TickClock(4)
    assert c.advance(11) == 2      # crosses 4 and 8
    assert c.advance(1) == 1       # reaches 12
    assert c.advance(3) == 0       # 13..15: no boundary
    assert c.advance(1) == 1       # 16


def test_boundary_landing_vs_crossing():
    """Landing ON a boundary and starting FROM one are not double counted."""
    c = TickClock(5)
    assert c.advance(5) == 1       # lands on 5: the boundary's tick
    assert c.advance(1) == 0       # starts from 5: already paid
    assert c.advance(4) == 1       # lands on 10


@pytest.mark.parametrize("interval", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("chunks", [
    [1] * 20,
    [7, 7, 7],
    [3, 5, 2, 8, 1, 1, 1],
    [20],
    [0, 4, 0, 4],                  # zero-length advances are free
])
def test_partition_invariance(interval, chunks):
    """Any partition of the step stream yields floor(total/interval) ticks."""
    c = TickClock(interval)
    total_ticks = sum(c.advance(n) for n in chunks)
    assert total_ticks == sum(chunks) // interval
    assert c.steps == sum(chunks)


def test_rejects_bad_arguments():
    with pytest.raises(ValueError):
        TickClock(0)
    with pytest.raises(ValueError):
        TickClock(-3)
    with pytest.raises(ValueError):
        TickClock(4).advance(-1)
