"""Unit tests for the repro.dist distribution layer beyond the seed tests:
compression round-trips on degenerate tensors, pspec inference fallbacks,
host-offload tier round-trips, a 1-stage pipeline, and the train step with
grad compression enabled end-to-end on the smoke config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.dist import compression, host_offload as ho
from repro.dist.pipeline import pipeline_apply
from repro.dist.sharding import batch_pspec, cache_pspecs, param_pspecs, path_str


# ---------------------------------------------------------------------------
# compression: property-style round trips
# ---------------------------------------------------------------------------

def _roundtrip(x):
    tree = {"t": x}
    ef = compression.ef_init(tree)
    qs, ef = compression.compress_grads(tree, ef)
    return compression.decompress_grads(qs)["t"], qs, ef


@pytest.mark.parametrize("x", [
    jnp.zeros((8, 8), jnp.float32),                       # all-zero: scale=0
    jnp.full((16,), 3.5, jnp.float32),                    # constant tensor
    jnp.asarray([1e30, -1e30, 1e22], jnp.float32),        # extreme magnitude
    jnp.asarray([1e-30, -1e-30, 0.0], jnp.float32),       # tiny magnitude
    jnp.linspace(-1.0, 1.0, 64).reshape(8, 8),            # generic
])
def test_compression_roundtrip_within_one_quantum(x):
    deq, qs, ef = _roundtrip(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert deq.shape == x.shape
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x),
                               atol=scale * 0.5 + 1e-12, rtol=0)
    # residual is exactly what the wire dropped
    np.testing.assert_allclose(np.asarray(ef["t"]),
                               np.asarray(x - deq), rtol=1e-6, atol=1e-30)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_compression_preserves_dtype(dtype):
    x = jnp.arange(16, dtype=dtype) / 16
    deq, qs, _ = _roundtrip(x)
    assert deq.dtype == dtype
    assert qs["t"]["q"].dtype == jnp.int8


def test_compression_unbiased_under_jit():
    """EF keeps the accumulated stream unbiased, also when jitted."""
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32, 16)),
                          jnp.float32)}

    @jax.jit
    def one(ef):
        qs, ef = compression.compress_grads(g, ef)
        return compression.decompress_grads(qs), ef

    ef = compression.ef_init(g)
    total = jnp.zeros_like(g["w"])
    n = 30
    for _ in range(n):
        deq, ef = one(ef)
        total = total + deq["w"]
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(total - n * g["w"]))) <= scale * 1.01


def test_compressed_bytes_counts_payload():
    qs, _ = compression.compress_grads(
        {"a": jnp.ones((4, 4)), "b": jnp.ones((3,))},
        compression.ef_init({"a": jnp.ones((4, 4)), "b": jnp.ones((3,))}))
    assert compression.compressed_bytes(qs) == (16 + 4) + (3 + 4)


# ---------------------------------------------------------------------------
# sharding: inference + divisibility fallback (AbstractMesh: no devices)
# ---------------------------------------------------------------------------

MESH24 = AbstractMesh((("data", 2), ("model", 4)))


def test_param_pspecs_rules():
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((256, 64), jnp.bfloat16)},
        "blocks": {
            "ln1": {"scale": jax.ShapeDtypeStruct((4, 64), jnp.float32)},
            "attn": {"wq": jax.ShapeDtypeStruct((4, 64, 64), jnp.bfloat16),
                     "wo": jax.ShapeDtypeStruct((4, 64, 64), jnp.bfloat16)},
            "ffn": {"w_in": jax.ShapeDtypeStruct((4, 64, 128), jnp.bfloat16),
                    "w_out": jax.ShapeDtypeStruct((4, 128, 64), jnp.bfloat16),
                    "router": jax.ShapeDtypeStruct((64, 8), jnp.float32)},
        },
    }
    sp = param_pspecs(params, MESH24)
    assert sp["embed"]["table"] == P("model", None)
    assert sp["blocks"]["ln1"]["scale"] == P(None, None)       # norm: replicated
    assert sp["blocks"]["attn"]["wq"] == P(None, None, "model")  # column
    assert sp["blocks"]["attn"]["wo"] == P(None, "model", None)  # row
    assert sp["blocks"]["ffn"]["w_in"] == P(None, None, "model")
    assert sp["blocks"]["ffn"]["w_out"] == P(None, "model", None)
    assert sp["blocks"]["ffn"]["router"] == P(None, None)      # replicated


def test_param_pspecs_moe_expert_dim():
    p = {"blocks": {"ffn": {
        "w_gate": jax.ShapeDtypeStruct((2, 8, 32, 64), jnp.bfloat16),
        "w_out": jax.ShapeDtypeStruct((2, 8, 64, 32), jnp.bfloat16),
    }}}
    sp = param_pspecs(p, MESH24)
    assert sp["blocks"]["ffn"]["w_gate"] == P(None, "model", None, None)
    assert sp["blocks"]["ffn"]["w_out"] == P(None, "model", None, None)


def test_param_pspecs_fallback_to_replicated():
    """A dim that doesn't divide the mesh axis must stay unsharded."""
    p = {"w_in": jax.ShapeDtypeStruct((10, 6), jnp.float32),    # 6 % 4 != 0
         "table": jax.ShapeDtypeStruct((7, 64), jnp.float32),   # 7 % 4 != 0
         "tiny": jax.ShapeDtypeStruct((3, 2), jnp.float32)}
    sp = param_pspecs(p, MESH24)
    assert sp["w_in"] == P(None, None)
    assert sp["table"] == P(None, None)
    assert sp["tiny"] == P(None, None)


def test_param_pspecs_fsdp_adds_data_axis():
    p = {"w_in": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
    sp = param_pspecs(p, MESH24, fsdp=True)
    assert sp["w_in"] == P("data", "model")
    # fallback: nothing left to shard over data -> column sharding only
    q = {"w_in": jax.ShapeDtypeStruct((3, 128), jnp.float32)}
    assert param_pspecs(q, MESH24, fsdp=True)["w_in"] == P(None, "model")


def test_batch_and_cache_pspecs():
    assert batch_pspec(MESH24) == P(("data",), None)
    cache = {"blocks": {
        "k": jax.ShapeDtypeStruct((4, 2, 32, 2, 16), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }}
    sp = cache_pspecs(cache, MESH24)
    assert sp["blocks"]["k"] == P(None, ("data",), "model", None, None)
    assert sp["blocks"]["pos"] == P()
    paged = {"blocks": {
        "k_pages": jax.ShapeDtypeStruct((4, 2, 16, 8, 2, 16), jnp.bfloat16)}}
    sp = cache_pspecs(paged, MESH24, slot_axes=("data", "model"))
    assert sp["blocks"]["k_pages"] == P(None, None, ("data", "model"),
                                        None, None, None)


def test_path_str():
    flat = jax.tree_util.tree_flatten_with_path(
        {"blocks": [{"attn": {"wq": 1}}]})[0]
    assert path_str(flat[0][0]) == "blocks/0/attn/wq"


# ---------------------------------------------------------------------------
# host offload + pipeline on a single device
# ---------------------------------------------------------------------------

def test_host_offload_roundtrip_2d():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(12.0).reshape(3, 4)
    y = ho.to_fast_tier(ho.to_slow_tier(x, mesh, P(None, None)),
                        mesh, P(None, None))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert isinstance(ho.supports_memory_kinds(), bool)


def test_pipeline_single_stage():
    """n_stages=1 degenerates to a plain scan over microbatches."""
    mesh = jax.make_mesh((1,), ("pod",))
    ws = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 8))

    def stage(w, h):
        return jnp.tanh(h @ w)

    with mesh:
        y = pipeline_apply(stage, ws, x, mesh=mesh, axis="pod")
    np.testing.assert_allclose(np.asarray(y), np.asarray(stage(ws[0], x)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# train step with grad compression: end-to-end on the smoke config
# ---------------------------------------------------------------------------

def test_train_step_grad_compression_end_to_end():
    from repro.configs.registry import get_smoke_config
    from repro.core.neoprof import NeoProfParams, neoprof_init
    from repro.core.sketch import SketchParams
    from repro.models import transformer as tr
    from repro.optim.optimizers import OptConfig, make_optimizer
    from repro.train.step import TrainConfig, build_train_step

    cfg = get_smoke_config("llama3.2-3b")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0, total_steps=10),
                       microbatches=2, remat=False, grad_compression=True)
    step = jax.jit(build_train_step(cfg, None, tcfg))

    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt_init, _ = make_optimizer(tcfg.opt)
    state = {"params": params, "opt": opt_init(params),
             "prof": neoprof_init(NeoProfParams(
                 sketch=SketchParams(width=tcfg.sketch_width))),
             "ef": compression.ef_init(params)}
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    # error feedback is live: residuals are nonzero after a step
    ef_norm = sum(float(jnp.sum(jnp.abs(l)))
                  for l in jax.tree_util.tree_leaves(state["ef"]))
    assert ef_norm > 0.0
    assert losses[-1] < losses[0]    # compressed grads still descend


def test_state_shapes_include_ef():
    from repro.configs.registry import get_smoke_config
    from repro.train.step import TrainConfig, make_state_shapes

    cfg = get_smoke_config("llama3.2-3b")
    shapes = make_state_shapes(cfg, TrainConfig(grad_compression=True))
    assert "ef" in shapes
    pl = jax.tree_util.tree_leaves(shapes["params"])
    el = jax.tree_util.tree_leaves(shapes["ef"])
    assert [tuple(e.shape) for e in el] == [tuple(p.shape) for p in pl]
    assert all(e.dtype == jnp.float32 for e in el)


def test_train_step_zero1_compressed_collective_end_to_end():
    """build_train_step(zero1=True, compress_collective=True) jits and
    descends: the flat spec is closure-static (never in the state pytree),
    the EF residual threads through, and the collective-byte aux prices
    the int8 gather under the fp32 one."""
    from repro.configs.registry import get_smoke_config
    from repro.core.neoprof import NeoProfParams, neoprof_init
    from repro.core.sketch import SketchParams
    from repro.models import transformer as tr
    from repro.optim import zero1
    from repro.optim.optimizers import OptConfig
    from repro.train.step import TrainConfig, build_train_step

    cfg = get_smoke_config("llama3.2-3b")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0, total_steps=10),
                       microbatches=2, remat=False, zero1=True,
                       compress_collective=True)
    step = jax.jit(build_train_step(cfg, None, tcfg))

    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt, spec = zero1.zero1_init(params, None, compress_collective=True)
    state = {"params": params, "opt": opt,
             "prof": neoprof_init(NeoProfParams(
                 sketch=SketchParams(width=tcfg.sketch_width)))}
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0]
    assert float(jnp.sum(jnp.abs(state["opt"]["ef"]))) > 0.0
    assert int(metrics["collective_bytes"]) < 4 * spec.padded
