"""Async migration data plane (DESIGN.md §15): double-buffered placement
tables that overlap the daemon's epoch copies with decode.

Pins the double-buffer semantics end to end: reads against the stale
committed epoch are bit-exact while a copy is in flight, writes landing
mid-epoch replay onto the in-flight buffer, no epoch N+2 issues before
N+1 commits, checkpoints commit-or-drop deterministically, and the serve
engine's sync/async arms produce identical tokens with the async arm's
decode stall at zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.tiering as tm
from repro.tiering import migrate as migrate_lib
from repro.tiering.memory import DaemonParams, TieredMemory
from repro.tiering.stats import TierStats

ROWS = np.arange(32 * 4, dtype=np.float32).reshape(32, 4) * 0.5


def _mem(async_plane, quota=4):
    spec = tm.ResourceSpec(name="t", n_pages=32, hot_slots=8,
                           quota_pages=quota, row_shape=(4,),
                           row_dtype="float32")
    mem = TieredMemory.from_spec(spec, daemon_params=DaemonParams(
        migration_interval=1, async_plane=async_plane))
    mem.bind_data(ROWS.copy())
    return mem, mem.init(), TierStats("t")


def _daemon(async_plane, n_pages=32, quota=8):
    # threshold updates frozen: these tests pin the DATA plane's epoch
    # lifecycle, so Algorithm-1 must not throttle promotions mid-test
    daemon = tm.NeoMemDaemon(tm.DaemonParams(
        async_plane=async_plane, threshold_update_period=10_000))
    spec = tm.ResourceSpec("embeddings", n_pages=n_pages, hot_slots=4,
                           quota_pages=quota, row_shape=(8, 16),
                           row_dtype="float32")
    h = daemon.register(tm.make_resource("embeddings", spec, rows_per_page=8))
    h.bind_data(jax.random.normal(jax.random.PRNGKey(0), (n_pages, 8, 16)))
    return daemon, h


def _drive(daemon, h, steps=24, seed=0, shift=0):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        toks = ((rng.zipf(1.5, size=64) + shift) % 32) * 8
        h.observe(jnp.asarray(toks, jnp.int32))
        daemon.tick()


# -- stale-epoch read parity --------------------------------------------------

def test_async_reads_bit_exact_vs_sync():
    """The same promotion stream through the sync and async planes: every
    read along the way is bit-identical (the stale committed epoch serves
    the same bytes because both tiers stay coherent), and total migration
    bytes agree once the last epoch is finalized."""
    runs = {}
    for mode in (False, True):
        mem, st, stats = _mem(mode)
        reads = []
        for i in range(12):
            mem.enqueue([i % 32, (i * 3) % 32, (i * 7) % 32])
            st, _ = mem.tick(st, stats)
            reads.append(np.asarray(mem.read_rows(st, jnp.arange(32))))
        mem.finalize_epoch(stats)
        reads.append(np.asarray(mem.read_rows(st, jnp.arange(32))))
        runs[mode] = (reads, stats)
    for i, (a, b) in enumerate(zip(runs[False][0], runs[True][0])):
        np.testing.assert_array_equal(a, b, err_msg=f"read {i}")
    s_sync, s_async = runs[False][1], runs[True][1]
    assert s_async.migration_bytes == s_sync.migration_bytes
    assert s_async.migration_bytes > 0
    assert s_async.inflight_bytes == 0       # finalize drained the epoch
    assert s_async.stall_s == 0.0            # never blocked on a commit
    assert s_sync.stall_s > 0.0              # the sync arm always blocks


def test_reads_during_inflight_epoch_are_stale_and_exact(monkeypatch):
    """With the readiness token held not-ready, reads resolve against the
    committed (pre-epoch) placement: promoted pages still serve from the
    slow tier, bit-exactly."""
    mem, st, stats = _mem(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    mem.enqueue([3, 9, 17])
    st, _ = mem.tick(st, stats)
    assert mem.busy and stats.inflight_bytes > 0
    # control table says promoted, committed view still says miss
    slots_ctl, _ = tm.lookup(st, jnp.asarray([3, 9, 17]))
    slots_seen = mem.lookup_slots(st, jnp.asarray([3, 9, 17]))
    assert (np.asarray(slots_ctl) >= 0).any()
    np.testing.assert_array_equal(np.asarray(slots_seen), -1)
    np.testing.assert_array_equal(
        np.asarray(mem.read_rows(st, jnp.arange(32))), ROWS)
    np.testing.assert_array_equal(
        np.asarray(mem.lookup_rows(st, jnp.arange(32))), ROWS)


# -- commit ordering ----------------------------------------------------------

def test_no_epoch_n2_issued_before_n1_commit(monkeypatch):
    """While the in-flight epoch's token is not ready, further ticks must
    neither commit nor issue — the single-buffer depth is an invariant."""
    mem, st, stats = _mem(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    mem.enqueue([1, 2, 3, 4])
    st, _ = mem.tick(st, stats)
    assert mem.busy
    fl = mem._inflight
    inflight0 = stats.inflight_bytes
    for i in range(4):
        mem.enqueue([(5 + i) % 32])
        st, event = mem.tick(st, stats)
        assert event is None                 # no new promotion batch
        assert mem._inflight is fl           # same epoch still in flight
        assert stats.inflight_bytes == inflight0
        assert stats.migration_epochs == 0   # nothing committed either
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: True)
    st, _ = mem.tick(st, stats)              # commit N+1, issue N+2
    assert stats.migration_epochs == 1
    assert stats.migration_bytes == inflight0
    # direct issue while busy is a programming error, not a silent overwrite
    mem2, st2, stats2 = _mem(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    mem2.enqueue([1, 2])
    st2, _ = mem2.tick(st2, stats2)
    from repro.tiering.memory import MigrationEvent
    ev = MigrationEvent(jnp.asarray([5], jnp.int32),
                        jnp.asarray([0], jnp.int32), 1)
    with pytest.raises(RuntimeError, match="in flight"):
        mem2.issue_migration(st2, ev, stats2)


def test_daemon_excludes_busy_resource_from_quota_split(monkeypatch):
    """The multiplexed daemon caps a busy resource at 0 in the budget split
    and re-issues only after its commit."""
    daemon, h = _daemon(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    _drive(daemon, h, steps=6)
    assert h.mem.busy
    assert h.stats.migration_epochs == 0
    pending_while_busy = h.stats.pending     # demand queues but never issues
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: True)
    _drive(daemon, h, steps=2, seed=1)
    assert h.stats.migration_epochs >= 1     # committed + re-issued
    assert h.stats.pending <= pending_while_busy + 64


# -- writes landing mid-epoch -------------------------------------------------

def test_write_mid_epoch_replays_onto_inflight_buffer(monkeypatch):
    """A write to a page being promoted by the in-flight epoch must land in
    BOTH the committed store and the in-flight buffer — otherwise the
    commit would resurrect the pre-write payload."""
    mem, st, stats = _mem(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    mem.enqueue([7, 21])
    st, _ = mem.tick(st, stats)
    assert mem.busy
    fresh = np.full((2, 4), 123.0, np.float32)
    mem.write_rows(st, jnp.asarray([7, 21]), jnp.asarray(fresh))
    # stale view: the write is visible right away through the slow tier
    np.testing.assert_array_equal(
        np.asarray(mem.read_rows(st, jnp.asarray([7, 21]))), fresh)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: True)
    st, _ = mem.tick(st, stats)              # the epoch commits
    slots = mem.lookup_slots(st, jnp.asarray([7, 21]))
    assert (np.asarray(slots) >= 0).all()    # now served from the fast tier
    np.testing.assert_array_equal(
        np.asarray(mem.read_rows(st, jnp.asarray([7, 21]))), fresh)


# -- checkpointing: commit-or-drop -------------------------------------------

def test_state_dict_finalizes_inflight_epoch(monkeypatch):
    """Saving with an uncommitted epoch force-commits it: the persisted
    placement map (the control table) matches the payload."""
    daemon, h = _daemon(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    _drive(daemon, h, steps=6)
    assert h.mem.busy and h.stats.inflight_bytes > 0
    states = daemon.state_dict()             # the commit half
    assert not h.mem.busy and h.stats.inflight_bytes == 0
    resident = np.flatnonzero(np.asarray(states["embeddings"].tier.page_slot)
                              >= 0)
    assert resident.size > 0
    # post-finalize reads serve resident pages from the fast tier
    slots = h.mem.lookup_slots(h.state, jnp.asarray(resident[:4], jnp.int32))
    assert (np.asarray(slots) >= 0).all()


def test_load_state_drops_inflight_epoch(monkeypatch):
    """Restoring with an uncommitted epoch drops it: the issued copy
    belongs to the pre-restore stream, and the committed view realigns
    with the restored control table."""
    daemon, h = _daemon(True)
    _drive(daemon, h, steps=8)
    daemon.finalize()
    saved = jax.tree.map(np.asarray, daemon.state_dict())
    table = np.asarray(h.state.tier.page_slot).copy()
    ref = np.asarray(h.read_rows(jnp.arange(8)))

    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    # drift toward a DIFFERENT hot set so an epoch is issued + left open
    _drive(daemon, h, steps=8, seed=3, shift=16)
    assert h.mem.busy
    monkeypatch.undo()
    daemon.load_state(saved)                 # the drop half
    assert not h.mem.busy and h.stats.inflight_bytes == 0
    np.testing.assert_array_equal(np.asarray(h.state.tier.page_slot), table)
    np.testing.assert_array_equal(np.asarray(h.read_rows(jnp.arange(8))), ref)


# -- mid-epoch snapshot conservation (satellite fix) -------------------------

def test_snapshot_folds_inflight_bytes(monkeypatch):
    """A telemetry snapshot taken mid-epoch still satisfies the row-level
    conservation gates: the issued bytes are folded into max_epoch_bytes
    so last <= max <= quota holds while the copy is in flight."""
    daemon, h = _daemon(True)
    monkeypatch.setattr(migrate_lib, "token_ready", lambda t: False)
    _drive(daemon, h, steps=6)
    assert h.mem.busy
    row = h.snapshot()
    assert row["inflight_bytes"] > 0
    assert row["last_epoch_bytes"] <= row["max_epoch_bytes"]
    assert row["inflight_bytes"] <= row["max_epoch_bytes"]
    assert row["max_epoch_bytes"] <= row["quota_bytes"]


# -- serve engine: sync/async A/B --------------------------------------------

ENGINE_KW = dict(max_seq=64, paged=True, page_t=4, hot_slots=6,
                 migration_interval=4, resources=("embeddings",),
                 embed_hot_slots=4, kv_quota=8)


@pytest.fixture(scope="module")
def engine_pair():
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    prompt = (np.arange(2 * 12).reshape(2, 12) * 7) % cfg.vocab
    sync = ServeEngine(cfg, params, ServeConfig(**ENGINE_KW))
    out_s = sync.generate(prompt, n_tokens=10)
    anc = ServeEngine(cfg, params, ServeConfig(async_migration=True,
                                               **ENGINE_KW))
    out_a = anc.generate(prompt, n_tokens=10)
    return sync, anc, out_s, out_a


def test_engine_async_bit_exact(engine_pair):
    sync, anc, out_s, out_a = engine_pair
    np.testing.assert_array_equal(out_s, out_a)


def test_engine_async_zero_stall_equal_bytes(engine_pair):
    sync, anc, _, _ = engine_pair
    anc.daemon.finalize()                    # end-of-run accounting barrier
    ss, sa = sync.tier_stats(), anc.tier_stats()
    for name in ss:
        assert ss[name]["migration_bytes"] == sa[name]["migration_bytes"], name
        assert sa[name]["stall_s"] == 0.0, name
        if ss[name]["migration_bytes"]:
            assert ss[name]["stall_s"] > 0.0, name
            assert sa[name]["overlap_bytes_per_decode_s"] > 0.0, name
        assert ss[name]["hit_rate"] == pytest.approx(sa[name]["hit_rate"],
                                                     abs=0.2), name


# -- preempt/resume + disagg hand-off landing mid-epoch ----------------------

def test_sched_disagg_preempt_bit_exact_under_async(engine_pair):
    """The full serving stack — chunked disaggregated prefill, hand-off,
    decode-lane preemption under a tight patience — replayed with the
    async plane on: token-for-token identical to the sync run, with
    hand-offs and preemptions actually exercised mid-epoch."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.sched import SchedConfig, Scheduler, Tenant
    cfg = get_smoke_config("llama3.2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(max_seq=48, paged=True, page_t=4, hot_slots=5,
                migration_interval=2, resources=("embeddings",),
                embed_hot_slots=4, embed_rows_per_page=8, kv_quota=8,
                lanes=2, kv_segments=5)
    work = [("a", 1, 18, 5), ("b", 2, 6, 6), ("a", 3, 11, 4),
            ("b", 4, 21, 3)]

    def serve(async_plane):
        eng = ServeEngine(cfg, params, ServeConfig(
            async_migration=async_plane, **base))
        sched = Scheduler(eng, [Tenant("a"), Tenant("b")], SchedConfig(
            preempt_patience=6, prefill_chunk=4, prefill_lanes=1,
            temperature=0.0, seed=7))
        rng = np.random.default_rng(0)
        reqs = [sched.submit(t, (rng.integers(0, cfg.vocab, n)
                                 .astype(np.int32)), max_new=m)
                for t, s, n, m in work]
        sched.run(max_steps=2000)
        return ({r.rid: list(r.out) for r in reqs},
                sum(r.preemptions for r in reqs), sched.handoffs, eng)

    out_s, _, _, _ = serve(False)
    out_a, preempts, handoffs, eng_a = serve(True)
    assert out_s == out_a
    assert handoffs == len(work)             # every request handed off
    eng_a.daemon.finalize()
    stats = eng_a.tier_stats()
    assert any(s["migration_bytes"] > 0 for s in stats.values())
    assert all(s["stall_s"] == 0.0 for s in stats.values())
