"""Distribution-layer tests that need >1 device run in a subprocess with
forced host devices (conftest must NOT set the flag globally)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import compression
from repro.optim.optimizers import OptConfig, adamw_init, adamw_update
from repro.optim import zero1

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_grad_compression_error_feedback():
    """int8+EF is unbiased over repeats: accumulated error stays bounded and
    the dequantized sum converges to the true sum."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    ef = compression.ef_init(g)
    total_q = jnp.zeros_like(g["w"])
    n = 20
    for _ in range(n):
        qs, ef = compression.compress_grads(g, ef)
        deq = compression.decompress_grads(qs)
        total_q = total_q + deq["w"]
    err = float(jnp.max(jnp.abs(total_q - n * g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert err <= scale * 1.01 + 1e-6    # residual never exceeds one quantum


def test_zero1_matches_adamw():
    """Flat-sharded ZeRO-1 update == per-tensor AdamW (single device)."""
    cfg = OptConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"a": jnp.ones((4, 8), jnp.float32) * 0.5,
              "b": jnp.arange(6, dtype=jnp.float32)}
    grads = {"a": jnp.full((4, 8), 0.1, jnp.float32),
             "b": jnp.linspace(-1, 1, 6, dtype=jnp.float32)}
    st_ref = adamw_init(params)
    p_ref, st_ref, _ = adamw_update(cfg, params, grads, st_ref)

    spec = zero1.flat_spec(params, n_shards=1)
    st_z = {"m": jnp.zeros((spec.padded,), jnp.float32),
            "v": jnp.zeros((spec.padded,), jnp.float32),
            "step": jnp.zeros((), jnp.int32)}
    p_z, st_z, _ = zero1.zero1_update(cfg, params, grads, st_z, spec, None)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_ref[k]), np.asarray(p_z[k]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single():
    """8-device (2 data x 4 model) train step: loss finite and equal to the
    unsharded loss (GSPMD correctness)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_smoke_config
        from repro.models import transformer as tr
        from repro.dist.sharding import param_pspecs

        cfg = get_smoke_config('llama3.2-3b')
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        batch = {'tokens': tokens, 'labels': tokens}

        loss_ref = tr.train_loss(cfg, params, batch, remat=False)[0]

        with mesh:
            specs = param_pspecs(params, mesh)
            ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                              params, specs)
            bs = jax.tree.map(lambda a: jax.device_put(
                a, NamedSharding(mesh, P('data', None))), batch)
            loss_sh = jax.jit(lambda p, b: tr.train_loss(cfg, p, b,
                                                         remat=False)[0])(ps, bs)
        err = abs(float(loss_ref) - float(loss_sh))
        assert err < 1e-2, (float(loss_ref), float(loss_sh))
        print('OK', float(loss_ref), float(loss_sh))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_ep_moe_matches_local():
    """shard_map EP MoE == single-device dispatch (same routing, no drops)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import moe as M
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        key = jax.random.PRNGKey(0)
        d, e, f, k = 32, 8, 64, 2
        p = M.moe_init(key, d, e, f, shared_f=32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d), jnp.float32)
        y_loc, idx_loc, _ = M.moe_apply_ep(p, x, k, ep_axes=None)
        with mesh:
            ep = M.EPContext(mesh=mesh, expert_axis='model', fsdp_axis='data',
                             dp_axes=('data',), capacity_factor=8.0)
            y_ep, idx_ep, _ = jax.jit(
                lambda p, x: M.moe_apply_ep(p, x, k, ep_axes=ep))(p, x)
        np.testing.assert_array_equal(np.asarray(idx_loc), np.asarray(idx_ep))
        err = float(jnp.max(jnp.abs(y_loc - y_ep)))
        assert err < 2e-2, err
        print('OK', err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_parity():
    """GPipe ppermute pipeline == sequential stage application."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ('pod',))
        n_stages, m, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d), jnp.float32) * 0.2

        def stage(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d), jnp.float32)
        y_ref = x
        for i in range(n_stages):
            y_ref = stage(ws[i], y_ref)
        with mesh:
            y = jax.jit(lambda ws, x: pipeline_apply(stage, ws, x, mesh=mesh,
                                                     axis='pod'))(ws, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-5, err
        print('OK', err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_flash_decode_combine():
    """paged attention sharded over slots == unsharded (combine correctness)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels.paged_attn import ops as pa
        mesh = jax.make_mesh((8,), ('s',))
        b, h, hkv, d, pg, t = 2, 4, 2, 32, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
        kp = jax.random.normal(ks[1], (b, pg, t, hkv, d), jnp.float32)
        vp = jax.random.normal(ks[2], (b, pg, t, hkv, d), jnp.float32)
        lens = jax.random.randint(ks[3], (b, pg), 0, t + 1)
        o_ref = pa.paged_attention(q, kp, vp, lens, interpret=True)

        def body(q, kp, vp, lens):
            m, l, acc = pa.paged_attention_local_stats(q, kp, vp, lens,
                                                       interpret=True)
            return pa.combine_stats(m, l, acc, ('s',)).astype(q.dtype)

        with mesh:
            o = jax.jit(shard_map(body, mesh=mesh,
                in_specs=(P(), P(None, 's'), P(None, 's'), P(None, 's')),
                out_specs=P(), check_rep=False))(q, kp, vp, lens)
        err = float(jnp.max(jnp.abs(o - o_ref)))
        assert err < 1e-4, err
        print('OK', err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_flash_decode_page_mass_combine():
    """Kernel page-stats combine across 8 shards: the shard-assembled
    per-page softmax mass equals the unsharded kernel export AND the dense
    reference (the global pmax/psum normalizers are the output combine's
    own pair — DESIGN.md §10)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels.paged_attn import ops as pa
        from repro.kernels.paged_attn.ref import page_mass_ref
        mesh = jax.make_mesh((8,), ('s',))
        b, h, hkv, d, pg, t = 2, 4, 2, 32, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
        kp = jax.random.normal(ks[1], (b, pg, t, hkv, d), jnp.float32)
        vp = jax.random.normal(ks[2], (b, pg, t, hkv, d), jnp.float32)
        lens = jax.random.randint(ks[3], (b, pg), 0, t + 1)
        o_ref, mass_ref = pa.paged_attention(q, kp, vp, lens, interpret=True,
                                             return_mass=True)

        def body(q, kp, vp, lens):
            m, l, acc, pm, pl = pa.paged_attention_local_stats(
                q, kp, vp, lens, interpret=True, return_page_stats=True)
            o, mass = pa.combine_stats(m, l, acc, ('s',),
                                       page_m=pm, page_l=pl)
            return o.astype(q.dtype), mass

        with mesh:
            o, mass = jax.jit(shard_map(body, mesh=mesh,
                in_specs=(P(), P(None, 's'), P(None, 's'), P(None, 's')),
                out_specs=(P(), P(None, 's')), check_rep=False))(q, kp, vp, lens)
        err_o = float(jnp.max(jnp.abs(o - o_ref)))
        err_m = float(jnp.max(jnp.abs(mass - mass_ref)))
        err_r = float(jnp.max(jnp.abs(mass - page_mass_ref(q, kp, lens))))
        assert err_o < 1e-4, err_o
        assert err_m < 1e-5, err_m
        assert err_r < 1e-5, err_r
        sums = np.asarray(mass).sum(-1)
        assert np.allclose(sums, 1.0, rtol=1e-4), sums
        print('OK', err_m, err_r)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_paged_decode_mass_stream():
    """decode_step_paged over an 8-way slot-sharded mesh: both collect_mass
    branches lower, logits match the single-device path, and the shard-
    assembled kv_mass stream equals the local kernel export."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_smoke_config
        from repro.models import transformer as tr, decode as dec
        cfg = get_smoke_config('llama3.2-3b')
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        mesh = jax.make_mesh((8,), ('s',))
        smesh = {'mesh': mesh, 'axes': ('s',)}
        tok = jnp.zeros((2, 1), jnp.int32)
        cl = dec.init_paged_cache(cfg, 2, 8, 4)
        logits_l, _, streams_l = dec.decode_step_paged(
            cfg, params, cl, tok, page_t=4, return_streams=True)
        with mesh:
            cs = dec.init_paged_cache(cfg, 2, 8, 4)
            logits_s, _, streams_s = jax.jit(
                lambda p, c, t: dec.decode_step_paged(
                    cfg, p, c, t, page_t=4, smesh=smesh,
                    return_streams=True))(params, cs, tok)
            logits_s0, _ = jax.jit(
                lambda p, c, t: dec.decode_step_paged(
                    cfg, p, c, t, page_t=4, smesh=smesh))(params, cs, tok)
        np.testing.assert_allclose(np.asarray(logits_s),
                                   np.asarray(logits_l),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(logits_s0),
                                   np.asarray(logits_s),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(streams_s['kv_mass']),
                                   np.asarray(streams_l['kv_mass']),
                                   rtol=1e-4, atol=1e-5)
        print('OK')
    """)
    assert "OK" in out


def test_host_offload_fallback():
    """CPU backend: slow-tier placement degrades to logical separation."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist import host_offload as ho
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8.0)
    y = ho.to_slow_tier(x, mesh, P(None))
    z = ho.to_fast_tier(y, mesh, P(None))
    assert float(jnp.sum(z - x)) == 0.0
    assert isinstance(ho.supports_memory_kinds(), bool)


@pytest.mark.slow
def test_local_grads_compressed_psum_parity():
    """local_grads DP grad psum through the shared int8+EF core: losses
    track the fp32 reduce and the metered wire bytes drop ~4x."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_smoke_config
        from repro.core.neoprof import NeoProfParams, neoprof_init
        from repro.core.sketch import SketchParams
        from repro.dist import compression
        from repro.models import transformer as tr
        from repro.optim.optimizers import OptConfig, make_optimizer
        from repro.train.step import TrainConfig, build_train_step

        cfg = get_smoke_config('llama3.2-3b')
        mesh = jax.make_mesh((4,), ('data',))
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab)
        batch = {'tokens': tokens, 'labels': tokens}

        def run(local, compress):
            tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                             total_steps=10),
                               microbatches=2, remat=False,
                               local_grads=local, grad_compression=compress)
            opt_init, _ = make_optimizer(tcfg.opt)
            state = {'params': params, 'opt': opt_init(params),
                     'prof': neoprof_init(NeoProfParams(
                         sketch=SketchParams(width=tcfg.sketch_width)))}
            if compress:
                state['ef'] = compression.ef_init(params)
            losses, wire = [], None
            with mesh:
                step = jax.jit(build_train_step(cfg, mesh, tcfg))
                for _ in range(3):
                    state, m = step(state, batch)
                    losses.append(float(m['loss']))
                    if 'dp_psum_bytes' in m:
                        wire = int(m['dp_psum_bytes'])
            return losses, wire, state

        l_ref, _, _ = run(False, False)        # pjit-reduced baseline
        l_fp, b_fp, _ = run(True, False)       # manual fp32 psum
        l_q, b_q, st_q = run(True, True)       # manual int8+EF psum
        assert np.isfinite(l_ref + l_fp + l_q).all()
        for a, b in zip(l_ref, l_fp):          # manual == pjit (fp32, up to
            assert abs(a - b) < 1e-3, (l_ref, l_fp)   # reduction order)
        for a, b in zip(l_fp, l_q):            # int8+EF tracks fp32
            assert abs(a - b) < 5e-3, (l_fp, l_q)
        assert l_q[-1] < l_q[0]                # and still descends
        ratio = b_fp / b_q
        assert 3.5 < ratio <= 4.0, ratio
        ef_norm = sum(float(jnp.sum(jnp.abs(l)))
                      for l in jax.tree_util.tree_leaves(st_q['ef']))
        assert ef_norm > 0.0                   # error feedback is live
        print('OK', l_fp[-1], l_q[-1], ratio)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_zero1_offload_master_parity():
    """ZeRO-1 with the master/EF vectors parked on the pinned-host slow
    tier (prefetch-before-optimizer-step): bitwise identical to resident."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_smoke_config
        from repro.core.neoprof import NeoProfParams, neoprof_init
        from repro.core.sketch import SketchParams
        from repro.models import transformer as tr
        from repro.optim import zero1
        from repro.optim.optimizers import OptConfig
        from repro.train.step import TrainConfig, build_train_step

        cfg = get_smoke_config('llama3.2-3b')
        mesh = jax.make_mesh((8,), ('data',))
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab)
        batch = {'tokens': tokens, 'labels': tokens}

        def run(offload):
            tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                             total_steps=10),
                               microbatches=2, remat=False, zero1=True,
                               offload_master=offload)
            opt, _ = zero1.zero1_init(params, mesh, offload=offload)
            state = {'params': params, 'opt': opt,
                     'prof': neoprof_init(NeoProfParams(
                         sketch=SketchParams(width=tcfg.sketch_width)))}
            losses = []
            with mesh:
                step = jax.jit(build_train_step(cfg, mesh, tcfg))
                for _ in range(3):
                    state, m = step(state, batch)
                    losses.append(float(m['loss']))
            return losses, state

        l_res, st_res = run(False)
        l_off, st_off = run(True)
        assert l_res == l_off, (l_res, l_off)
        for k in ('m', 'v'):
            np.testing.assert_array_equal(np.asarray(st_res['opt'][k]),
                                          np.asarray(st_off['opt'][k]))
        print('OK', l_off[-1])
    """)
    assert "OK" in out
