"""Per-arch smoke tests: reduced config, one fwd/train step, shape + finite
checks, decode parity vs full forward.  (Deliverable (f) smoke requirement.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.models import decode as dec
from repro.models import transformer as tr
from repro.models.layers import logits_apply

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_aux_tokens:
        batch["aux_embeds"] = jax.random.normal(
            key, (B, cfg.n_aux_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tr.init_params(cfg, key)
    batch = _batch(cfg, key)

    def loss_fn(p):
        return tr.train_loss(cfg, p, batch, remat=True)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    sq = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(sq), f"{arch}: grad norm nan"
    # output shape check via forward
    x, _ = tr.forward(cfg, params, batch["tokens"],
                      aux_embeds=batch.get("aux_embeds"), remat=False)
    assert x.shape == (B, S, cfg.d_model)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_parity(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = tr.init_params(cfg, key)
    batch = _batch(cfg, key)
    raw_aux = batch.get("aux_embeds")
    dec_aux = raw_aux
    if cfg.encoder_layers and raw_aux is not None:
        dec_aux = tr.encode(cfg, params, raw_aux)

    cache = dec.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t: dec.decode_step(cfg, p, c, t,
                                                   aux_embeds=dec_aux))
    logits_step = None
    c = cache
    n = 4
    for t in range(n):
        logits_step, c = step(params, c, batch["tokens"][:, t:t + 1])
    x_full, _ = tr.forward(cfg, params, batch["tokens"][:, :n],
                           aux_embeds=raw_aux, remat=False)
    logits_full = logits_apply(params["embed"], x_full[:, -1:],
                               cfg.final_softcap)
    err = float(jnp.max(jnp.abs(logits_step - logits_full)))
    # bf16 params; MoE capacity paths may differ slightly at tiny scale
    assert err < 0.25, f"{arch}: decode divergence {err}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_paged_decode_runs(arch):
    cfg = get_smoke_config(arch)
    if cfg.encoder_layers:
        pytest.skip("paged decode n/a for enc-dec (see DESIGN.md skips)")
    key = jax.random.PRNGKey(2)
    params = tr.init_params(cfg, key)
    cache = dec.init_paged_cache(cfg, B, n_slots=4, page_t=8)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, c, t: dec.decode_step_paged(cfg, p, c, t,
                                                         page_t=8))
    c = cache
    for _ in range(10):   # crosses a page boundary (page_t=8)
        logits, c = step(params, c, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(c["pos"]) == 10


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    w = get_config("whisper-base")
    assert w.d_model == 512 and w.encoder_layers == 6 and w.vocab == 51865
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.n_experts == 256 and ds.moe.top_k == 8 and ds.mtp
    km = get_config("kimi-k2-1t-a32b")
    assert km.moe.n_experts == 384 and km.moe.top_k == 8


def test_param_counts_plausible():
    """Total params within 15% of the nameplate sizes."""
    targets = {
        "gemma2-27b": 27e9, "llama3.2-3b": 3.2e9, "qwen1.5-4b": 4e9,
        "kimi-k2-1t-a32b": 1.0e12, "deepseek-v3-671b": 671e9,
        "stablelm-1.6b": 1.6e9,
    }
    for arch, target in targets.items():
        n = get_config(arch).total_params()
        assert 0.7 * target < n < 1.35 * target, (arch, n, target)
